#!/usr/bin/env python
"""Docs link-check: every relative markdown link must resolve to a file.

    python docs/check_links.py

Scans all *.md files in the repo (skipping hidden and vendored dirs),
extracts inline links, and verifies local targets exist. External links
(http/https/mailto) are not fetched — CI must stay hermetic. Also run as a
test via tests/test_docs.py. Exits nonzero listing any broken links.
"""

from __future__ import annotations

import os
import re
import sys
from typing import List, Tuple

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SKIP_DIRS = {".git", ".github", "__pycache__", ".pytest_cache", "node_modules"}
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")


def markdown_files() -> List[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(ROOT):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        out.extend(os.path.join(dirpath, f) for f in filenames
                   if f.endswith(".md"))
    return sorted(out)


def broken_links() -> List[Tuple[str, str]]:
    bad = []
    for path in markdown_files():
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            local = target.split("#", 1)[0]
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), local))
            if not os.path.exists(resolved):
                bad.append((os.path.relpath(path, ROOT), target))
    return bad


def main() -> int:
    bad = broken_links()
    for src, target in bad:
        print(f"BROKEN {src}: {target}")
    n = len(markdown_files())
    print(f"checked {n} markdown files, {len(bad)} broken links")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
