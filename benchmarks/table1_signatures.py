"""Paper Table 1 CSV wrapper — the workload lives in ``repro.bench``.

Direct scheme (Alg 1, iisignature-style baseline) vs Horner's scheme
(Alg 2, pySigLib), forward and backward.  Cells and timing methodology:
:func:`repro.bench.workloads.table1_signatures`.
"""

from __future__ import annotations

from repro.bench import workloads

from .common import entry_row


def run(quick: bool = True, repeats: int = 5):
    entries = workloads.table1_signatures(
        mode="quick" if quick else "full", repeats=repeats)
    return [entry_row(e) for e in entries]
