"""Paper Table 1: truncated-signature forward/backward runtimes.

Compares the two algorithms the paper implements — the direct scheme (Alg 1,
iisignature-style baseline) and Horner's scheme (Alg 2, pySigLib) — plus the
Pallas kernel path (interpret mode on CPU; compiled on TPU).  The paper's
(B, L, d, N) cells are used, scaled by --quick for CI.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.signature import signature, signature_direct
from .common import bench, row

PAPER_CELLS = [(128, 256, 4, 6), (128, 512, 8, 5), (128, 1024, 16, 4)]
QUICK_CELLS = [(16, 64, 4, 6), (16, 128, 8, 5), (16, 256, 16, 4)]


def run(quick: bool = True, repeats: int = 5):
    cells = QUICK_CELLS if quick else PAPER_CELLS
    lines = []
    for (B, L, d, N) in cells:
        path = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.2
        tag = f"table1_B{B}_L{L}_d{d}_N{N}"

        f_direct = jax.jit(lambda p: signature_direct(p, N))
        f_horner = jax.jit(lambda p: signature(p, N))
        t_dir = bench(f_direct, path, repeats=repeats)
        t_hor = bench(f_horner, path, repeats=repeats)
        lines.append(row(f"{tag}_fwd_direct", t_dir))
        lines.append(row(f"{tag}_fwd_horner", t_hor,
                         f"speedup_vs_direct={t_dir / t_hor:.2f}x"))

        g_auto = jax.jit(jax.grad(lambda p: signature_direct(p, N).sum()))
        g_rev = jax.jit(jax.grad(lambda p: signature(p, N).sum()))
        t_ga = bench(g_auto, path, repeats=repeats)
        t_gr = bench(g_rev, path, repeats=repeats)
        lines.append(row(f"{tag}_bwd_autodiff", t_ga))
        lines.append(row(f"{tag}_bwd_timereversed", t_gr,
                         f"speedup_vs_autodiff={t_ga / t_gr:.2f}x"))
    return lines
