"""Log-signature runtimes: Horner + log/Lyndon epilogue vs plain signatures.

Measures (a) the overhead of the tensor-log + Lyndon projection on top of
the shared Horner recursion, (b) mode cost ("lyndon" gather vs "brackets"
triangular matmul vs "expand"), and (c) the achieved compression ratio
(Witt dimension vs full tensor dimension) — the reason to ship log-sigs.
"""

from __future__ import annotations

import jax

from repro.core.lyndon import logsig_dim
from repro.core.signature import signature
from repro.core.logsignature import logsignature
from repro.core.tensoralg import sig_dim
from .common import bench, row

PAPER_CELLS = [(128, 256, 4, 6), (128, 512, 8, 5), (128, 1024, 16, 4)]
QUICK_CELLS = [(16, 64, 4, 6), (16, 128, 8, 5), (16, 256, 16, 4)]


def run(quick: bool = True, repeats: int = 5):
    cells = QUICK_CELLS if quick else PAPER_CELLS
    lines = []
    for (B, L, d, N) in cells:
        path = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.2
        tag = f"table3_B{B}_L{L}_d{d}_N{N}"
        ratio = f"compress={logsig_dim(d, N)}/{sig_dim(d, N)}"

        f_sig = jax.jit(lambda p: signature(p, N, backend="reference"))
        t_sig = bench(f_sig, path, repeats=repeats)
        lines.append(row(f"{tag}_signature", t_sig, ratio))

        for mode in ("lyndon", "brackets", "expand"):
            f_ls = jax.jit(lambda p, m=mode: logsignature(
                p, N, mode=m, backend="reference"))
            t_ls = bench(f_ls, path, repeats=repeats)
            lines.append(row(f"{tag}_logsig_{mode}", t_ls,
                             f"epilogue_x{t_ls / max(t_sig, 1e-12):.2f}"))

        f_grad = jax.jit(jax.grad(
            lambda p: logsignature(p, N, backend="reference").sum()))
        lines.append(row(f"{tag}_logsig_grad",
                         bench(f_grad, path, repeats=repeats)))
    return lines
