"""Paper Table 3 CSV wrapper — the workload lives in ``repro.bench``.

Horner + log/Lyndon epilogue vs plain signatures: per-mode epilogue cost
and the achieved compression ratio.  Cells and timing methodology:
:func:`repro.bench.workloads.table3_logsignatures`.
"""

from __future__ import annotations

from repro.bench import workloads

from .common import entry_row


def run(quick: bool = True, repeats: int = 5):
    entries = workloads.table3_logsignatures(
        mode="quick" if quick else "full", repeats=repeats)
    return [entry_row(e) for e in entries]
