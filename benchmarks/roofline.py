"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute  T_c = FLOPs_per_device / 197e12        [bf16 MXU peak]
    memory   T_m = HBM_bytes_per_device / 819e9
    network  T_n = collective_bytes_per_device / 50e9 [per-link ICI]

FLOPs: the trip-count-corrected HLO dot count from the dry-run
(``hlo_dot_flops`` — XLA's cost_analysis undercounts while-loop bodies, see
launch/hlo_analysis.py).  On the CPU dry-run backend XLA promotes bf16 dots to
f32 but the dot *shapes* (hence FLOPs) are unchanged.

HBM bytes: analytic per-device estimate (documented lower bound):
  train:   3 gathers of bf16 weights per microbatch (fwd + 2 remat/bwd reads)
           + 20 B/param optimizer update on the local shard
           + ~6 residual-sized activation tensors per layer per microbatch
  prefill: 1 weight gather + activations
  decode:  bf16 weights + full KV/state cache read + write per token

MODEL_FLOPS: 6·N_active·T for train (2·N for fwd-only) + exact attention
terms; the MODEL/HLO ratio flags remat/redundancy waste (full remat ⇒ ~0.75
on train cells).

Collectives: per-device ring-traffic estimates parsed from the partitioned
HLO (launch/hlo_analysis.py ring formulas).
"""

from __future__ import annotations

import json
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
GB = 1 << 30


def count_params(cfg) -> Dict[str, float]:
    from repro.models import build_model
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = 0
    matmul = 0
    embed = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        total += n
        name = str(path[-1])
        if "embed" in str(path) and "table" in name:
            embed += n
        elif leaf.ndim >= 2:
            matmul += n
    active = matmul
    if cfg.n_experts:
        expert = 3 * cfg.d_model * cfg.moe_d_ff
        active = matmul - (cfg.n_experts - cfg.n_experts_per_tok) * \
            expert * cfg.n_layers
    return {"total": total, "matmul": matmul, "active": active,
            "embed": embed}


def attention_flops_fwd(cfg, B, S) -> float:
    d_attn = cfg.n_heads * cfg.hd if cfg.n_heads else 0
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        Q, N = cfg.ssm_chunk, cfg.ssm_state
        per_layer = 2 * B * S * (Q * N + Q * d_inner + 2 * N * d_inner)
        return per_layer * cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        W = min(cfg.attn_window or S, S)
        return 4 * B * S * W * d_attn * n_attn
    if cfg.family == "encdec":
        F = cfg.n_audio_frames
        enc = 4 * B * F * F * d_attn * cfg.n_enc_layers
        dec = (4 * B * S * S + 4 * B * S * F) * d_attn * cfg.n_layers
        return enc + dec
    return 4 * B * S * S * d_attn * cfg.n_layers


def model_flops(cfg, shape, counts) -> float:
    """Useful MODEL_FLOPS (6N·T train / 2N·T fwd + attention)."""
    B, S = shape.batch, shape.seq
    T = B * S
    if shape.kind == "train":
        return 6 * counts["active"] * T + 3 * attention_flops_fwd(cfg, B, S)
    if shape.kind == "prefill":
        return 2 * counts["active"] * T + attention_flops_fwd(cfg, B, S)
    # decode: one token, full context
    per_tok = 2 * counts["active"]
    d_attn = cfg.n_heads * cfg.hd if cfg.n_heads else 0
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        attn = 4 * cfg.ssm_state * d_inner * cfg.n_layers
    elif cfg.family == "hybrid":
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        attn = 4 * min(cfg.attn_window, S) * d_attn * n_attn
    elif cfg.family == "encdec":
        attn = (4 * S + 4 * cfg.n_audio_frames) * d_attn * cfg.n_layers
    else:
        attn = 4 * S * d_attn * cfg.n_layers
    return B * (per_tok + attn)


def cache_bytes(cfg, shape) -> float:
    """Global decode-cache bytes (bf16)."""
    B, S = shape.batch, shape.seq
    if cfg.family == "ssm":
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        st = B * H * cfg.ssm_state * cfg.ssm_head_dim * 4
        return (st + B * (cfg.ssm_conv - 1) * (d_inner + 2 * cfg.ssm_state) * 4) \
            * cfg.n_layers
    per_layer_kv = 2 * B * cfg.n_kv_heads * cfg.hd * 2
    if cfg.family == "hybrid":
        total = 0
        for i in range(cfg.n_layers):
            kind = cfg.block_pattern[i % len(cfg.block_pattern)]
            if kind == "attn":
                total += per_layer_kv * min(cfg.attn_window, S)
            else:
                total += B * cfg.lru_width * 4 * 4
        return total
    if cfg.family == "encdec":
        return (per_layer_kv * S + per_layer_kv * cfg.n_audio_frames) \
            * cfg.n_layers
    return per_layer_kv * S * cfg.n_layers


def hbm_bytes(cfg, shape, counts, n_chips, n_mb, tp=16) -> float:
    """Per-device HBM traffic estimate (see module docstring)."""
    B, S = shape.batch, shape.seq
    P_bf16 = counts["matmul"] * 2
    if shape.kind == "train":
        weights = 3 * n_mb * P_bf16 / tp
        optim = 20 * counts["total"] / n_chips
        tokens_loc = B * S / n_chips
        acts = 6 * 2 * tokens_loc * cfg.d_model * max(cfg.n_layers, 1) * n_mb / max(n_mb, 1)
        return weights + optim + acts
    if shape.kind == "prefill":
        tokens_loc = B * S / n_chips
        return P_bf16 / tp + 6 * 2 * tokens_loc * cfg.d_model * max(cfg.n_layers, 1)
    return P_bf16 / tp + 2 * cache_bytes(cfg, shape) / n_chips


def sig_model_flops(shape, n_chips) -> float:
    """Analytic FLOPs for the sig-kernel workload cells: one Δ matmul per
    pair (2·L²·d, the MXU part) + ~10 VPU flops per refined PDE cell; the
    gradient cell pays ~3x (forward + adjoint + dΔ accumulation)."""
    B, L, d = shape.batch, shape.seq, 8
    pairs = float(B) * B
    per_pair = 2 * L * L * d + 10 * L * L
    mult = 3.0 if shape.kind == "sig_train" else 1.0
    return pairs * per_pair * mult


def analyze_results(path: str = "dryrun_results.json"):
    from repro.models import get_config
    from repro.launch.shapes import SHAPES, SIG_SHAPES
    with open(path) as f:
        results = json.load(f)
    rows = []
    for r in results:
        if "skipped" in r or "error" in r:
            rows.append(r)
            continue
        if r["arch"] == "sigkernel-workload":
            shape = SIG_SHAPES[r["shape"]]
            n_chips = r["n_chips"]
            mf = sig_model_flops(shape, n_chips)
            # dot flops (Δ matmuls) from HLO; PDE VPU flops analytic
            pde = mf - 2 * shape.batch ** 2 * shape.seq ** 2 * 8 * \
                (3.0 if shape.kind == "sig_train" else 1.0)
            t_c = (r["hlo_dot_flops"] + pde / n_chips) / PEAK_FLOPS
            delta_bytes = shape.batch ** 2 * shape.seq ** 2 * 4 / n_chips
            t_m = 3 * delta_bytes / HBM_BW      # write Δ + stream it in fwd/solve
            traffic = sum(c["traffic"] for c in r["collectives"].values())
            t_n = traffic / ICI_BW
            bound = max(t_c, t_m, t_n)
            rows.append(dict(
                r, model_flops=mf, t_compute=t_c, t_memory=t_m, t_network=t_n,
                bottleneck=max((t_c, "compute"), (t_m, "memory"),
                               (t_n, "collective"))[1],
                roofline_fraction=(t_c / bound if bound else 0.0),
                model_over_hlo=1.0, params_total=0, params_active=0))
            continue
        cfg = get_config(r["arch"])
        shape = SHAPES[r["shape"]]
        n_chips = r["n_chips"]
        counts = count_params(cfg)
        n_mb = r.get("num_microbatches", 1)
        mf = model_flops(cfg, shape, counts)
        hlo_f = r["hlo_dot_flops"]               # per-device (SPMD module)
        t_c = hlo_f / PEAK_FLOPS
        t_m = hbm_bytes(cfg, shape, counts, n_chips, n_mb) / HBM_BW
        traffic = sum(c["traffic"] for c in r["collectives"].values())
        t_n = traffic / ICI_BW
        dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
        bound = max(t_c, t_m, t_n)
        rows.append(dict(
            r, model_flops=mf, t_compute=t_c, t_memory=t_m, t_network=t_n,
            bottleneck=dom,
            roofline_fraction=(t_c / bound if bound else 0.0),
            model_over_hlo=(mf / (hlo_f * n_chips) if hlo_f else 0.0),
            params_total=counts["total"], params_active=counts["active"],
        ))
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | Tc (ms) | Tm (ms) | Tn (ms) | bound | "
           "roofline frac | model/HLO flops | peak GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | SKIP | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | ERROR | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} "
            f"| {r['t_network']*1e3:.2f} | {r['bottleneck']} "
            f"| {r['roofline_fraction']:.2f} | {r['model_over_hlo']:.2f} "
            f"| {r['peak_bytes_per_device']/GB:.1f} |")
    return "\n".join(lines)


def main():
    import sys
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    rows = analyze_results(path)
    print(markdown_table(rows))
    out = path.replace(".json", "_roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=float)
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
