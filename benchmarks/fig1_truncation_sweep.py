"""Paper Figure 1: signature runtime vs truncation level (B=32, L=1024, d=5)."""

from __future__ import annotations

import jax

from repro.core.signature import signature, signature_direct
from .common import bench, row


def run(quick: bool = True, repeats: int = 3):
    B, L, d = (8, 128, 5) if quick else (32, 1024, 5)
    path = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.2
    lines = []
    for N in range(2, 8):
        f_h = jax.jit(lambda p, N=N: signature(p, N))
        f_d = jax.jit(lambda p, N=N: signature_direct(p, N))
        g_h = jax.jit(jax.grad(lambda p, N=N: signature(p, N).sum()))
        t_h = bench(f_h, path, repeats=repeats)
        t_d = bench(f_d, path, repeats=repeats)
        t_g = bench(g_h, path, repeats=repeats)
        lines.append(row(f"fig1_N{N}_fwd_horner", t_h,
                         f"direct/horner={t_d / t_h:.2f}"))
        lines.append(row(f"fig1_N{N}_bwd", t_g))
    return lines
