"""Paper Figure 1 CSV wrapper — the workload lives in ``repro.bench``.

Signature runtime vs truncation level:
:func:`repro.bench.workloads.fig1_truncation_sweep`.
"""

from __future__ import annotations

from repro.bench import workloads

from .common import entry_row


def run(quick: bool = True, repeats: int = 3):
    entries = workloads.fig1_truncation_sweep(
        mode="quick" if quick else "full", repeats=repeats)
    return [entry_row(e) for e in entries]
