"""Paper Figure 2: signature-kernel runtime vs stream length (B=32, d=5)."""

from __future__ import annotations

import jax

from repro.core.sigkernel import (sigkernel, delta_matrix, solve_goursat,
                                  solve_goursat_antidiag)
from .common import bench, row


def run(quick: bool = True, repeats: int = 3):
    B, d = (8, 5) if quick else (32, 5)
    lengths = [32, 64, 128, 256] if quick else [128, 256, 512, 1024, 2048]
    lines = []
    for L in lengths:
        kx = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.1
        ky = jax.random.normal(jax.random.PRNGKey(1), (B, L, d)) * 0.1
        f_wave = jax.jit(
            lambda x, y: solve_goursat_antidiag(delta_matrix(x, y)))
        g_exact = jax.jit(jax.grad(lambda x, y: sigkernel(x, y).sum()))
        t_f = bench(f_wave, kx, ky, repeats=repeats)
        t_g = bench(g_exact, kx, ky, repeats=repeats)
        lines.append(row(f"fig2_L{L}_fwd", t_f, f"per_pair_us={t_f/B*1e6:.1f}"))
        lines.append(row(f"fig2_L{L}_bwd_exact", t_g))
    return lines
