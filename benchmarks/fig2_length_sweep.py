"""Paper Figure 2 CSV wrapper — the workload lives in ``repro.bench``.

Signature-kernel runtime vs stream length:
:func:`repro.bench.workloads.fig2_length_sweep`.
"""

from __future__ import annotations

from repro.bench import workloads

from .common import entry_row


def run(quick: bool = True, repeats: int = 3):
    entries = workloads.fig2_length_sweep(
        mode="quick" if quick else "full", repeats=repeats)
    return [entry_row(e) for e in entries]
