"""Benchmark utilities: min-over-repeats timing (paper §5 methodology)."""

from __future__ import annotations

import time

import jax


def bench(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Minimum wall time (seconds) over ``repeats`` runs, after jit warmup.

    The paper takes the minimum over 50 runs; on CPU we default to 5 to keep
    the suite fast — pass repeats=50 for paper-exact methodology.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
