"""Benchmark utilities — thin compatibility shims over ``repro.bench``.

The real timing implementation (paper §5 min-over-repeats methodology plus
a machine fingerprint) lives in :mod:`repro.bench.timer`; this module only
keeps the historical ``bench``/``row`` names for the legacy CSV wrappers.
"""

from __future__ import annotations

from repro.bench.timer import bench  # noqa: F401  (re-export)


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


def entry_row(entry: dict) -> str:
    """One ``name,us_per_call,derived`` CSV line from a suite entry dict."""
    seconds = entry.get("seconds") or 0.0
    return row(entry["name"], seconds, entry.get("derived", ""))
