"""Paper Table 2 CSV wrapper — the workload lives in ``repro.bench``.

Row-scan Goursat baseline vs the vectorised anti-diagonal wavefront
(forward), autodiff-through-the-solver vs pySigLib's exact one-pass
backward (Alg 4), plus the Gram engine through every usable backend:
:func:`repro.bench.workloads.table2_sigkernels`.

``--smoke`` pushes tiny shapes through EVERY registered backend (forward +
grad + the symmetric pair-solve budget) and asserts agreement — the CI
``bench-smoke`` job runs it on every push
(:func:`repro.bench.workloads.smoke_checks`).
"""

from __future__ import annotations

from repro.bench import workloads

from .common import entry_row


def run(quick: bool = True, repeats: int = 5):
    entries = workloads.table2_sigkernels(
        mode="quick" if quick else "full", repeats=repeats)
    return [entry_row(e) for e in entries]


def run_gram(quick: bool = True, repeats: int = 5, backends=None):
    entries = workloads.gram_backends(
        mode="quick" if quick else "full", repeats=repeats,
        backends=backends)
    return [entry_row(e) for e in entries]


def run_smoke(repeats: int = 1):
    entries = workloads.smoke_checks(repeats=repeats)
    entries += workloads.gram_backends(mode="smoke", repeats=max(repeats, 1))
    return [entry_row(e) for e in entries]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes through every backend; assert agreement")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    lines = (run_smoke(repeats=args.repeats) if args.smoke
             else run(quick=not args.full, repeats=args.repeats))
    for line in lines:
        print(line, flush=True)
