"""Paper Table 2: signature-kernel forward/backward runtimes.

Forward: row-scan Goursat solver (serial baseline, sigkernel-package-style)
vs the vectorised anti-diagonal wavefront (pySigLib's parallel scheme — SIMD
on CPU, the Pallas kernel on TPU).

Backward: autodiff-through-the-solver (baseline) vs pySigLib's exact one-pass
backward (Alg 4) wired through custom_vjp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sigkernel import (sigkernel, delta_matrix, solve_goursat,
                                  solve_goursat_antidiag)
from .common import bench, row

PAPER_CELLS = [(128, 256, 8), (128, 512, 16), (128, 1024, 32)]
QUICK_CELLS = [(16, 64, 8), (16, 128, 16), (8, 256, 32)]


def run(quick: bool = True, repeats: int = 5):
    cells = QUICK_CELLS if quick else PAPER_CELLS
    lines = []
    for (B, L, d) in cells:
        kx = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.1
        ky = jax.random.normal(jax.random.PRNGKey(1), (B, L, d)) * 0.1
        tag = f"table2_B{B}_L{L}_d{d}"

        f_scan = jax.jit(lambda x, y: solve_goursat(delta_matrix(x, y)))
        f_wave = jax.jit(lambda x, y: solve_goursat_antidiag(delta_matrix(x, y)))
        t_scan = bench(f_scan, kx, ky, repeats=repeats)
        t_wave = bench(f_wave, kx, ky, repeats=repeats)
        lines.append(row(f"{tag}_fwd_rowscan", t_scan))
        lines.append(row(f"{tag}_fwd_wavefront", t_wave,
                         f"speedup_vs_rowscan={t_scan / t_wave:.2f}x"))

        g_auto = jax.jit(jax.grad(
            lambda x, y: solve_goursat(delta_matrix(x, y)).sum()))
        g_exact = jax.jit(jax.grad(
            lambda x, y: sigkernel(x, y).sum()))
        t_ga = bench(g_auto, kx, ky, repeats=repeats)
        t_ge = bench(g_exact, kx, ky, repeats=repeats)
        lines.append(row(f"{tag}_bwd_autodiff", t_ga))
        lines.append(row(f"{tag}_bwd_exact_alg4", t_ge,
                         f"speedup_vs_autodiff={t_ga / t_ge:.2f}x"))
    return lines
