"""Paper Table 2: signature-kernel forward/backward runtimes.

Forward: row-scan Goursat solver (serial baseline, sigkernel-package-style)
vs the vectorised anti-diagonal wavefront (pySigLib's parallel scheme — SIMD
on CPU, the Pallas kernel on TPU).

Backward: autodiff-through-the-solver (baseline) vs pySigLib's exact one-pass
backward (Alg 4) wired through custom_vjp.

Gram section (beyond-paper): the unified engine of ``repro.core.gram``
through every registered backend — dense, fused-Δ, and the symmetric
upper-triangle fast path.  ``--smoke`` runs tiny shapes through every
backend (forward + grad) so dispatch regressions fail fast in CI.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.gram import sigkernel_gram
from repro.core.sigkernel import (sigkernel, delta_matrix, solve_goursat,
                                  solve_goursat_antidiag)
from .common import bench, row

PAPER_CELLS = [(128, 256, 8), (128, 512, 16), (128, 1024, 32)]
QUICK_CELLS = [(16, 64, 8), (16, 128, 16), (8, 256, 32)]
GRAM_CELLS_QUICK = [(8, 32, 4)]
GRAM_CELLS_PAPER = [(32, 128, 8)]


def run(quick: bool = True, repeats: int = 5):
    cells = QUICK_CELLS if quick else PAPER_CELLS
    lines = []
    for (B, L, d) in cells:
        kx = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.1
        ky = jax.random.normal(jax.random.PRNGKey(1), (B, L, d)) * 0.1
        tag = f"table2_B{B}_L{L}_d{d}"

        f_scan = jax.jit(lambda x, y: solve_goursat(delta_matrix(x, y)))
        f_wave = jax.jit(lambda x, y: solve_goursat_antidiag(delta_matrix(x, y)))
        t_scan = bench(f_scan, kx, ky, repeats=repeats)
        t_wave = bench(f_wave, kx, ky, repeats=repeats)
        lines.append(row(f"{tag}_fwd_rowscan", t_scan))
        lines.append(row(f"{tag}_fwd_wavefront", t_wave,
                         f"speedup_vs_rowscan={t_scan / t_wave:.2f}x"))

        g_auto = jax.jit(jax.grad(
            lambda x, y: solve_goursat(delta_matrix(x, y)).sum()))
        g_exact = jax.jit(jax.grad(
            lambda x, y: sigkernel(x, y).sum()))
        t_ga = bench(g_auto, kx, ky, repeats=repeats)
        t_ge = bench(g_exact, kx, ky, repeats=repeats)
        lines.append(row(f"{tag}_bwd_autodiff", t_ga))
        lines.append(row(f"{tag}_bwd_exact_alg4", t_ge,
                         f"speedup_vs_autodiff={t_ga / t_ge:.2f}x"))

    lines.extend(run_gram(quick=quick, repeats=repeats))
    return lines


def run_gram(quick: bool = True, repeats: int = 5,
             backends=None):
    """Gram engine rows: every backend × {dense, symmetric} (+ fused)."""
    cells = GRAM_CELLS_QUICK if quick else GRAM_CELLS_PAPER
    if backends is None:
        backends = dispatch.backends_for("gram")
        if not dispatch.on_tpu():
            # interpret-mode Pallas timings measure nothing meaningful and
            # dominate CPU wall-clock; --smoke covers those for correctness
            backends = [b for b in backends if not dispatch.get(b).needs_tpu]
    # reference first so the other rows can report their speedup against it
    backends = (["reference"] if "reference" in backends else []) + \
        [b for b in backends if b != "reference"]
    lines = []
    for (B, L, d) in cells:
        X = jax.random.normal(jax.random.PRNGKey(2), (B, L, d)) * 0.1
        Y = jax.random.normal(jax.random.PRNGKey(3), (B, L, d)) * 0.1
        tag = f"table2_gram_B{B}_L{L}_d{d}"
        t_ref = None
        for b in backends:
            f = jax.jit(lambda x, y, b=b: sigkernel_gram(x, y, backend=b))
            t = bench(f, X, Y, repeats=repeats)
            extra = "" if t_ref is None else f"speedup_vs_reference={t_ref / t:.2f}x"
            if b == "reference":
                t_ref = t
            lines.append(row(f"{tag}_dense_{b}", t, extra))
        # symmetric fast path: ~half the PDE solves of the dense Kxx
        for b in backends:
            f_sym = jax.jit(lambda x, b=b: sigkernel_gram(x, backend=b))
            t_sym = bench(f_sym, X, repeats=repeats)
            lines.append(row(f"{tag}_symmetric_{b}", t_sym))
    return lines


def run_smoke(repeats: int = 1):
    """Tiny shapes through EVERY backend, forward and grad — the CI smoke
    job.  Any dispatch/registry regression fails here in seconds."""
    import numpy as np
    B, L, d = 3, 8, 2
    X = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.1
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, L, d)) * 0.1
    lines = []
    K_ref = sigkernel_gram(X, Y, backend="reference")
    for b in dispatch.backends_for("gram"):
        t = bench(lambda: sigkernel_gram(X, Y, backend=b), repeats=repeats,
                  warmup=1)
        K = sigkernel_gram(X, Y, backend=b)
        np.testing.assert_allclose(K, K_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"smoke: {b} disagrees")
        g = jax.grad(lambda q: sigkernel_gram(q, Y, backend=b).sum())(X)
        assert np.isfinite(np.asarray(g)).all(), f"smoke: {b} grad not finite"
        lines.append(row(f"smoke_gram_{b}", t, "ok"))
    with dispatch.count_pair_solves() as c:
        sigkernel_gram(X, backend="pallas_fused")
    budget = B * (B + 1) // 2
    assert c.total <= budget, (c.total, budget)
    lines.append(row("smoke_symmetric_pair_solves", 0.0,
                     f"solves={c.total}<=budget={budget}"))
    for b in dispatch.backends_for("sigkernel"):
        k = sigkernel(X, Y, backend=b)
        np.testing.assert_allclose(
            k, sigkernel(X, Y, backend="reference"), rtol=5e-4, atol=1e-5,
            err_msg=f"smoke: sigkernel {b} disagrees")
        lines.append(row(f"smoke_sigkernel_{b}", 0.0, "ok"))
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes through every backend; assert agreement")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    lines = (run_smoke(repeats=args.repeats) if args.smoke
             else run(quick=not args.full, repeats=args.repeats))
    for line in lines:
        print(line, flush=True)
