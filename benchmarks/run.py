# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--full] [--repeats N]

--full uses the paper's exact (B, L, d, N) cells (slow on CPU); the default
quick mode scales them down but keeps the comparisons intact.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--repeats", type=int, default=3)
    args, _ = ap.parse_known_args()

    from . import (table1_signatures, table2_sigkernels,
                   table3_logsignatures, fig1_truncation_sweep,
                   fig2_length_sweep, grad_accuracy)

    print("name,us_per_call,derived")
    for mod in (table1_signatures, table2_sigkernels, table3_logsignatures,
                fig1_truncation_sweep, fig2_length_sweep, grad_accuracy):
        for line in mod.run(quick=not args.full, repeats=args.repeats):
            print(line, flush=True)


if __name__ == "__main__":
    main()
