"""DEPRECATED entry point — forwards to ``python -m repro.bench``.

The benchmark harness moved to :mod:`repro.bench` (persistent BENCH JSONs,
machine fingerprints, autotuning, a CI regression gate — see
docs/benchmarks.md).  This stub keeps old command lines working:

    PYTHONPATH=src python -m benchmarks.run [--full] [--repeats N]

now runs the suite and writes ``BENCH_quick.json`` / ``BENCH_full.json``
(``BENCH_PR10.json`` with ``--smoke``) exactly like ``python -m
repro.bench`` with the same flags.
"""

from __future__ import annotations

import sys
import warnings


def main() -> int:
    warnings.warn(
        "python -m benchmarks.run is deprecated; use python -m repro.bench "
        "(docs/benchmarks.md)", DeprecationWarning, stacklevel=2)
    print("benchmarks.run is deprecated; forwarding to "
          "`python -m repro.bench` ...", file=sys.stderr)
    from repro.bench.__main__ import main as bench_main
    return bench_main()


if __name__ == "__main__":
    sys.exit(main())
