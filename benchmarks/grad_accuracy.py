"""pySigLib §3.4 headline claim: exact gradients vs the second-PDE
approximation of [30], as a function of path length and dyadic order.

The exact one-pass backward matches autodiff to float precision everywhere;
the PDE-approximation error is large for short paths / low dyadic order and
shrinks as the grid refines — exactly the failure mode the paper reports.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sigkernel import (delta_matrix, solve_goursat,
                                  solve_goursat_grad,
                                  solve_goursat_grad_pde_approx)
from .common import row


def run(quick: bool = True, repeats: int = 0):
    lines = []
    for L in ([4, 8, 16] if quick else [4, 8, 16, 32, 64]):
        for lam in ([0, 1] if quick else [0, 1, 2]):
            x = jax.random.normal(jax.random.PRNGKey(0), (4, L, 3)) * 0.3
            y = jax.random.normal(jax.random.PRNGKey(1), (4, L, 3)) * 0.3
            delta = delta_matrix(x, y)
            grid = solve_goursat(delta, lam, lam, return_grid=True)
            gbar = jnp.ones(delta.shape[:-2])
            d_true = jax.grad(lambda d: solve_goursat(d, lam, lam).sum())(delta)
            d_exact = solve_goursat_grad(delta, grid, gbar, lam, lam)
            d_approx = solve_goursat_grad_pde_approx(delta, grid, gbar, lam, lam)
            scale = float(jnp.abs(d_true).max())
            e_exact = float(jnp.abs(d_exact - d_true).max()) / scale
            e_approx = float(jnp.abs(d_approx - d_true).max()) / scale
            lines.append(row(
                f"gradacc_L{L}_lam{lam}", 0.0,
                f"rel_err_exact={e_exact:.2e};rel_err_pde_approx={e_approx:.2e}"))
    return lines
