"""§3.4 gradient-accuracy CSV wrapper — the workload lives in ``repro.bench``.

Exact one-pass backward vs the second-PDE approximation of [30], as a
function of path length and dyadic order:
:func:`repro.bench.workloads.grad_accuracy`.
"""

from __future__ import annotations

from repro.bench import workloads

from .common import row


def run(quick: bool = True, repeats: int = 0):
    entries = workloads.grad_accuracy(
        mode="quick" if quick else "full", repeats=repeats)
    return [row(e["name"], 0.0, e["derived"]) for e in entries]
