"""repro — fast signature-based computations in JAX (pySigLib reproduction).

The blessed public surface (API v1, see docs/api/public.md):

* **Config objects** — :class:`TransformPipeline`, :class:`GridConfig`,
  :class:`LaunchConfig` (kernel launch parameters: tile/strip/block sizes;
  bitwise-neutral), :class:`FeatureConfig` (approximate sig-kernel feature
  maps: ``rff`` / ``nystroem``), and the static-kernel lifts
  :class:`Linear` / :class:`RBF` (:class:`StaticKernel` base).  All frozen
  pytree dataclasses.
* **Class entry points** — :class:`Signature`, :class:`LogSignature`,
  :class:`SigKernel` close over a config and are jit/vmap-friendly.
* **Functional API** — :func:`signature`, :func:`logsignature`,
  :func:`sigkernel`, :func:`sigkernel_gram`, :func:`mmd2`,
  :func:`scoring_rule` for one-off calls; ``repro.core`` holds the full
  implementation surface.
* **Streaming** — :class:`Path` (per-prefix signature store: O(1)
  interval queries, incremental ``update()``) with :class:`RollingConfig`;
  ``repro.stream`` holds the engine and ``repro.serve`` the
  admission-batched feature server built on it.
"""

from .api import LogSignature, SigKernel, Signature
from .core.config import (GridConfig, LaunchConfig, Linear, RBF,
                          StaticKernel, TransformPipeline)
from .core.features import FeatureConfig
from .core.gram import (sigkernel_gram, sigkernel_gram_reduce,
                        sigkernel_gram_sharded)
from .core.logsignature import logsignature
from .core.losses import mmd2, scoring_rule
from .core.signature import signature
from .core.sigkernel import sigkernel
from .core.transforms import bucket_length, pad_ragged
from .stream import Path, RollingConfig
from . import core
from . import stream

__version__ = "0.2.0"

__all__ = [
    # config objects
    "TransformPipeline", "GridConfig", "LaunchConfig", "FeatureConfig",
    "StaticKernel", "Linear", "RBF",
    # class entry points
    "Signature", "LogSignature", "SigKernel",
    # functional API
    "signature", "logsignature", "sigkernel", "sigkernel_gram",
    "sigkernel_gram_reduce", "sigkernel_gram_sharded",
    "mmd2", "scoring_rule",
    # streaming engine (docs/api/public.md, "Streaming paths & serving")
    "Path", "RollingConfig",
    # ragged-batch helpers (pre-jit canonicalisation; docs/api/public.md)
    "pad_ragged", "bucket_length",
    # namespaces
    "core", "stream",
    "__version__",
]
