"""repro — multi-pod JAX framework reproducing pySigLib (signatures + signature kernels)."""

__version__ = "0.1.0"
