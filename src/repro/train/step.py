"""Train-step factory: mixed precision, microbatch gradient accumulation.

Master params fp32 (FSDP-sharded); a bf16 compute copy is cast once per step
so FSDP all-gathers move bf16 (half the bytes).  Gradients accumulate in fp32
across microbatches via lax.scan; AdamW updates the sharded master copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.parallel.api import shard


def cast_compute(params, dtype):
    def one(p):
        if jnp.issubdtype(p.dtype, jnp.floating) and p.ndim >= 1:
            return p.astype(dtype)
        return p
    return jax.tree.map(one, params)


def apply_param_dtype(tree, cfg):
    """Master-parameter dtype policy (cfg.param_dtype; bf16 for 340B-class).

    Works on arrays and ShapeDtypeStructs alike."""
    target = jnp.dtype(cfg.param_dtype)

    def one(p):
        if jnp.issubdtype(p.dtype, jnp.floating) and p.dtype != target:
            if hasattr(p, "astype"):
                return p.astype(target)
            return jax.ShapeDtypeStruct(p.shape, target)
        return p

    return jax.tree.map(one, tree)


def make_train_step(model, optimizer, *, num_microbatches: int = 1,
                    param_pspecs=None, accum_dtype: str = "float32"):
    """param_pspecs: optional tree of PartitionSpec matching params — applied
    to gradients/accumulators so FSDP gradients reduce-scatter into shards
    instead of being all-reduced into replicated buffers.
    accum_dtype: gradient-accumulator dtype; bf16 halves both the accumulator
    memory and the per-microbatch reduce-scatter bytes (340B-class default)."""
    cfg = model.cfg
    cdt = jnp.dtype(cfg.compute_dtype)
    adt = jnp.dtype(accum_dtype)

    def constrain(tree):
        if param_pspecs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            param_pspecs)

    def loss_fn(compute_params, mb):
        loss, metrics = model.loss(compute_params, mb)
        return loss.astype(jnp.float32), metrics

    def train_step(params, opt_state, batch):
        compute = cast_compute(params, cdt)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if num_microbatches > 1:
            def split(x):
                n = num_microbatches
                if getattr(x, "ndim", 0) == 0:   # scalars (e.g. max_len)
                    return jnp.broadcast_to(jnp.asarray(x), (n,))
                return x.reshape(n, x.shape[0] // n, *x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def body(acc, mb):
                g_acc, loss_acc = acc
                (loss, metrics), grads = grad_fn(compute, mb)
                grads = constrain(grads)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(adt), g_acc, grads)
                g_acc = constrain(g_acc)
                return (g_acc, loss_acc + loss), metrics

            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), compute))
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss_sum / num_microbatches
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (loss, metrics), grads = grad_fn(compute, batch)
            grads = constrain(jax.tree.map(
                lambda g: g.astype(jnp.float32), grads))

        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(model):
    cdt = jnp.dtype(model.cfg.compute_dtype)

    def eval_step(params, batch):
        loss, metrics = model.loss(cast_compute(params, cdt), batch)
        return dict(metrics, loss=loss)

    return eval_step
