"""GQA attention: blocked (flash-style) training path + cached decode paths.

The training/prefill path streams over KV chunks with an online softmax so the
(S × S) score matrix is never materialised — required for the 32k-prefill
shapes to fit HBM.  Decode supports a full preallocated KV cache and a
sliding-window ring cache (RecurrentGemma local attention; enables the
long_500k decode shape with O(window) memory).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from .layers import apply_rope, dense_init

NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
              qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim).reshape(
            d_model, n_heads, head_dim),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim).reshape(
            d_model, n_kv, head_dim),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim).reshape(
            d_model, n_kv, head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model).reshape(
            n_heads, head_dim, d_model),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), jnp.float32)
        p["bk"] = jnp.zeros((n_kv, head_dim), jnp.float32)
        p["bv"] = jnp.zeros((n_kv, head_dim), jnp.float32)
    return p


def qkv(p, x, positions, rope_theta: Optional[float]):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if rope_theta is not None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_pos: jax.Array, kv_pos: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      chunk: int = 1024) -> jax.Array:
    """Streaming-softmax attention.

    q: (B, S, H, D); k, v: (B, Skv, KV, D); GQA via head grouping.
    q_pos: (S,), kv_pos: (Skv,).  window > 0 limits to local attention.
    Never materialises more than (B, S, H, chunk) of scores.
    """
    B, S, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=-10 ** 9)
    n_chunks = (Skv + pad) // chunk

    # K/V stay in model dtype (bf16); each chunk is sliced and the matmuls
    # accumulate in f32 via preferred_element_type — the full-sequence K/V
    # are never materialised in f32.  Their SEQ dim must be unsharded here:
    # SP leaves the residual stream seq-sharded, and per-chunk dynamic slices
    # from a seq-sharded tensor make XLA all-gather it EVERY chunk iteration
    # (32x per layer) instead of once.
    k = shard(k, "batch", None, "model", None)
    v = shard(v, "batch", None, "model", None)
    qs = (q / math.sqrt(D)).astype(q.dtype).reshape(B, S, KV, G, D)

    def step(carry, j):
        m, lsum, acc = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, axis=1)
        pj = jax.lax.dynamic_slice_in_dim(kv_pos, j * chunk, chunk, axis=0)
        s = jnp.einsum("bskgd,bckd->bskgc", qs, kj,
                       preferred_element_type=jnp.float32)
        mask = jnp.ones((S, chunk), bool)
        if causal:
            mask &= pj[None, :] <= q_pos[:, None]
        if window:
            mask &= pj[None, :] > q_pos[:, None] - window
        mask &= pj[None, :] >= 0
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l_new = lsum * corr + p_.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p_.astype(v.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, G), jnp.float32)
    a0 = jnp.zeros((B, S, KV, G, D), jnp.float32)
    (m, lsum, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                     jnp.arange(n_chunks))
    out = acc / jnp.maximum(lsum[..., None], 1e-30)
    return out.reshape(B, S, H, D).astype(q.dtype)


def attention(p, x, positions, *, rope_theta, causal=True, window=0,
              kv_x: Optional[jax.Array] = None, kv_positions=None,
              chunk: int = 1024):
    """Self or cross attention over full sequences (train / prefill)."""
    dt = x.dtype
    if kv_x is None:
        q, k, v = qkv(p, x, positions, rope_theta)
        kv_pos = positions
    else:  # cross attention: KV from encoder states, no rope on cross
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
        kv_pos = kv_positions
        causal = False
    q = shard(q, "batch", "seq", "model", None)
    k = shard(k, "batch", "seq", "model", None)
    out = blocked_attention(q, k, v, positions, kv_pos,
                            causal=causal, window=window, chunk=chunk)
    out = shard(out, "batch", "seq", "model", None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# decode with caches
# ---------------------------------------------------------------------------

def cache_init(batch: int, max_len: int, n_kv: int, head_dim: int,
               dtype=jnp.bfloat16) -> Dict:
    """Full preallocated KV cache (positions implicit = slot index)."""
    return {
        "k": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, head_dim), dtype),
    }


def window_cache_init(batch: int, window: int, n_kv: int, head_dim: int,
                      dtype=jnp.bfloat16) -> Dict:
    """Sliding-window ring cache: O(window) memory at any context length."""
    return {
        "k": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, window, n_kv, head_dim), dtype),
        "pos": jnp.full((window,), -10 ** 9, jnp.int32),
    }


def decode_attention(p, x, cache: Dict, cur_len: jax.Array, *,
                     rope_theta, window: int = 0):
    """One-token attention against a cache.  x: (B, 1, d_model).

    Returns (out (B, 1, d_model), updated cache).  For window > 0 the cache is
    a ring buffer indexed cur_len % window.
    """
    dt = x.dtype
    q, k, v = qkv(p, x, jnp.reshape(cur_len, (1,)), rope_theta)
    if window:
        slot = (cur_len % window).astype(jnp.int32)
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        cache["pos"] = jax.lax.dynamic_update_slice(
            cache["pos"], jnp.reshape(cur_len, (1,)).astype(jnp.int32), (slot,))
        kv_pos = cache["pos"]
        valid = (kv_pos >= 0) & (kv_pos <= cur_len) & (kv_pos > cur_len - window)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cur_len, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cur_len, 0, 0))
        kv_pos = jnp.arange(cache["k"].shape[1])
        valid = kv_pos <= cur_len

    B, _, H, D = q.shape
    KV = cache["k"].shape[2]
    G = H // KV
    # cache stays in its storage dtype; f32 accumulation via the matmul only
    qf = (q / math.sqrt(D)).astype(cache["k"].dtype).reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, cache["k"],
                   preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", w.astype(cache["v"].dtype), cache["v"],
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, H, D).astype(dt)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), cache
