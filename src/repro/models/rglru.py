"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Temporal mixing:  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
with a_t = exp(-c·softplus(Λ)·r_t); r, i input-dependent sigmoid gates.
Training/prefill uses an associative scan (parallel prefix, O(L log L));
decode is an O(1) recurrence, so the hybrid family supports long_500k.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from .layers import dense_init

_C = 8.0


def rglru_init(key, cfg):
    D, W = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    return {
        "w_x": dense_init(ks[0], D, W),
        "w_y": dense_init(ks[1], D, W),
        "conv_w": jax.random.normal(ks[2], (4, W), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((W,), jnp.float32),
        "w_a": dense_init(ks[3], W, W, scale=0.02),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[4], W, W, scale=0.02),
        "b_i": jnp.zeros((W,), jnp.float32),
        # Λ init so that a ≈ U[0.9, 0.999] at r = 1 (griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, W)) / _C)),
        "w_out": dense_init(ks[5], W, D),
    }


def _gates(p, x):
    r = jax.nn.sigmoid(x @ p["w_a"].astype(x.dtype) + p["b_a"].astype(x.dtype))
    i = jax.nn.sigmoid(x @ p["w_i"].astype(x.dtype) + p["b_i"].astype(x.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"]))[None] * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i.astype(jnp.float32) * x.astype(jnp.float32))
    return a, gated


def _conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K)) + b


def rglru_apply(p, u: jax.Array, cfg, return_cache: bool = False,
                chunk: int = 64):
    """Full-sequence recurrent block. u: (B, L, d_model).

    Temporal mixing uses a chunked scan (sequential over chunks of ``chunk``
    steps, masked log-decay weights within a chunk — every exponent is ≤ 0 so
    the weights are bounded by 1).  O(L·chunk) work with O(B·chunk²·W) peak
    memory for ONE chunk, instead of associative_scan's O(L log L) live
    intermediates — the difference between 36 GiB and ~7 GiB per device on
    the train_4k dry-run.
    """
    dt = u.dtype
    x = u @ p["w_x"].astype(dt)
    x_raw = x
    x = _conv(x, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    y = jax.nn.gelu(u @ p["w_y"].astype(dt))

    a, gated = _gates(p, x)                     # (B, L, W) f32
    log_a = jnp.log(jnp.maximum(a, 1e-37))

    B_, L, W = gated.shape
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:  # padded steps: a = 1 (log 0), b = 0 — exact no-ops
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        gated = jnp.pad(gated, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // Q
    la = log_a.reshape(B_, nc, Q, W)
    bv = gated.reshape(B_, nc, Q, W)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def chunk_step(hc, inp):
        laj, bj = inp                            # (B, Q, W)
        cum = jnp.cumsum(laj, axis=1)            # (B, Q, W), <= 0
        diff = cum[:, :, None, :] - cum[:, None, :, :]
        diff = jnp.where(causal[None, :, :, None], diff, -1e30)
        h_intra = jnp.einsum("btjw,bjw->btw", jnp.exp(diff), bj)
        h = h_intra + jnp.exp(cum) * hc[:, None, :]
        return h[:, -1, :], h

    h0 = jnp.zeros((B_, W), jnp.float32)
    swap = lambda t: jnp.moveaxis(t, 1, 0)
    # remat the chunk body: the (B, Q, Q, W) decay weights are recomputed in
    # the backward instead of being saved per chunk by the scan
    h_last, hs = jax.lax.scan(jax.checkpoint(chunk_step, prevent_cse=False),
                              h0, (swap(la), swap(bv)))
    h = jnp.moveaxis(hs, 0, 1).reshape(B_, nc * Q, W)[:, :L]
    h = shard(h.astype(dt), "batch", "seq", "model")
    out = (h * y) @ p["w_out"].astype(dt)
    if return_cache:
        tail = x_raw[:, max(0, x_raw.shape[1] - 3):, :].astype(jnp.float32)
        if tail.shape[1] < 3:
            tail = jnp.pad(tail, ((0, 0), (3 - tail.shape[1], 0), (0, 0)))
        cache = {"h": h_last, "conv": tail}   # padded steps are exact no-ops
        return out, cache
    return out


def rglru_cache_init(batch: int, cfg, dtype=jnp.float32) -> Dict:
    return {"h": jnp.zeros((batch, cfg.lru_width), dtype),
            "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype)}


def rglru_decode(p, u: jax.Array, cache: Dict, cfg) -> Tuple[jax.Array, Dict]:
    """One-token recurrence. u: (B, 1, d_model)."""
    dt = u.dtype
    x_new = (u[:, 0] @ p["w_x"].astype(dt))                      # (B, W)
    buf = jnp.concatenate([cache["conv"].astype(dt), x_new[:, None]], axis=1)
    w = p["conv_w"].astype(dt)
    x = jnp.einsum("bkc,kc->bc", buf, w) + p["conv_b"].astype(dt)
    y = jax.nn.gelu(u[:, 0] @ p["w_y"].astype(dt))
    a, gated = _gates(p, x)
    h = a * cache["h"] + gated
    out = ((h.astype(dt) * y) @ p["w_out"].astype(dt))[:, None]
    return out, {"h": h, "conv": buf[:, 1:].astype(cache["conv"].dtype)}
