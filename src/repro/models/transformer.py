"""Decoder-only LM assembly for dense / MoE / SSM / hybrid families.

Uniform stacks use scan-over-layers (stacked params, small HLO, fast 96-layer
compiles) with optional per-layer remat; heterogeneous stacks (RecurrentGemma's
(rec, rec, attn) pattern) are unrolled.  Prefill/decode thread per-layer caches
through the same scan.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from . import layers as L
from . import attention as A
from . import moe as MOE
from . import ssm as SSM
from . import rglru as RG


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def layer_kind(cfg, idx: int) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return cfg.block_pattern[idx % len(cfg.block_pattern)]
    if cfg.family == "moe":
        return "moe"
    return "dense"


def layer_init(key, cfg, kind: str):
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"ln": L.rmsnorm_init(cfg.d_model),
                "mixer": SSM.ssm_init(ks[0], cfg)}
    p = {"ln1": L.rmsnorm_init(cfg.d_model), "ln2": L.rmsnorm_init(cfg.d_model)}
    if kind == "rec":
        p["rec"] = RG.rglru_init(ks[0], cfg)
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    elif kind == "moe":
        p["attn"] = A.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, cfg.qkv_bias)
        p["moe"] = MOE.moe_init(ks[1], cfg)
    else:  # dense or local-attn hybrid layer
        p["attn"] = A.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, cfg.qkv_bias)
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)
    return p


def layer_apply(p, x, positions, cfg, kind: str) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence layer. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        x = x + SSM.ssm_apply(p["mixer"], L.rmsnorm(p["ln"], x, cfg.norm_eps), cfg)
        return x, aux
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "rec":
        x = x + RG.rglru_apply(p["rec"], h, cfg)
    else:
        window = cfg.attn_window if (kind == "attn" and cfg.attn_window) else 0
        x = x + A.attention(p["attn"], h, positions, rope_theta=cfg.rope_theta,
                            causal=True, window=window)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, aux = MOE.moe_apply(p["moe"], h, cfg)
        x = x + y
    else:
        x = x + L.mlp(p["mlp"], h, cfg.act)
    return x, aux


# ---------------------------------------------------------------------------
# decode-path per-layer
# ---------------------------------------------------------------------------

def layer_cache_init(cfg, kind: str, batch: int, max_len: int, dtype=jnp.bfloat16):
    if kind == "ssm":
        return SSM.ssm_cache_init(batch, cfg)
    if kind == "rec":
        return RG.rglru_cache_init(batch, cfg)
    if kind == "attn" and cfg.attn_window:
        return A.window_cache_init(batch, min(cfg.attn_window, max_len),
                                   cfg.n_kv_heads, cfg.hd, dtype)
    return A.cache_init(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)


def layer_decode(p, x, cache, cur_len, cfg, kind: str):
    if kind == "ssm":
        h, cache = SSM.ssm_decode(p["mixer"],
                                  L.rmsnorm(p["ln"], x, cfg.norm_eps), cache, cfg)
        return x + h, cache
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "rec":
        h, cache = RG.rglru_decode(p["rec"], h, cache, cfg)
    else:
        window = cfg.attn_window if (kind == "attn" and cfg.attn_window) else 0
        h, cache = A.decode_attention(p["attn"], h, cache, cur_len,
                                      rope_theta=cfg.rope_theta, window=window)
    x = x + h
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        y, _ = MOE.moe_apply(p["moe"], h, cfg)
        x = x + y
    else:
        x = x + L.mlp(p["mlp"], h, cfg.act)
    return x, cache


# ---------------------------------------------------------------------------
# full stacks
# ---------------------------------------------------------------------------

def _uniform(cfg) -> bool:
    return cfg.scan_layers and cfg.family in ("dense", "moe", "vlm", "ssm")


def _grouped(cfg) -> bool:
    """Hybrid stacks scan over pattern groups (e.g. (rec, rec, attn) × 8 for
    RecurrentGemma) so remat bounds memory the same way uniform scans do —
    unrolled per-layer jax.checkpoint does NOT free residuals across layers."""
    return (cfg.scan_layers and cfg.family == "hybrid"
            and cfg.n_layers >= 2 * len(cfg.block_pattern))


def _group_split(cfg):
    g = len(cfg.block_pattern)
    return cfg.n_layers // g, cfg.n_layers % g   # (n_groups, n_rest)


def stack_init(key, cfg):
    kinds = [layer_kind(cfg, i) for i in range(cfg.n_layers)]
    keys = jax.random.split(key, cfg.n_layers)
    if _uniform(cfg):
        stacked = jax.vmap(lambda k: layer_init(k, cfg, kinds[0]))(keys)
        return {"stacked": stacked}
    if _grouped(cfg):
        g = len(cfg.block_pattern)
        n_groups, n_rest = _group_split(cfg)
        params = {"groups": {}}
        for j, kind in enumerate(cfg.block_pattern):
            gkeys = jnp.stack([keys[i * g + j] for i in range(n_groups)])
            params["groups"][f"pos_{j}"] = jax.vmap(
                lambda k, kind=kind: layer_init(k, cfg, kind))(gkeys)
        for r in range(n_rest):
            i = n_groups * g + r
            params[f"layer_{i}"] = layer_init(keys[i], cfg, kinds[i])
        return params
    return {f"layer_{i}": layer_init(keys[i], cfg, kinds[i])
            for i in range(cfg.n_layers)}


def stack_apply(params, x, positions, cfg):
    """Run all layers over a full sequence. Returns (x, aux)."""
    if _uniform(cfg):
        kind = layer_kind(cfg, 0)
        body = functools.partial(layer_apply, positions=positions, cfg=cfg, kind=kind)
        fn = (lambda p, h: body(p, h))
        if cfg.remat:
            fn = jax.checkpoint(fn, prevent_cse=False)

        def scan_body(carry, lp):
            h, aux = carry
            h = shard(h, "batch", "residual", None)   # SP residual boundary
            h, a = fn(lp, h)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                   params["stacked"])
        return x, aux

    if _grouped(cfg):
        n_groups, n_rest = _group_split(cfg)

        def group_fn(gp, h):
            a_sum = jnp.zeros((), jnp.float32)
            for j, kind in enumerate(cfg.block_pattern):
                h, a = layer_apply(gp[f"pos_{j}"], h, positions, cfg, kind)
                a_sum = a_sum + a
            return h, a_sum

        fn = jax.checkpoint(group_fn, prevent_cse=False) if cfg.remat \
            else group_fn

        def scan_body(carry, gp):
            h, aux = carry
            h = shard(h, "batch", "residual", None)
            h, a = fn(gp, h)
            return (h, aux + a), None

        (x, aux), _ = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), params["groups"])
        for r in range(n_rest):
            i = n_groups * len(cfg.block_pattern) + r
            x, a = layer_apply(params[f"layer_{i}"], x, positions, cfg,
                               layer_kind(cfg, i))
            aux = aux + a
        return x, aux

    aux = jnp.zeros((), jnp.float32)
    for i in range(cfg.n_layers):
        kind = layer_kind(cfg, i)
        fn = functools.partial(layer_apply, positions=positions, cfg=cfg, kind=kind)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x = shard(x, "batch", "residual", None)
        x, a = fn(params[f"layer_{i}"], x)
        aux = aux + a
    return x, aux


def stack_cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if _uniform(cfg):
        one = layer_cache_init(cfg, layer_kind(cfg, 0), batch, max_len, dtype)
        return {"stacked": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)}
    if _grouped(cfg):
        n_groups, n_rest = _group_split(cfg)
        caches = {"groups": {}}
        for j, kind in enumerate(cfg.block_pattern):
            one = layer_cache_init(cfg, kind, batch, max_len, dtype)
            caches["groups"][f"pos_{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one)
        for r in range(n_rest):
            i = n_groups * len(cfg.block_pattern) + r
            caches[f"layer_{i}"] = layer_cache_init(
                cfg, layer_kind(cfg, i), batch, max_len, dtype)
        return caches
    return {f"layer_{i}": layer_cache_init(cfg, layer_kind(cfg, i), batch,
                                           max_len, dtype)
            for i in range(cfg.n_layers)}


def stack_decode(params, x, caches, cur_len, cfg):
    if _uniform(cfg):
        kind = layer_kind(cfg, 0)

        # caches ride in the scan CARRY with per-layer dynamic updates, so the
        # while-loop aliases the (donated) cache buffers in place — scanning
        # them as xs/ys would double-buffer the full multi-GB cache in temp.
        def body(carry, lp):
            h, cs, i = carry
            ck = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                cs)
            h, ck_new = layer_decode(lp, h, ck, cur_len, cfg, kind)
            cs = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cs, ck_new)
            return (h, cs, i + 1), None

        (x, new_cache, _), _ = jax.lax.scan(
            body, (x, caches["stacked"], jnp.asarray(0, jnp.int32)),
            params["stacked"])
        return x, {"stacked": new_cache}

    if _grouped(cfg):
        n_groups, n_rest = _group_split(cfg)

        def body(carry, gp):
            h, cs, i = carry
            new_cs = dict(cs)
            for j, kind in enumerate(cfg.block_pattern):
                ck = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0,
                                                           keepdims=False),
                    cs[f"pos_{j}"])
                h, ck_new = layer_decode(gp[f"pos_{j}"], h, ck, cur_len, cfg,
                                         kind)
                new_cs[f"pos_{j}"] = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n.astype(c.dtype), i, 0),
                    new_cs[f"pos_{j}"], ck_new)
            return (h, new_cs, i + 1), None

        (x, new_groups, _), _ = jax.lax.scan(
            body, (x, caches["groups"], jnp.asarray(0, jnp.int32)),
            params["groups"])
        new = {"groups": new_groups}
        for r in range(n_rest):
            i = n_groups * len(cfg.block_pattern) + r
            x, new[f"layer_{i}"] = layer_decode(
                params[f"layer_{i}"], x, caches[f"layer_{i}"], cur_len, cfg,
                layer_kind(cfg, i))
        return x, new

    new = {}
    for i in range(cfg.n_layers):
        kind = layer_kind(cfg, i)
        x, new[f"layer_{i}"] = layer_decode(params[f"layer_{i}"], x,
                                            caches[f"layer_{i}"], cur_len, cfg, kind)
    return x, new


def stack_prefill(params, x, positions, cfg, max_len: int, dtype=jnp.bfloat16):
    """Full-sequence forward that also builds decode caches."""
    B = x.shape[0]

    def one_layer_prefill(p, h, kind):
        # run the layer and extract its cache
        if kind == "ssm":
            y, cache = SSM.ssm_apply(p["mixer"],
                                     L.rmsnorm(p["ln"], h, cfg.norm_eps), cfg,
                                     return_cache=True)
            return h + y, cache
        hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        if kind == "rec":
            y, cache = RG.rglru_apply(p["rec"], hn, cfg, return_cache=True)
            h = h + y
        else:
            window = cfg.attn_window if (kind == "attn" and cfg.attn_window) else 0
            q, k, v = A.qkv(p["attn"], hn, positions, cfg.rope_theta)
            out = A.blocked_attention(q, k, v, positions, positions,
                                      causal=True, window=window)
            h = h + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"].astype(h.dtype))
            S = hn.shape[1]
            if window:
                W = min(cfg.attn_window, max_len)
                cache = A.window_cache_init(B, W, cfg.n_kv_heads, cfg.hd, dtype)
                take = min(W, S)
                # ring convention: position p lives at slot p % W
                slots = (jnp.arange(S - take, S) % W).astype(jnp.int32)
                cache["k"] = cache["k"].at[:, slots].set(
                    k[:, S - take:].astype(dtype))
                cache["v"] = cache["v"].at[:, slots].set(
                    v[:, S - take:].astype(dtype))
                cache["pos"] = cache["pos"].at[slots].set(
                    jnp.arange(S - take, S, dtype=jnp.int32))
            else:
                cache = A.cache_init(B, max_len, cfg.n_kv_heads, cfg.hd, dtype)
                cache["k"] = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(dtype), (0, 0, 0, 0))
                cache["v"] = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(dtype), (0, 0, 0, 0))
        hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
        if kind == "moe":
            y, _ = MOE.moe_apply(p["moe"], hn, cfg)
            h = h + y
        else:
            h = h + L.mlp(p["mlp"], hn, cfg.act)
        return h, cache

    if _uniform(cfg):
        kind = layer_kind(cfg, 0)

        def body(h, lp):
            h, cache = one_layer_prefill(lp, h, kind)
            return h, cache

        x, caches = jax.lax.scan(body, x, params["stacked"])
        return x, {"stacked": caches}

    if _grouped(cfg):
        n_groups, n_rest = _group_split(cfg)

        def gbody(h, gp):
            gcaches = {}
            for j, kind in enumerate(cfg.block_pattern):
                h, gcaches[f"pos_{j}"] = one_layer_prefill(
                    gp[f"pos_{j}"], h, kind)
            return h, gcaches

        x, groups = jax.lax.scan(gbody, x, params["groups"])
        caches = {"groups": groups}
        for r in range(n_rest):
            i = n_groups * len(cfg.block_pattern) + r
            x, caches[f"layer_{i}"] = one_layer_prefill(
                params[f"layer_{i}"], x, layer_kind(cfg, i))
        return x, caches

    caches = {}
    for i in range(cfg.n_layers):
        x, caches[f"layer_{i}"] = one_layer_prefill(
            params[f"layer_{i}"], x, layer_kind(cfg, i))
    return x, caches
