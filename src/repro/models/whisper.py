"""Whisper-style encoder-decoder backbone (audio frontend stubbed per spec).

The conv/mel frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, n_audio_frames, d_model).  Encoder: bidirectional self-attn;
decoder: causal self-attn + cross-attn.  Sinusoidal positions are computed on
the fly so decoder length is unrestricted (the assigned decode_32k/train_4k
shapes exceed Whisper's native 448 — noted in DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as A


def sinusoid(positions: jax.Array, d: int, dtype) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {"ln1": L.layernorm_init(cfg.d_model),
            "attn": A.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd),
            "ln2": L.layernorm_init(cfg.d_model),
            "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act)}


def dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {"ln1": L.layernorm_init(cfg.d_model),
            "attn": A.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd),
            "ln2": L.layernorm_init(cfg.d_model),
            "cross": A.attn_init(ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd),
            "ln3": L.layernorm_init(cfg.d_model),
            "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.act)}


def encoder_apply(params, frames: jax.Array, cfg) -> jax.Array:
    """frames: (B, F, d_model) stubbed embeddings -> encoder states."""
    x = frames + sinusoid(jnp.arange(frames.shape[1]), cfg.d_model, frames.dtype)
    pos = jnp.arange(frames.shape[1])

    def body(h, lp):
        a = A.attention(lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
                        pos, rope_theta=None, causal=False)
        h = h + a
        h = h + L.mlp(lp["mlp"], L.layernorm(lp["ln2"], h, cfg.norm_eps), cfg.act)
        return h, None

    if cfg.scan_layers:
        fn = jax.checkpoint(lambda p, h: body(h, p)[0], prevent_cse=False) \
            if cfg.remat else (lambda p, h: body(h, p)[0])
        x, _ = jax.lax.scan(
            lambda h, lp: (fn(lp, A.shard(h, "batch", "residual", None)), None),
            x, params["stacked"])
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, params[f"layer_{i}"])
    return x


def dec_layer_apply(lp, h, enc, pos, enc_pos, cfg):
    a = A.attention(lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps),
                    pos, rope_theta=None, causal=True)
    h = h + a
    c = A.attention(lp["cross"], L.layernorm(lp["ln2"], h, cfg.norm_eps),
                    pos, rope_theta=None, kv_x=enc, kv_positions=enc_pos)
    h = h + c
    h = h + L.mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps), cfg.act)
    return h


def decoder_apply(params, tokens_emb: jax.Array, enc: jax.Array, cfg) -> jax.Array:
    S = tokens_emb.shape[1]
    pos = jnp.arange(S)
    enc_pos = jnp.arange(enc.shape[1])
    x = tokens_emb + sinusoid(pos, cfg.d_model, tokens_emb.dtype)

    def body(h, lp):
        return dec_layer_apply(lp, h, enc, pos, enc_pos, cfg), None

    if cfg.scan_layers:
        fn = jax.checkpoint(lambda p, h: body(h, p)[0], prevent_cse=False) \
            if cfg.remat else (lambda p, h: body(h, p)[0])
        x, _ = jax.lax.scan(
            lambda h, lp: (fn(lp, A.shard(h, "batch", "residual", None)), None),
            x, params["stacked"])
    else:
        for i in range(cfg.n_layers):
            x = dec_layer_apply(params[f"layer_{i}"], x, enc, pos, enc_pos, cfg)
    return x


def init(key, cfg):
    ks = jax.random.split(key, 4)
    if cfg.scan_layers:
        enc = {"stacked": jax.vmap(lambda k: enc_layer_init(k, cfg))(
            jax.random.split(ks[0], cfg.n_enc_layers))}
        dec = {"stacked": jax.vmap(lambda k: dec_layer_init(k, cfg))(
            jax.random.split(ks[1], cfg.n_layers))}
    else:
        enc = {f"layer_{i}": enc_layer_init(k, cfg)
               for i, k in enumerate(jax.random.split(ks[0], cfg.n_enc_layers))}
        dec = {f"layer_{i}": dec_layer_init(k, cfg)
               for i, k in enumerate(jax.random.split(ks[1], cfg.n_layers))}
    return {"enc": enc, "dec": dec,
            "embed": L.embed_init(ks[2], cfg.vocab, cfg.d_model),
            "ln_enc": L.layernorm_init(cfg.d_model),
            "ln_out": L.layernorm_init(cfg.d_model)}


def decoder_prefill(params, tokens_emb: jax.Array, enc: jax.Array, cfg,
                    max_len: int, dtype=jnp.bfloat16):
    """Decoder forward that also fills self-attn caches and cross K/V."""
    B, S = tokens_emb.shape[:2]
    pos = jnp.arange(S)
    enc_pos = jnp.arange(enc.shape[1])
    x = tokens_emb + sinusoid(pos, cfg.d_model, tokens_emb.dtype)

    def one(lp, h):
        hn = L.layernorm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = A.qkv(lp["attn"], hn, pos, None)
        out = A.blocked_attention(q, k, v, pos, pos, causal=True)
        h = h + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"].astype(h.dtype))
        cache = A.cache_init(B, max_len, cfg.n_kv_heads, cfg.hd, dtype)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(dtype), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(dtype), (0, 0, 0, 0))
        ck = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"].astype(enc.dtype))
        cv = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"].astype(enc.dtype))
        cache["ck"] = ck.astype(dtype)
        cache["cv"] = cv.astype(dtype)
        c = A.attention(lp["cross"], L.layernorm(lp["ln2"], h, cfg.norm_eps),
                        pos, rope_theta=None, kv_x=enc, kv_positions=enc_pos)
        h = h + c
        h = h + L.mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps), cfg.act)
        return h, cache

    if cfg.scan_layers:
        def body(h, lp):
            h2, cache = one(lp, h)
            return h2, cache
        x, caches = jax.lax.scan(body, x, params["dec"]["stacked"])
        return x, {"stacked": caches}
    caches = {}
    for i in range(cfg.n_layers):
        x, caches[f"layer_{i}"] = one(params["dec"][f"layer_{i}"], x)
    return x, caches


# ---------------------------------------------------------------------------
# decode path (cached)
# ---------------------------------------------------------------------------

def dec_cache_init(params, enc: jax.Array, cfg, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Dict:
    """Self-attn KV caches + precomputed per-layer cross K/V."""
    def one(lp):
        k = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wk"].astype(enc.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc, lp["cross"]["wv"].astype(enc.dtype))
        return {"ck": k.astype(dtype), "cv": v.astype(dtype),
                **A.cache_init(batch, max_len, cfg.n_kv_heads, cfg.hd, dtype)}

    if cfg.scan_layers:
        caches = jax.vmap(one)(params["dec"]["stacked"])
        return {"stacked": caches}
    return {f"layer_{i}": one(params["dec"][f"layer_{i}"])
            for i in range(cfg.n_layers)}


def dec_layer_decode(lp, h, cache, cur_len, cfg):
    a, cache = A.decode_attention(
        lp["attn"], L.layernorm(lp["ln1"], h, cfg.norm_eps), cache, cur_len,
        rope_theta=None)
    h = h + a
    # cross attention against precomputed K/V
    dt = h.dtype
    hn = L.layernorm(lp["ln2"], h, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", hn, lp["cross"]["wq"].astype(dt))
    B, _, H, D = q.shape
    KV = cache["ck"].shape[2]
    qf = (q / math.sqrt(D)).astype(cache["ck"].dtype).reshape(B, KV, H // KV, D)
    s = jnp.einsum("bkgd,bckd->bkgc", qf, cache["ck"],
                   preferred_element_type=jnp.float32)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", w.astype(cache["cv"].dtype), cache["cv"],
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, 1, H, D).astype(dt)
    h = h + jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"].astype(dt))
    h = h + L.mlp(lp["mlp"], L.layernorm(lp["ln3"], h, cfg.norm_eps), cfg.act)
    return h, cache


def decoder_decode(params, x, caches, cur_len, cfg):
    x = x + sinusoid(jnp.reshape(cur_len, (1,)), cfg.d_model, x.dtype)
    if cfg.scan_layers:
        # carry-based cache threading (in-place while-loop aliasing; see
        # transformer.stack_decode)
        def body(carry, lp):
            h, cs, i = carry
            ck = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
                cs)
            h, ck_new = dec_layer_decode(lp, h, ck, cur_len, cfg)
            cs = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), i, 0), cs, ck_new)
            return (h, cs, i + 1), None

        (x, new, _), _ = jax.lax.scan(
            body, (x, caches["stacked"], jnp.asarray(0, jnp.int32)),
            params["dec"]["stacked"])
        return x, {"stacked": new}
    new = {}
    for i in range(cfg.n_layers):
        x, new[f"layer_{i}"] = dec_layer_decode(
            params["dec"][f"layer_{i}"], x, caches[f"layer_{i}"], cur_len, cfg)
    return x, new
