"""Mamba-2 (SSD — state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm: quadratic attention-like mixing inside chunks of
length Q, linear state passing between chunks (scan), so training/prefill is
O(L·Q) and decode is a pure O(1)-per-token recurrence — which is what makes
the long_500k decode shape feasible for this family.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from .layers import dense_init, rmsnorm, rmsnorm_init


def ssd_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_state


def ssm_init(key, cfg):
    d_inner, H, N = ssd_dims(cfg)
    conv_dim = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * N + H
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, d_in_proj),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                    jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1)),  # softplus^-1(1)
        "norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(ks[2], d_inner, cfg.d_model),
    }


def _split_proj(zxbcdt, d_inner, N, H):
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner:2 * d_inner + N]
    Cm = zxbcdt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv, width K. x: (B, L, C), w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssm_apply(p, u: jax.Array, cfg, return_cache: bool = False):
    """Full-sequence SSD. u: (B, L, d_model) -> (B, L, d_model)[, cache]."""
    dt_ = u.dtype
    d_inner, H, N = ssd_dims(cfg)
    P = cfg.ssm_head_dim
    B_, L_real, _ = u.shape
    Q = min(cfg.ssm_chunk, L_real)
    pad = (-L_real) % Q
    if pad:  # padded steps get dt = 0 ⇒ exact no-ops on the state
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    L = L_real + pad
    nc = L // Q

    zxbcdt = u @ p["in_proj"].astype(dt_)
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, N, H)
    if pad:
        live = (jnp.arange(L) < L_real)[None, :, None]
        dt = jnp.where(live, dt, -1e9)  # softplus(-1e9) = 0
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    x, Bm, Cm = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + N],
                 xbc[..., d_inner + N:])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, L, H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    dA = dt * A                                                   # (B, L, H)

    xh = x.reshape(B_, nc, Q, H, P)
    Bc = Bm.reshape(B_, nc, Q, N)
    Cc = Cm.reshape(B_, nc, Q, N)
    dtc = dt.reshape(B_, nc, Q, H)
    dAc = dA.reshape(B_, nc, Q, H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    # One sequential scan over chunks computes intra-chunk (quadratic in Q),
    # the carried state, and the inter-chunk contribution TOGETHER, so the
    # (Q, Q, H) decay tensors exist for ONE chunk at a time (O(L·Q) memory
    # instead of O(L·Q·H) for all chunks at once).
    def chunk_step(S_prev, inp):
        xj, Bj, Cj, dtj, dAj = inp                     # (B, Q, ...) one chunk
        cum = jnp.cumsum(dAj, axis=1)                  # (B, Q, H)
        scores = jnp.einsum("bin,bjn->bij", Cj, Bj,
                            preferred_element_type=jnp.float32)
        # mask the exponent BEFORE exp: exp on the i<j branch would overflow
        # and poison gradients through the where (inf * 0 -> NaN in bwd).
        diff = cum[:, :, None, :] - cum[:, None, :, :]             # (B,i,j,H)
        diff = jnp.where(causal[None, :, :, None], diff, -1e30)
        w = jnp.exp(diff) * scores[..., None] * dtj[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w.astype(xj.dtype), xj,
                             preferred_element_type=jnp.float32)
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", Cj.astype(jnp.float32),
                             S_prev, jnp.exp(cum))
        decay_last = jnp.exp(cum[:, -1:, :] - cum)     # (B, Q, H)
        S_loc = jnp.einsum("bjn,bjh,bjhp->bhnp", Bj.astype(jnp.float32),
                           (decay_last * dtj), xj.astype(jnp.float32))
        S_new = S_prev * jnp.exp(cum[:, -1])[:, :, None, None] + S_loc
        return S_new, (y_intra + y_inter)

    S0 = jnp.zeros((B_, H, N, P), jnp.float32)
    swap = lambda t: jnp.moveaxis(t, 1, 0)
    # remat the chunk body: its (B, Q, Q, H) decay residuals would otherwise
    # be saved for EVERY chunk by the scan backward
    S_final, y = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False), S0,
        (swap(xh), swap(Bc), swap(Cc), swap(dtc), swap(dAc)))
    y = jnp.moveaxis(y, 0, 1).reshape(B_, L, H, P)     # (B, L, H, P) f32
    y = y + p["D"][None, None, :, None] * x.reshape(B_, L, H, P).astype(jnp.float32)
    y = y.reshape(B_, L, d_inner).astype(dt_)

    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    y = shard(y, "batch", "seq", "model")
    out = (y @ p["out_proj"].astype(dt_))[:, :L_real]
    if return_cache:
        K = cfg.ssm_conv
        tail = xbc_raw[:, max(L_real - (K - 1), 0):L_real, :].astype(jnp.float32)
        if tail.shape[1] < K - 1:
            tail = jnp.pad(tail, ((0, 0), (K - 1 - tail.shape[1], 0), (0, 0)))
        cache = {"conv": tail, "state": S_final}
        return out, cache
    return out


# ---------------------------------------------------------------------------
# decode (recurrent O(1) per token)
# ---------------------------------------------------------------------------

def ssm_cache_init(batch: int, cfg, dtype=jnp.float32) -> Dict:
    d_inner, H, N = ssd_dims(cfg)
    conv_dim = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, H, N, cfg.ssm_head_dim), dtype),
    }


def ssm_decode(p, u: jax.Array, cache: Dict, cfg) -> Tuple[jax.Array, Dict]:
    """One-token recurrence. u: (B, 1, d_model)."""
    dt_ = u.dtype
    d_inner, H, N = ssd_dims(cfg)
    P = cfg.ssm_head_dim

    zxbcdt = u[:, 0] @ p["in_proj"].astype(dt_)                   # (B, proj)
    z, x, Bm, Cm, dt = _split_proj(zxbcdt, d_inner, N, H)
    xbc_new = jnp.concatenate([x, Bm, Cm], axis=-1)               # (B, conv_dim)
    conv_buf = jnp.concatenate([cache["conv"],
                                xbc_new[:, None, :].astype(cache["conv"].dtype)],
                               axis=1)                            # (B, K, conv)
    w = p["conv_w"].astype(dt_)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_buf.astype(dt_), w)
                      + p["conv_b"].astype(dt_))
    x, Bm, Cm = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + N],
                 xbc[..., d_inner + N:])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                          # (B, H)
    xh = x.reshape(-1, H, P).astype(jnp.float32)
    state = (cache["state"].astype(jnp.float32) * da[:, :, None, None]
             + jnp.einsum("bn,bh,bhp->bhnp", Bm.astype(jnp.float32), dt, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(-1, d_inner).astype(dt_)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    new_cache = {"conv": conv_buf[:, 1:, :], "state": state.astype(cache["state"].dtype)}
    return out, new_cache
