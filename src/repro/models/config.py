"""Architecture configuration for all supported model families.

One dataclass covers the ten assigned architectures (dense / MoE / VLM /
audio enc-dec / SSM / hybrid).  Exact full-size configs live in
``repro.configs.<arch>``; every config also provides a ``reduced()`` variant
for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | vlm | encdec | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    act: str = "silu"                       # silu | gelu | relu2
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                       # per-expert hidden size

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    n_audio_frames: int = 1500              # stubbed conv frontend output length

    # --- VLM (internvl) ---
    n_patches: int = 256                    # stubbed ViT patch embeddings

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- hybrid (recurrentgemma) ---
    lru_width: int = 0
    attn_window: int = 0                    # local attention window
    block_pattern: Tuple[str, ...] = ()     # e.g. ("rec", "rec", "attn")

    # --- training ---
    remat: bool = True
    scan_layers: bool = True
    moment_dtype: str = "float32"           # adam moment dtype (bf16 for huge models)
    param_dtype: str = "float32"            # master copy dtype
    compute_dtype: str = "bfloat16"

    # --- paper integration: signature-kernel auxiliary loss (DESIGN.md §4/5) ---
    sig_loss: bool = False
    sig_loss_dim: int = 4
    sig_loss_weight: float = 0.01
    sig_dyadic: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can run long_500k (sub-quadratic sequence mixing)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.block_pattern else
                         max(len(self.block_pattern), 3)),
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128, vocab=256, head_dim=16,
            scan_layers=False, remat=False,
            compute_dtype="float32",
        )
        if self.n_experts:
            kw.update(n_experts=4, n_experts_per_tok=2, moe_d_ff=64,
                      n_shared_experts=min(self.n_shared_experts, 1))
        if self.n_enc_layers:
            kw.update(n_enc_layers=2, n_audio_frames=16)
        if self.family == "vlm":
            kw.update(n_patches=8)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        if self.lru_width:
            kw.update(lru_width=64, attn_window=8)
        return self.replace(**kw)


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401  (populates the registry)
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
