"""Shared model building blocks (pure-pytree functional style).

Params are nested dicts of jnp arrays; every constructor is `init_*(key, ...)`
and every application is a pure function.  Activation sharding uses logical
axis names resolved through ``repro.parallel.api``.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.api import shard


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return jax.random.normal(key, (d_in, d_out), jnp.float32) * scale


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"]).astype(dt)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D), positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                          # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                # (B, S, 1, D/2)
    sin = sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    if act == "relu2":  # squared-ReLU, 2-matrix MLP (nemotron)
        return {"w_in": dense_init(ks[0], d_model, d_ff),
                "w_out": dense_init(ks[1], d_ff, d_model)}
    return {"w_gate": dense_init(ks[0], d_model, d_ff),
            "w_up": dense_init(ks[1], d_model, d_ff),
            "w_out": dense_init(ks[2], d_ff, d_model)}


def mlp(p, x, act: str):
    dt = x.dtype
    if act == "relu2":
        h = x @ p["w_in"].astype(dt)
        h = jnp.square(jax.nn.relu(h))
    else:
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * u
    h = shard(h, "batch", "seq", "model")
    return h @ p["w_out"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int):
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embed(p, tokens, dtype):
    return p["table"].astype(dtype)[tokens]


def unembed(p, x):
    logits = x @ p["table"].astype(x.dtype).T
    return shard(logits, "batch", "seq", "model")


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 1e-4) -> jax.Array:
    """Mean token cross-entropy with optional z-loss, vocab-parallel safe."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()
