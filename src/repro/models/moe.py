"""Mixture-of-Experts block: deterministic capacity-based top-k dispatch.

GShard/Switch-style dense dispatch (one-hot einsums) — fully static shapes,
TPU/SPMD friendly: with experts sharded over the ``expert`` logical axis the
dispatch einsum lowers to an all-to-all.  Supports shared experts with a
sigmoid gate (Qwen-MoE) and fine-grained routed experts (DBRX).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from .layers import dense_init, mlp_init, mlp

CAPACITY_FACTOR = 1.25
GROUP = 256


def moe_init(key, cfg):
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], D, E, scale=0.02),
        "w_gate": jax.random.normal(ks[1], (E, D, F), jnp.float32) / math.sqrt(D),
        "w_up": jax.random.normal(ks[2], (E, D, F), jnp.float32) / math.sqrt(D),
        "w_out": jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F),
    }
    if cfg.n_shared_experts:
        k1, k2 = jax.random.split(jax.random.fold_in(key, 7))
        p["shared"] = mlp_init(k1, D, cfg.n_shared_experts * F, cfg.act)
        p["shared_gate"] = dense_init(k2, D, 1, scale=0.02)
    return p


def moe_apply(p, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    dt = x.dtype
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    tokens = B * S
    g = min(GROUP, tokens)
    ng = tokens // g
    xg = shard(x.reshape(ng, g, D), "batch", None, None)

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)   # (ng, g, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, idx = jax.lax.top_k(probs, k)                        # (ng, g, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    if g <= 128:
        cap = g  # dropless for decode / tiny groups (exactness at boundaries)
    else:
        cap = int(math.ceil(g * k / E * CAPACITY_FACTOR))
        cap = max(4, -(-cap // 4) * 4)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # (ng, g, k, E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(ng, k * g, E)    # k-major priority
    pos = jnp.cumsum(flat, axis=1) - 1.0                         # slot within expert
    keep = flat * (pos < cap)
    disp_flat = keep[..., None] * jax.nn.one_hot(pos, cap, dtype=jnp.float32)
    disp = disp_flat.reshape(ng, k, g, E, cap).sum(1)            # (ng, g, E, cap)
    comb = (disp_flat.reshape(ng, k, g, E, cap)
            * gate_w.transpose(0, 2, 1)[..., None, None]).sum(1)

    x_e = jnp.einsum("ngd,ngec->necd", xg, disp.astype(dt))      # (ng, E, cap, D)
    x_e = shard(x_e, "batch", "expert", None, None)
    gate = jnp.einsum("necd,edf->necf", x_e, p["w_gate"].astype(dt))
    up = jnp.einsum("necd,edf->necf", x_e, p["w_up"].astype(dt))
    act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
    h = shard(act * up, "batch", "expert", None, None)
    y_e = jnp.einsum("necf,efd->necd", h, p["w_out"].astype(dt))
    y_e = shard(y_e, "batch", "expert", None, None)
    y = jnp.einsum("necd,ngec->ngd", y_e, comb.astype(dt))       # (ng, g, D)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(xg.reshape(B, S, D) @ p["shared_gate"].astype(dt))
        y = y + sg * mlp(p["shared"], x, cfg.act)

    # load-balance auxiliary loss (Switch) + router z-loss
    me = probs.mean(axis=(0, 1))                                 # mean prob / expert
    ce = onehot.sum(2).mean(axis=(0, 1))                         # fraction routed
    aux = E * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, aux
