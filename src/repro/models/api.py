"""Top-level model API: build_model(cfg) -> Model with init / loss / serve fns.

Families: dense, moe, vlm (dense LM + stubbed patch embeddings), encdec
(whisper), ssm (mamba2), hybrid (recurrentgemma).  The paper's technique
attaches as an optional signature-kernel auxiliary loss on the hidden-state
trajectory (cfg.sig_loss — DESIGN.md §4/5).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.parallel.api import shard
from . import layers as L
from . import transformer as T
from . import whisper as W

VOCAB_ALIGN = 256


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable            # (key) -> params
    loss: Callable            # (params, batch) -> (loss, metrics)
    prefill: Callable         # (params, batch) -> (logits, cache)
    decode: Callable          # (params, cache, tokens, cur_len) -> (logits, cache)
    cache_init: Callable      # (params, batch_size, max_len) -> cache


def padded_vocab(cfg) -> int:
    return -(-cfg.vocab // VOCAB_ALIGN) * VOCAB_ALIGN


def _logits(params, x, cfg):
    table = params["lm_head"] if "lm_head" in params else params["embed"]
    logits = L.unembed(table, x)
    vp = padded_vocab(cfg)
    if vp != cfg.vocab:  # mask synthetic vocab slots
        mask = jnp.arange(vp) < cfg.vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _sig_aux(params, hidden, batch, cfg):
    """Signature-kernel MMD between the model's hidden trajectory and a target
    path distribution (the paper-technique hook available on every arch)."""
    from repro.core import losses as sig_losses
    from repro.core.config import GridConfig
    S = hidden.shape[1]
    stride = max(1, S // 32)
    path_h = hidden[:, ::stride][:, :32].astype(jnp.float32)
    target = batch["sig_target"].astype(jnp.float32)
    return sig_losses.sig_aux_loss(
        path_h, target, proj=params["sig_proj"],
        grid=GridConfig(cfg.sig_dyadic, cfg.sig_dyadic))


def build_model(cfg) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_lm(cfg)


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / vlm / ssm / hybrid)
# ---------------------------------------------------------------------------

def _build_lm(cfg) -> Model:
    vp = padded_vocab(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)

    def init(key):
        ks = jax.random.split(key, 5)
        params = {"embed": L.embed_init(ks[0], vp, cfg.d_model),
                  "final_norm": L.rmsnorm_init(cfg.d_model),
                  "layers": T.stack_init(ks[1], cfg)}
        if not cfg.tie_embeddings:
            params["lm_head"] = L.embed_init(ks[2], vp, cfg.d_model)
        if cfg.family == "vlm":
            params["patch_proj"] = L.dense_init(ks[3], 1024, cfg.d_model)
        if cfg.sig_loss:
            params["sig_proj"] = L.dense_init(ks[4], cfg.d_model,
                                              cfg.sig_loss_dim)
        return params

    def embed_inputs(params, batch):
        x = L.embed(params["embed"], batch["tokens"], cdt)
        if cfg.family == "vlm" and "patches" in batch:
            pe = batch["patches"].astype(cdt) @ params["patch_proj"].astype(cdt)
            x = jnp.concatenate([pe, x[:, pe.shape[1]:]], axis=1)
        return shard(x, "batch", "seq", None)

    def forward(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, aux = T.stack_apply(params["layers"], x, positions, cfg)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def loss(params, batch):
        x, aux = forward(params, batch)
        logits = _logits(params, x, cfg)
        ce = L.cross_entropy(logits, batch["labels"])
        total = ce + 0.01 * aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.sig_loss:
            sl = _sig_aux(params, x, batch, cfg)
            total = total + cfg.sig_loss_weight * sl
            metrics["sig"] = sl
        return total, metrics

    def prefill(params, batch):
        x = embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        max_len = batch.get("max_len", x.shape[1])
        x, caches = T.stack_prefill(params["layers"], x, positions, cfg,
                                    max_len, cdt)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return _logits(params, x[:, -1:], cfg), caches

    def cache_init(params, batch_size, max_len):
        return T.stack_cache_init(cfg, batch_size, max_len, cdt)

    def decode(params, caches, tokens, cur_len):
        x = L.embed(params["embed"], tokens, cdt)       # (B, 1)
        x = shard(x, "batch", None, None)
        x, caches = T.stack_decode(params["layers"], x, caches, cur_len, cfg)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return _logits(params, x, cfg), caches

    return Model(cfg, init, loss, prefill, decode, cache_init)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------

def _build_encdec(cfg) -> Model:
    vp = padded_vocab(cfg)
    cdt = jnp.dtype(cfg.compute_dtype)

    def init(key):
        k1, k2 = jax.random.split(key)
        params = W.init(k1, cfg.replace(vocab=vp))
        if cfg.sig_loss:
            params["sig_proj"] = L.dense_init(k2, cfg.d_model, cfg.sig_loss_dim)
        return params

    def encode(params, batch):
        frames = batch["frames"].astype(cdt)
        enc = W.encoder_apply(params["enc"], frames, cfg)
        return L.layernorm(params["ln_enc"], enc, cfg.norm_eps)

    def loss(params, batch):
        enc = encode(params, batch)
        temb = L.embed(params["embed"], batch["tokens"], cdt)
        x = W.decoder_apply(params["dec"], temb, enc, cfg)
        x = L.layernorm(params["ln_out"], x, cfg.norm_eps)
        logits = _logits(params, x, cfg)
        ce = L.cross_entropy(logits, batch["labels"])
        metrics = {"ce": ce}
        total = ce
        if cfg.sig_loss:
            sl = _sig_aux(params, x, batch, cfg)
            total = total + cfg.sig_loss_weight * sl
            metrics["sig"] = sl
        return total, metrics

    def prefill(params, batch):
        enc = encode(params, batch)
        max_len = batch.get("max_len", batch["tokens"].shape[1])
        temb = L.embed(params["embed"], batch["tokens"], cdt)
        x, caches = W.decoder_prefill(params, temb, enc, cfg, max_len, cdt)
        x = L.layernorm(params["ln_out"], x, cfg.norm_eps)
        return _logits(params, x[:, -1:], cfg), caches

    def cache_init(params, batch_size, max_len):
        # caches require encoder states; serve path uses prefill instead.
        enc = jnp.zeros((batch_size, cfg.n_audio_frames, cfg.d_model), cdt)
        return W.dec_cache_init(params, enc, cfg, batch_size, max_len, cdt)

    def decode(params, caches, tokens, cur_len):
        x = L.embed(params["embed"], tokens, cdt)
        x, caches = W.decoder_decode(params, x, caches, cur_len, cfg)
        x = L.layernorm(params["ln_out"], x, cfg.norm_eps)
        return _logits(params, x, cfg), caches

    return Model(cfg, init, loss, prefill, decode, cache_init)
