"""Model zoo: the ten assigned architectures as composable pytree modules."""

from .config import ArchConfig, get_config, list_configs, register
from .api import Model, build_model

__all__ = ["ArchConfig", "get_config", "list_configs", "register",
           "Model", "build_model"]
