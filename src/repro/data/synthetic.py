"""Deterministic, stateless, resumable synthetic data pipelines.

Every batch is a pure function of (seed, step) via PRNG fold-in — the
pipeline carries NO state, so restart/elastic-rescale resume is exact: the
training loop just asks for ``batch_at(step)``.  Sharding-aware: batches are
produced host-locally and device_put against the step's input shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenLM:
    """Zipf-ish synthetic token stream for LM training."""
    vocab: int
    seq: int
    batch: int
    seed: int = 0
    n_patches: int = 0          # vlm: prepend patch embeddings
    n_frames: int = 0           # encdec: audio frame embeddings
    d_model: int = 0
    sig_target_dim: int = 0

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        # Zipf-like marginal: exponentiate a uniform for a heavy head
        u = jax.random.uniform(k1, (self.batch, self.seq + 1),
                               minval=1e-6, maxval=1.0)
        toks = jnp.minimum((u ** 3.0) * self.vocab,
                           self.vocab - 1).astype(jnp.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.n_patches:
            out["patches"] = 0.1 * jax.random.normal(
                k2, (self.batch, self.n_patches, 1024), jnp.bfloat16)
        if self.n_frames:
            out["frames"] = 0.1 * jax.random.normal(
                k3, (self.batch, self.n_frames, self.d_model), jnp.bfloat16)
        if self.sig_target_dim:
            out["sig_target"] = gbm_paths(k4, self.batch, 32,
                                          self.sig_target_dim)
        return out


def gbm_paths(key, batch: int, length: int, dim: int,
              mu: float = 0.0, sigma: float = 0.2) -> jax.Array:
    """Geometric-Brownian-motion paths (B, L, d) — the canonical sig-kernel
    workload distribution (quant-finance time series)."""
    dt = 1.0 / max(length - 1, 1)
    dw = jax.random.normal(key, (batch, length - 1, dim)) * jnp.sqrt(dt)
    logp = jnp.cumsum((mu - 0.5 * sigma ** 2) * dt + sigma * dw, axis=1)
    logp = jnp.concatenate([jnp.zeros((batch, 1, dim)), logp], axis=1)
    return jnp.exp(logp) - 1.0


def fbm_paths(key, batch: int, length: int, dim: int,
              hurst: float = 0.7, n_modes: int = 32) -> jax.Array:
    """Approximate fractional Brownian motion via spectral synthesis:
    X(t) = Σ_k k^{-(H+1/2)} sin(2πk t + φ_k) with random phases."""
    freqs = jnp.arange(1, n_modes + 1, dtype=jnp.float32)      # (K,)
    amps = freqs ** (-(hurst + 0.5))
    phases = jax.random.uniform(key, (batch, n_modes, dim)) * 2 * jnp.pi
    t = jnp.linspace(0.0, 1.0, length)                         # (L,)
    ang = (2 * jnp.pi * freqs[None, None, :, None] * t[None, :, None, None]
           + phases[:, None, :, :])                            # (B, L, K, d)
    return (amps[None, None, :, None] * jnp.sin(ang)).sum(axis=2)


@dataclasses.dataclass(frozen=True)
class PathData:
    """Path-distribution data for signature-kernel workloads."""
    batch: int
    length: int
    dim: int
    seed: int = 0
    kind: str = "gbm"

    def batch_at(self, step: int) -> jax.Array:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5161), step)
        return gbm_paths(key, self.batch, self.length, self.dim)
