"""Streaming ``Path`` engine: O(1) interval signatures over growing paths.

Signatory's ``Path`` class (PAPERS.md, arxiv 2001.00706) showed the right
shape for online signature serving: precompute the signature of every
*prefix* of a path once (one O(L) scan), and every interval query becomes a
single Chen combine of two stored group elements — no re-scan, whatever the
interval.  This module is that engine on top of the repro stack:

* the prefix store is the library's own Horner stream scan
  (:func:`repro.core.signature._signature_stream_from_increments`), so
  ``path.signature(0, j)`` is **bitwise** the reference
  ``repro.signature(points[:j])``;
* interval queries use the truncated-tensor-algebra group structure:
  ``S(x[i:j]) = S(x[:i])^{-1} ⊗ S(x[:j])`` with the inverses precomputed
  (:func:`repro.core.tensoralg.sig_inverse`), so a query is one
  :func:`repro.core.tensoralg.chen` — O(sig_dim), independent of ``j-i``
  and of the path length (verified by the scan/combine counters in
  :mod:`repro.core.dispatch`);
* ``update(new_points)`` extends the path by scanning **only the new
  chunk** and Chen-combining its prefixes onto the stored tip — O(chunk)
  work, zero full-path re-scans;
* buffers are padded to PR 5's power-of-two buckets
  (:func:`repro.core.transforms.bucket_length`) along both the capacity
  and the append-chunk axes, so paths of nearby lengths share one jit
  trace and steady-state appends hit a **warm** trace (instrumented by
  :func:`trace_counts`).

Transform support: ``lead_lag`` composes (its increments are local, so an
interval of the transformed stream *is* the transform of the interval);
``time_aug`` and ``basepoint`` are rejected — the ``[t0, t1]`` grid
renormalises every increment whenever the path grows, and a basepoint
belongs to the whole path, not to its intervals.  Put a physical time
channel in the data instead (docs/api/public.md, "Streaming paths").

Numerical contract: queries are *exact* group arithmetic on the stored
prefixes.  ``signature(0, j)`` (and the no-arg full signature) is bitwise
identical to the reference scan of ``points[:j]``; general ``(i, j)``
intervals agree with a fresh recompute to within a few ULPs (the combine
multiplies two floats the scan folds in a different order).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..core import lyndon
from ..core import tensoralg as ta
from ..core import transforms as tf
from ..core.config import TransformPipeline, _pytree_dataclass
from ..core.dispatch import record_combines
from ..core.logsignature import MODES as _LOGSIG_MODES
from ..core.signature import _signature_stream_from_increments

#: jit-trace counters per kernel kind — bumped by a Python side effect
#: inside the jitted bodies, so they advance once per *trace* (shape
#: bucket), never on warm-cache calls.  Tests and the serving loop read
#: them to prove bucketing really bounds retracing.
_TRACE_COUNTS: Dict[str, int] = {"build": 0, "update": 0, "query": 0,
                                 "evict": 0}


def trace_counts() -> Dict[str, int]:
    """Snapshot of the jit-trace counters (build/update/query/evict)."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    """Zero the jit-trace counters (tests)."""
    for k in _TRACE_COUNTS:
        _TRACE_COUNTS[k] = 0


def _check_pipeline(transforms: Optional[TransformPipeline]
                    ) -> TransformPipeline:
    if transforms is None:
        return TransformPipeline()
    if not isinstance(transforms, TransformPipeline):
        raise TypeError(
            f"transforms= expects a TransformPipeline, got "
            f"{type(transforms).__name__}")
    if transforms.time_aug or transforms.basepoint:
        raise ValueError(
            "repro.Path supports lead_lag only: time_aug renormalises every "
            "increment whenever the path grows (the [t0, t1] grid spans the "
            "whole path) and basepoint belongs to the full path, not its "
            "intervals — incompatible with an incremental prefix store.  "
            "Add a physical time channel to the data instead "
            "(docs/api/public.md, 'Streaming paths & serving')")
    return transforms


# ---------------------------------------------------------------------------
# jitted kernels (module-level so every Path instance shares one trace cache)
# ---------------------------------------------------------------------------

def _gather(store: jax.Array, idx: jax.Array) -> jax.Array:
    """Rows of a (..., M, S) store at positions ``idx``.

    ``idx`` is (n,) int32 (shared across the batch) or (..., n) per-batch;
    returns (..., n, S).
    """
    idx = jnp.asarray(idx, jnp.int32)
    n = idx.shape[-1]
    tgt = (*store.shape[:-2], n, store.shape[-1])
    return jnp.take_along_axis(store, jnp.broadcast_to(idx[..., :, None], tgt),
                               axis=-2)


@functools.partial(jax.jit, static_argnames=("d", "depth"))
def _interval_kernel(prefix: jax.Array, inv_prefix: jax.Array,
                     ql: jax.Array, qr: jax.Array, *, d: int, depth: int
                     ) -> jax.Array:
    """Signatures of the intervals [ql, qr) of transformed increments.

    ``ql`` / ``qr`` are (n,) int32 window bounds in *transformed-step*
    coordinates; one vectorised Chen combine of the stored inverse
    prefixes with the stored prefixes — the only data touched is 2n rows
    of the stores, whatever the window sizes.
    """
    _TRACE_COUNTS["query"] += 1
    record_combines(ql.shape[-1])
    q_right = _gather(prefix, qr - 1)
    inv_left = _gather(inv_prefix, jnp.maximum(ql - 1, 0))
    inv_left = jnp.where((ql > 0)[..., None], inv_left,
                         jnp.zeros((), inv_left.dtype))
    return ta.chen(inv_left, q_right, d, depth)


@functools.partial(jax.jit, static_argnames=("depth", "lead_lag"))
def _build_kernel(points: jax.Array, *, depth: int, lead_lag: bool
                  ) -> Tuple[jax.Array, jax.Array]:
    """Prefix store of an edge-padded point buffer: (Q_1..Q_M, inverses).

    ``points`` is (..., C, d) with the tail edge-padded (repeated last
    point), so padded increments are exactly zero — Horner no-ops — and
    the prefix stream simply repeats the true tip across the padding.
    """
    _TRACE_COUNTS["build"] += 1
    z = points[..., 1:, :] - points[..., :-1, :]
    z = tf.transform_increments(z, False, lead_lag)
    prefix = _signature_stream_from_increments(z, depth)
    inv = ta.sig_inverse(prefix, z.shape[-1], depth)
    return prefix, inv


@functools.partial(jax.jit, static_argnames=("depth", "lead_lag"))
def _update_kernel(points: jax.Array, prefix: jax.Array,
                   inv_prefix: jax.Array, length: jax.Array,
                   chunk: jax.Array, k: jax.Array, *,
                   depth: int, lead_lag: bool):
    """Append an edge-padded chunk: scan the chunk, Chen onto the tip.

    Shapes: ``points`` (..., C, d), ``chunk`` (..., kc, d) with kc ≤ C,
    ``length``/``k`` broadcastable int32 — the true point count so far and
    the true size of this chunk (``k = 0`` makes the whole call a no-op,
    which is what the serving loop's group padding relies on).  The only
    scan is over the kc-row chunk; the stored prefixes are extended by one
    batched Chen combine — never re-read, never re-scanned.
    """
    _TRACE_COUNTS["update"] += 1
    f = 2 if lead_lag else 1
    C = points.shape[-2]
    kc = chunk.shape[-2]
    M = prefix.shape[-2]
    length = jnp.asarray(length, jnp.int32)
    k = jnp.asarray(k, jnp.int32)

    # raw chunk increments, anchored at the current tip; rows at or past
    # the true chunk size are masked to zero (edge padding already makes
    # them zero for real chunks; the mask also covers k = 0 no-op calls)
    last = jnp.take_along_axis(
        points, (length - 1)[..., None, None]
        * jnp.ones((1, points.shape[-1]), jnp.int32), axis=-2)
    z = jnp.diff(jnp.concatenate([last, chunk], axis=-2), axis=-2)
    valid = jnp.arange(kc) < k[..., None]
    z = jnp.where(valid[..., None], z, jnp.zeros((), z.dtype))
    z = tf.transform_increments(z, False, lead_lag)
    d_t = z.shape[-1]
    mc = z.shape[-2]

    # O(chunk): prefix signatures of the chunk alone, and their inverses
    s_chunk = _signature_stream_from_increments(z, depth)
    inv_chunk = ta.sig_inverse(s_chunk, d_t, depth)

    # O(1) per new step: splice onto the stored tip by Chen's identity
    m = f * (length - 1)                                   # steps so far
    q_m = jnp.take_along_axis(
        prefix, (m - 1)[..., None, None]
        * jnp.ones((1, prefix.shape[-1]), jnp.int32), axis=-2)
    inv_q_m = jnp.take_along_axis(
        inv_prefix, (m - 1)[..., None, None]
        * jnp.ones((1, prefix.shape[-1]), jnp.int32), axis=-2)
    q_m = jnp.broadcast_to(q_m, s_chunk.shape)
    inv_q_m = jnp.broadcast_to(inv_q_m, s_chunk.shape)
    new_q = ta.chen(q_m, s_chunk, d_t, depth)
    new_inv = ta.chen(inv_chunk, inv_q_m, d_t, depth)      # (ab)⁻¹ = b⁻¹a⁻¹
    record_combines(2 * mc)

    # scatter the mc new prefixes at offset m, the chunk at offset length
    idx = jnp.arange(M)
    src = idx - m[..., None]                               # (..., M)
    on = (src >= 0) & (src < mc)
    gathered_q = jnp.take_along_axis(
        new_q, jnp.clip(src, 0, mc - 1)[..., None], axis=-2)
    gathered_i = jnp.take_along_axis(
        new_inv, jnp.clip(src, 0, mc - 1)[..., None], axis=-2)
    prefix = jnp.where(on[..., None], gathered_q, prefix)
    inv_prefix = jnp.where(on[..., None], gathered_i, inv_prefix)

    pidx = jnp.arange(C)
    psrc = pidx - length[..., None]
    pon = (psrc >= 0) & (psrc < kc)
    gathered_p = jnp.take_along_axis(
        chunk, jnp.clip(psrc, 0, kc - 1)[..., None], axis=-2)
    points = jnp.where(pon[..., None], gathered_p, points)
    return points, prefix, inv_prefix, length + k


@functools.partial(jax.jit, static_argnames=("C", "M", "f", "d", "depth"))
def _evict_kernel(points: jax.Array, prefix: jax.Array,
                  inv_prefix: jax.Array, length: jax.Array, e: jax.Array, *,
                  C: int, M: int, f: int, d: int, depth: int):
    """Drop the first ``e`` points by a group-inverse splice — no re-scan.

    The evicted prefix ``Q_{f·e}`` is a pivot: every surviving prefix is
    rebased as ``Q'_k = Q_{f·e}⁻¹ ⊗ Q_{f·e+k}`` (and its inverse as
    ``Q'⁻¹_k = Q_{f·e+k}⁻¹ ⊗ Q_{f·e}``) — two *batched* Chen combines over
    the gathered survivor rows, exactly the group identity interval
    queries use.  No increment is ever re-folded: the only scan-shaped
    work is the gather.  ``C``/``M`` are the (static) shrunken point /
    store capacities; gathers clip at the true tip so the tail padding
    repeats it, matching ``_build_kernel``'s edge-pad semantics.
    """
    _TRACE_COUNTS["evict"] += 1
    e = jnp.asarray(e, jnp.int32)
    length = jnp.asarray(length, jnp.int32)
    pidx = jnp.clip(e + jnp.arange(C, dtype=jnp.int32), 0, length - 1)
    new_points = _gather(points, pidx)
    t = f * e                                      # transformed pivot step
    sidx = jnp.clip(t + jnp.arange(M, dtype=jnp.int32), 0,
                    f * (length - 1) - 1)
    q = _gather(prefix, sidx)
    iq = _gather(inv_prefix, sidx)
    piv_q = jnp.broadcast_to(_gather(prefix, (t - 1)[None]), q.shape)
    piv_i = jnp.broadcast_to(_gather(inv_prefix, (t - 1)[None]), q.shape)
    new_prefix = ta.chen(piv_i, q, d, depth)
    new_inv = ta.chen(iq, piv_q, d, depth)
    record_combines(2 * M)
    return new_points, new_prefix, new_inv, length - e


# ---------------------------------------------------------------------------
# configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RollingConfig:
    """Rolling-window query plan: ``window`` points every ``stride`` points.

    Static metadata (window/stride set output shapes).  ``window`` counts
    *points*, so the smallest meaningful window is 2 (one increment).
    """

    window: int
    stride: int = 1

    def __post_init__(self):
        for name, lo in (("window", 2), ("stride", 1)):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < lo:
                raise ValueError(
                    f"RollingConfig.{name} must be a Python int >= {lo}, "
                    f"got {v!r}")

    def num_windows(self, length: int) -> int:
        """How many full windows fit in a ``length``-point path."""
        if length < self.window:
            return 0
        return (length - self.window) // self.stride + 1


_pytree_dataclass(RollingConfig, data_fields=(),
                  meta_fields=("window", "stride"))


# ---------------------------------------------------------------------------
# Path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Path:
    """A (possibly growing) path with precomputed per-prefix signatures.

    Construct with :meth:`from_points`; every instance is immutable —
    :meth:`update` returns a *new* ``Path`` sharing the (functionally
    updated) buffers.  A frozen pytree: instances pass through ``jax.jit``
    / ``jax.grad`` boundaries, and gradients flow from any query back to
    the stored prefixes and on to the original points.

    Data leaves: ``points`` (..., C, d) the bucketed point buffer,
    ``prefix`` / ``inv_prefix`` (..., M, sig_dim) the per-prefix signatures
    ``Q_m = S(x over the first m transformed increments)`` and their group
    inverses, ``length`` the true point count (int32 scalar — all paths in
    a batch share it; buffer content past it is unspecified).  Static
    metadata: ``depth``, the (lead-lag-only) ``transforms`` and the
    optional ``retention`` cap (:meth:`evict` runs automatically inside
    :meth:`update` whenever the length would exceed it).
    """

    points: jax.Array
    prefix: jax.Array
    inv_prefix: jax.Array
    length: jax.Array
    depth: int
    transforms: TransformPipeline = TransformPipeline()
    retention: Optional[int] = None

    # -- construction -------------------------------------------------------

    @classmethod
    def from_points(cls, points: jax.Array, depth: int, *,
                    transforms: Optional[TransformPipeline] = None,
                    retention: Optional[int] = None) -> "Path":
        """Build the prefix store for ``points`` (..., L, d), L ≥ 2.

        One O(L) Horner stream scan (the same scan as
        ``repro.signature(..., stream=True)``), padded up to the
        power-of-two capacity bucket so nearby lengths share a jit trace.

        ``retention=n`` caps the stored history at ``n`` points: every
        :meth:`update` that would exceed it auto-:meth:`evict`\\ s the
        oldest points first, so an endless stream runs in O(n) memory with
        zero re-scans.  The initial points must already fit the cap.
        """
        transforms = _check_pipeline(transforms)
        if retention is not None and (
                not isinstance(retention, int) or isinstance(retention, bool)
                or retention < 2):
            raise ValueError(
                f"retention must be None or a Python int >= 2 (a path keeps "
                f"at least one increment), got {retention!r}")
        points = jnp.asarray(points)
        if points.ndim < 2:
            raise ValueError(
                f"Path.from_points expects (..., L, d) points, got shape "
                f"{points.shape}")
        L = points.shape[-2]
        if L < 2:
            raise ValueError(
                f"Path needs at least 2 points (one increment), got L={L}")
        if retention is not None and L > retention:
            raise ValueError(
                f"initial points ({L}) exceed retention={retention}; slice "
                f"the history yourself — eviction applies to updates")
        if not (isinstance(depth, int) and not isinstance(depth, bool)
                and depth >= 1):
            raise ValueError(f"depth must be a Python int >= 1, got {depth!r}")
        C = tf.bucket_length(L)
        if C > L:
            width = [(0, 0)] * points.ndim
            width[-2] = (0, C - L)
            points = jnp.pad(points, width, mode="edge")
        prefix, inv = _build_kernel(points, depth=depth,
                                    lead_lag=transforms.lead_lag)
        return cls(points=points, prefix=prefix, inv_prefix=inv,
                   length=jnp.asarray(L, jnp.int32), depth=depth,
                   transforms=transforms, retention=retention)

    # -- shape facts --------------------------------------------------------

    @property
    def d(self) -> int:
        """Raw channel count of the stored points."""
        return self.points.shape[-1]

    @property
    def transformed_d(self) -> int:
        """Channel count the signatures are computed over."""
        return self.transforms.transformed_dim(self.d)

    @property
    def capacity(self) -> int:
        """Point capacity of the buffers (the current power-of-two bucket)."""
        return self.points.shape[-2]

    @property
    def sig_dim(self) -> int:
        """Flat signature width of every query result."""
        return self.prefix.shape[-1]

    @property
    def _f(self) -> int:
        """Transformed increments per raw increment (2 under lead-lag)."""
        return 2 if self.transforms.lead_lag else 1

    def __len__(self) -> int:
        return int(self.length)

    # -- queries ------------------------------------------------------------

    def _concrete_length(self, what: str) -> int:
        try:
            return int(self.length)
        except jax.errors.ConcretizationTypeError:
            raise ValueError(
                f"Path.{what} needs a concrete Path (its length drives "
                f"Python-level shape decisions); call it outside jax.jit — "
                f"interval queries with explicit (i, j) trace fine") from None

    def _check_interval(self, i, j):
        if j is None:
            j = self.length
        conc_len = None
        try:
            conc_len = int(self.length)
        except jax.errors.ConcretizationTypeError:
            pass
        if isinstance(i, int) and isinstance(j, int):
            if i < 0 or j - i < 2 or (conc_len is not None and j > conc_len):
                raise ValueError(
                    f"interval [{i}, {j}) must satisfy 0 <= i <= j-2 and "
                    f"j <= length ({conc_len}) — a signature needs at least "
                    f"one increment")
        return i, j

    def signature(self, i: int = 0, j: Optional[int] = None) -> jax.Array:
        """Signature of ``points[i:j]`` — one Chen combine, no re-scan.

        ``j`` defaults to the current length (the full-path signature).
        ``i == 0`` (a concrete zero) returns the stored prefix directly —
        bitwise the reference Horner scan of ``points[:j]``.  General
        intervals combine the precomputed inverse prefix with the prefix:
        exact group arithmetic, a few ULPs from a fresh recompute.
        """
        i, j = self._check_interval(i, j)
        f = self._f
        qr = f * (jnp.asarray(j, jnp.int32) - 1)
        if isinstance(i, int) and i == 0:
            return _gather(self.prefix, (qr - 1)[None])[..., 0, :]
        ql = f * jnp.asarray(i, jnp.int32)
        return _interval_kernel(self.prefix, self.inv_prefix, ql[None],
                                qr[None], d=self.transformed_d,
                                depth=self.depth)[..., 0, :]

    def logsignature(self, i: int = 0, j: Optional[int] = None, *,
                     mode: str = "lyndon") -> jax.Array:
        """Log-signature of ``points[i:j]`` via the Lyndon machinery.

        The interval signature (one Chen combine) is pushed through
        :func:`repro.core.tensoralg.tensor_log` and compressed to the
        requested basis — still no re-scan.
        """
        if mode not in _LOGSIG_MODES:
            raise ValueError(
                f"mode must be one of {_LOGSIG_MODES}, got {mode!r}")
        flat = ta.tensor_log(self.signature(i, j), self.transformed_d,
                             self.depth)
        if mode == "expand":
            return flat
        return lyndon.compress(flat, self.transformed_d, self.depth, mode)

    def rolling(self, window: Union[int, RollingConfig], *,
                stride: int = 1) -> jax.Array:
        """Signatures of every full ``window``-point window, batched.

        ``window`` may be a :class:`RollingConfig` (whose stride wins).
        Returns (..., n_windows, sig_dim) — window ``w`` starts at point
        ``w·stride``.  One *vectorised* Chen combine over all windows; the
        prefix store is gathered, never re-scanned.  Needs a concrete
        ``Path`` (the window count is a Python-level shape).
        """
        cfg = window if isinstance(window, RollingConfig) \
            else RollingConfig(window=window, stride=stride)
        L = self._concrete_length("rolling")
        n = cfg.num_windows(L)
        if n < 1:
            raise ValueError(
                f"no full {cfg.window}-point window fits in a {L}-point "
                f"path")
        f = self._f
        # pad the window count to a power-of-two bucket (repeating the last
        # window) so a growing path revisits one warm query trace per bucket
        nb = tf.bucket_length(n, minimum=1)
        w = jnp.minimum(jnp.arange(nb, dtype=jnp.int32), n - 1)
        starts = w * cfg.stride
        out = _interval_kernel(
            self.prefix, self.inv_prefix, f * starts,
            f * (starts + cfg.window - 1), d=self.transformed_d,
            depth=self.depth)
        return out[..., :n, :]

    # -- incremental extension ----------------------------------------------

    def update(self, new_points: jax.Array) -> "Path":
        """Extend the path with ``new_points`` (..., k, d), k ≥ 1.

        O(chunk) work: the new increments are scanned (the chunk is padded
        to its own power-of-two bucket so steady-state appends of similar
        sizes share one warm jit trace) and Chen-combined onto the stored
        tip — the existing prefixes are never re-read or re-scanned.  When
        the buffers run out of capacity they grow to the next power-of-two
        bucket (an expected, bounded retrace).  Needs a concrete ``Path``.
        """
        new_points = jnp.asarray(new_points)
        if new_points.ndim < 2 or new_points.shape[-1] != self.d:
            raise ValueError(
                f"update expects (..., k, {self.d}) new points, got shape "
                f"{new_points.shape}")
        k = new_points.shape[-2]
        if k < 1:
            raise ValueError("update needs at least one new point")
        L = self._concrete_length("update")
        kc = tf.bucket_length(k, minimum=1)
        if kc > k:
            width = [(0, 0)] * new_points.ndim
            width[-2] = (0, kc - k)
            new_points = jnp.pad(new_points, width, mode="edge")
        points, prefix, inv_prefix = self.points, self.prefix, self.inv_prefix
        need = L + kc
        if need > self.capacity:
            grow = tf.bucket_length(need) - self.capacity
            pw = [(0, 0)] * points.ndim
            pw[-2] = (0, grow)
            points = jnp.pad(points, pw, mode="edge")
            sw = [(0, 0)] * prefix.ndim
            sw[-2] = (0, self._f * grow)
            prefix = jnp.pad(prefix, sw, mode="edge")
            inv_prefix = jnp.pad(inv_prefix, sw, mode="edge")
        points, prefix, inv_prefix, length = _update_kernel(
            points, prefix, inv_prefix, self.length, new_points,
            jnp.asarray(k, jnp.int32), depth=self.depth,
            lead_lag=self.transforms.lead_lag)
        out = dataclasses.replace(
            self, points=points, prefix=prefix, inv_prefix=inv_prefix,
            length=length)
        if self.retention is not None and L + k > self.retention:
            out = out.evict(before=L + k - self.retention)
        return out

    # -- eviction ------------------------------------------------------------

    def evict(self, *, before: int) -> "Path":
        """Drop ``points[:before]`` — O(remaining) group splices, no re-scan.

        The surviving prefixes are rebased through the evicted tip's group
        inverse (``Q'_k = Q_{f·e}⁻¹ ⊗ Q_{f·e+k}``, one *batched* Chen
        combine for the prefixes and one for their inverses), so not a
        single increment is re-folded — ``repro.core.dispatch.
        count_scan_steps`` reads zero across any eviction.  Queries on the
        new path are in its own coordinates (old point ``before + i`` is
        new point ``i``) and agree with a fresh build to a few ULPs.
        Buffers shrink to the new length's power-of-two bucket, releasing
        memory; at least 2 points (one increment) must survive.  Needs a
        concrete ``Path``.
        """
        if not isinstance(before, int) or isinstance(before, bool) \
                or before < 0:
            raise ValueError(
                f"evict(before=) must be a Python int >= 0, got {before!r}")
        L = self._concrete_length("evict")
        if before == 0:
            return self
        if before > L - 2:
            raise ValueError(
                f"evict(before={before}) would leave fewer than 2 of the "
                f"{L} points — a path keeps at least one increment")
        newL = L - before
        f = self._f
        C = tf.bucket_length(newL)
        points, prefix, inv_prefix, length = _evict_kernel(
            self.points, self.prefix, self.inv_prefix, self.length,
            jnp.asarray(before, jnp.int32), C=C, M=f * (C - 1), f=f,
            d=self.transformed_d, depth=self.depth)
        return dataclasses.replace(
            self, points=points, prefix=prefix, inv_prefix=inv_prefix,
            length=length)


_pytree_dataclass(Path,
                  data_fields=("points", "prefix", "inv_prefix", "length"),
                  meta_fields=("depth", "transforms", "retention"))


# ---------------------------------------------------------------------------
# coalesced (admission-batched) updates — the serving loop's hot path
# ---------------------------------------------------------------------------

def coalesced_update(paths: Sequence[Path],
                     chunks: Sequence[jax.Array]) -> List[Path]:
    """Apply one append per path as a SINGLE batched kernel call.

    All paths must share ``(capacity, d, depth, transforms)`` and be
    unbatched (``points`` of shape (C, d)) — the serving loop groups by
    exactly that key.  Chunks are padded to the group's common chunk
    bucket, paths that would overflow are grown first (outside the batch),
    and the group itself is padded to a power-of-two size with no-op
    (``k = 0``) members so the number of distinct traces stays bounded in
    the stream count.  Returns the updated paths, in order.
    """
    if len(paths) != len(chunks):
        raise ValueError(
            f"coalesced_update got {len(paths)} paths but {len(chunks)} "
            f"chunks")
    if not paths:
        return []
    p0 = paths[0]
    if p0.points.ndim != 2:
        raise ValueError(
            "coalesced_update expects unbatched paths ((C, d) points); "
            "batch them through the group axis instead")
    key0 = (p0.capacity, p0.d, p0.depth, p0.transforms)
    ks = [jnp.asarray(c).shape[-2] for c in chunks]
    kc = tf.bucket_length(max(ks), minimum=1)

    prepared_paths: List[Path] = []
    prepared_chunks: List[jax.Array] = []
    for p, c, k in zip(paths, chunks, ks):
        c = jnp.asarray(c)
        if c.ndim != 2 or c.shape[-1] != p0.d:
            raise ValueError(
                f"chunk shape {c.shape} does not match (k, {p0.d})")
        if (p.capacity, p.d, p.depth, p.transforms) != key0:
            raise ValueError(
                "coalesced_update needs a homogeneous group "
                "(capacity, d, depth, transforms); group before calling")
        if kc > k:
            c = jnp.pad(c, ((0, kc - k), (0, 0)), mode="edge")
        L = p._concrete_length("update")
        if L + kc > p.capacity:
            raise ValueError(
                f"path at length {L} cannot take a {kc}-bucket chunk within "
                f"capacity {p.capacity}; grow it first (Path.update does "
                f"this automatically)")
        prepared_paths.append(p)
        prepared_chunks.append(c)

    G = len(prepared_paths)
    Gb = tf.bucket_length(G, minimum=1)
    pad = Gb - G
    stack = lambda xs: jnp.stack(list(xs) + [xs[0]] * pad)  # noqa: E731
    points = stack([p.points for p in prepared_paths])
    prefix = stack([p.prefix for p in prepared_paths])
    inv = stack([p.inv_prefix for p in prepared_paths])
    length = stack([p.length for p in prepared_paths])
    chunk = stack(prepared_chunks)
    kvec = jnp.asarray(ks + [0] * pad, jnp.int32)          # pads are no-ops

    points, prefix, inv, length = _update_kernel(
        points, prefix, inv, length, chunk, kvec, depth=p0.depth,
        lead_lag=p0.transforms.lead_lag)
    out: List[Path] = []
    for g, p in enumerate(prepared_paths):
        new = dataclasses.replace(p, points=points[g], prefix=prefix[g],
                                  inv_prefix=inv[g], length=length[g])
        if p.retention is not None and int(length[g]) > p.retention:
            new = new.evict(before=int(length[g]) - p.retention)
        out.append(new)
    return out
