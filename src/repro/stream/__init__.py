"""Streaming signature engine: growing paths with O(1) interval queries.

``repro.stream`` holds the online half of the library: a Signatory-style
:class:`Path` whose per-prefix signature store turns every interval query
into a single Chen combine and every append into an O(chunk) extension,
plus the coalesced-update primitive the serving loop
(:mod:`repro.serve.sig_server`) batches concurrent streams through.
"""

from .path import (  # noqa: F401
    Path,
    RollingConfig,
    coalesced_update,
    reset_trace_counts,
    trace_counts,
)

__all__ = [
    "Path",
    "RollingConfig",
    "coalesced_update",
    "reset_trace_counts",
    "trace_counts",
]
