"""Class-style entry points — the stable ``repro`` surface (API v1).

KSig-shaped composable kernel objects: each class closes over a config
(:class:`TransformPipeline`, :class:`GridConfig`, a :class:`StaticKernel`
lift) and is itself a **pytree-registered frozen dataclass**, so instances
pass transparently through ``jax.jit`` / ``jax.vmap`` / ``jax.grad``
boundaries — static metadata (depth, backend, flags) partitions the trace
cache, kernel hyper-parameters (``sigma``, ``scale``, ``t0``/``t1``) stay
traceable leaves::

    import jax, repro

    sk = repro.SigKernel(static_kernel=repro.RBF(sigma=1.0),
                         transforms=repro.TransformPipeline(time_aug=True))
    K = jax.jit(sk.gram)(X)                   # bound methods jit directly
    K = jax.jit(lambda k, X: k.gram(X))(sk, X)  # or pass the object itself

The functional API (``repro.core.signature`` & co) remains the underlying
implementation; these classes add no logic beyond argument binding.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from .core.config import (GridConfig, Linear, StaticKernel,
                          TransformPipeline, _pytree_dataclass as _pytree)
from .core.features import FeatureConfig
from .core import gram as _gram
from .core import losses as _losses
from .core.logsignature import logsignature as _logsignature
from .core.signature import signature as _signature
from .core.sigkernel import sigkernel as _sigkernel


@dataclasses.dataclass(frozen=True)
class Signature:
    """Truncated path signature as a configured callable.

    ``Signature(depth, transforms=..., backend=..., stream=...)`` —
    ``__call__(path)`` maps (..., L, d) paths to flat signatures.
    ``__call__(path, lengths=...)`` treats the batch as ragged (per-path
    true point counts; see docs/api/public.md § Ragged batches).
    """

    depth: int
    transforms: TransformPipeline = TransformPipeline()
    backend: str = "auto"
    stream: bool = False

    def __call__(self, path: jax.Array, lengths=None) -> jax.Array:
        return _signature(path, self.depth, transforms=self.transforms,
                          backend=self.backend, stream=self.stream,
                          lengths=lengths)


@dataclasses.dataclass(frozen=True)
class LogSignature:
    """Truncated log-signature (Lyndon-compressed) as a configured callable."""

    depth: int
    mode: str = "lyndon"
    transforms: TransformPipeline = TransformPipeline()
    backend: str = "auto"
    stream: bool = False

    def __call__(self, path: jax.Array, lengths=None) -> jax.Array:
        return _logsignature(path, self.depth, mode=self.mode,
                             transforms=self.transforms,
                             backend=self.backend, stream=self.stream,
                             lengths=lengths)


@dataclasses.dataclass(frozen=True)
class SigKernel:
    """Signature kernel with a swappable static-kernel lift.

    ``SigKernel(static_kernel=Linear()|RBF(...), transforms=...,
    grid=GridConfig(lam1, lam2), backend=...)`` exposes:

    * ``__call__(x, y)`` — k(x, y) for batched path pairs;
    * ``gram(X, Y=None, ...)`` — the Gram matrix (symmetric fast path when
      ``Y`` is omitted);
    * ``mmd2(X, Y, ...)`` / ``scoring_rule(X, y, ...)`` — the training
      losses, routed through the same engine.

    Differentiable end-to-end: the Goursat solve uses the exact one-pass
    §3.4 backward, the static-kernel Gram its (exact) autodiff.

    ``features=`` (a :class:`repro.FeatureConfig`) switches ``gram`` /
    ``mmd2`` / ``scoring_rule`` onto the approximate feature-map backends
    (``"rff"`` / ``"nystroem"``); ``error_budget=`` instead lets
    ``backend="auto"`` pick one when the autotune frontier proves it fits
    the budget.  ``__call__`` (single pair) always uses the exact solve.
    """

    static_kernel: StaticKernel = Linear()
    transforms: TransformPipeline = TransformPipeline()
    grid: GridConfig = GridConfig()
    backend: str = "auto"
    features: Optional[FeatureConfig] = None
    error_budget: Optional[float] = None

    def _kw(self):
        return dict(transforms=self.transforms, grid=self.grid,
                    static_kernel=self.static_kernel, backend=self.backend)

    def _gram_kw(self):
        return dict(self._kw(), features=self.features,
                    error_budget=self.error_budget)

    def __call__(self, x: jax.Array, y: jax.Array, *,
                 lengths_x=None, lengths_y=None) -> jax.Array:
        return _sigkernel(x, y, lengths_x=lengths_x, lengths_y=lengths_y,
                          **self._kw())

    def gram(self, X: jax.Array, Y: Optional[jax.Array] = None, *,
             row_block: Optional[int] = None,
             symmetric: Optional[bool] = None,
             lengths=None, lengths_y=None) -> jax.Array:
        return _gram.sigkernel_gram(X, Y, row_block=row_block,
                                    symmetric=symmetric, lengths=lengths,
                                    lengths_y=lengths_y, **self._gram_kw())

    def mmd2(self, X: jax.Array, Y: jax.Array, *, unbiased: bool = True,
             row_block: Optional[int] = None,
             streaming: Optional[bool] = None,
             lengths=None, lengths_y=None) -> jax.Array:
        return _losses.mmd2(X, Y, unbiased=unbiased, row_block=row_block,
                            streaming=streaming,
                            lengths=lengths, lengths_y=lengths_y,
                            **self._gram_kw())

    def scoring_rule(self, X: jax.Array, y: jax.Array, *,
                     row_block: Optional[int] = None,
                     streaming: Optional[bool] = None,
                     lengths=None, length_y=None) -> jax.Array:
        return _losses.scoring_rule(X, y, row_block=row_block,
                                    streaming=streaming,
                                    lengths=lengths, length_y=length_y,
                                    **self._gram_kw())


_pytree(Signature, data_fields=("transforms",),
        meta_fields=("depth", "backend", "stream"))
_pytree(LogSignature, data_fields=("transforms",),
        meta_fields=("depth", "mode", "backend", "stream"))
_pytree(SigKernel, data_fields=("static_kernel", "transforms", "features"),
        meta_fields=("grid", "backend", "error_budget"))
