"""Trip-count-aware HLO text analysis for the dry-run roofline.

XLA's ``cost_analysis()`` and a naive scan of the HLO text both count a
while-loop body ONCE, which undercounts scanned-layer models by ~n_layers ×
n_microbatches.  This module parses the compiled HLO module text into
computations, extracts per-computation collective traffic and dot FLOPs, and
aggregates through the while-loop call graph using parsed trip counts.

Heuristics (documented in EXPERIMENTS.md §Roofline):
* trip count of a while loop = the integer constant compared against the
  induction variable in its condition computation (max constant if several);
* per-device link traffic (ring estimates, result shape R, group size n):
    all-gather        R·(n-1)/n     reduce-scatter  R·(n-1)
    all-reduce        2·R·(n-1)/n   all-to-all      R·(n-1)/n
    collective-permute R
* dot FLOPs = 2 · |result| · |contracting dims of lhs|;
* elementwise arithmetic FLOPs (``arith_flops``) = |result| per elementwise
  op (transcendentals counted once, like XLA's cost model) — the dominant
  term for the scan-heavy Goursat PDE kernels, whose wavefront updates are
  VPU adds/multiplies with almost no dots.  Both counts aggregate through
  while-loop trip counts identically.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

#: elementwise HLO opcodes counted as one arithmetic FLOP per result
#: element (matching XLA's cost model: transcendentals are 1, fused
#: multiply-adds appear as separate multiply + add instructions)
ELEMENTWISE_OPS = frozenset((
    "add", "subtract", "multiply", "divide", "power", "negate", "abs",
    "maximum", "minimum", "exponential", "exponential-minus-one", "log",
    "log-plus-one", "sqrt", "rsqrt", "cbrt", "tanh", "sine", "cosine",
    "atan2", "logistic", "remainder", "round-nearest-afz",
    "round-nearest-even", "floor", "ceil", "sign", "erf", "expm1", "log1p",
))

#: opcode position: "<shape> <opcode>(" right after the result shape
_OPCODE_RE = re.compile(
    r"^\(?[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+([a-z][\w\-]*)\(")

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"^\(?([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: List[int]) -> int:
    return _DTYPE_BYTES.get(dtype, 4) * (math.prod(dims) if dims else 1)


def _parse_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.match(text.strip())
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation headers sit at column 0 and end with '{'; bodies indented."""
    comps: Dict[str, List[str]] = {}
    cur, body = None, []
    for line in hlo.splitlines():
        stripped = line.rstrip()
        if cur is None:
            if (stripped and not line[0].isspace()
                    and stripped.endswith("{") and "(" in stripped):
                head = stripped
                is_entry = head.startswith("ENTRY")
                if is_entry:
                    head = head[len("ENTRY"):].strip()
                name = head.split("(", 1)[0].strip().lstrip("%").rstrip()
                name = name.split()[0] if name else name
                cur = name
                body = []
                comps[cur] = body
                if is_entry:
                    comps["__entry__"] = body
        else:
            if stripped == "}" and not line[0].isspace():
                cur = None
            elif stripped.strip() == "}":
                cur = None
            else:
                body.append(line)
    return comps


def _group_size(line: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)  # iota form [G,N]
    if m:
        return int(m.group(2))
    return default


class HloStats:
    def __init__(self):
        self.flops = 0.0          # dot (MXU) flops
        self.arith_flops = 0.0    # elementwise (VPU) flops
        self.collective: Dict[str, Dict[str, float]] = {
            c: {"count": 0.0, "out_bytes": 0.0, "traffic": 0.0}
            for c in COLLECTIVES}

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.arith_flops += other.arith_flops * mult
        for c in COLLECTIVES:
            for k in self.collective[c]:
                self.collective[c][k] += other.collective[c][k] * mult

    @property
    def total_flops(self) -> float:
        """Dot + elementwise FLOPs — what a roofline compute term wants."""
        return self.flops + self.arith_flops

    @property
    def total_traffic(self) -> float:
        return sum(c["traffic"] for c in self.collective.values())

    def to_dict(self):
        return {"flops": self.flops, "arith_flops": self.arith_flops,
                "total_flops": self.total_flops,
                "collectives": self.collective,
                "total_traffic": self.total_traffic}


def analyze(hlo: str) -> HloStats:
    comps = split_computations(hlo)
    shapes: Dict[str, Dict[str, Tuple[str, List[int]]]] = {}
    for name, lines in comps.items():
        tbl = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            sh = _parse_shape(m.group(2))
            if sh:
                tbl[m.group(1).lstrip("%")] = sh
        shapes[name] = tbl

    def trip_count(cond_name: str) -> int:
        consts = []
        for line in comps.get(cond_name, []):
            for c in re.findall(r"constant\((\d+)\)", line):
                consts.append(int(c))
        return max(consts) if consts else 1

    local_cache: Dict[str, HloStats] = {}

    def local_stats(name: str) -> Tuple[HloStats, List[Tuple[str, int]]]:
        st = HloStats()
        calls: List[Tuple[str, int]] = []
        tbl = shapes.get(name, {})
        for line in comps.get(name, []):
            s = line.strip()
            m = _DEF_RE.match(s)
            if not m:
                continue
            rhs = m.group(2)
            sh = _parse_shape(rhs)
            # while loops
            wm = re.search(r"while\(", rhs)
            if wm:
                bm = re.search(r"body=(%?[\w.\-]+)", rhs)
                cm = re.search(r"condition=(%?[\w.\-]+)", rhs)
                if bm and cm:
                    calls.append((bm.group(1).lstrip("%"),
                                  trip_count(cm.group(1).lstrip("%"))))
                continue
            # nested calls / fusions / conditionals: count once
            for cm in re.finditer(
                    r"(?:calls=|to_apply=|fusion\(|branch_computations=\{)"
                    r"(%?[\w.\-]+)", rhs):
                callee = cm.group(1).lstrip("%")
                if callee in comps:
                    calls.append((callee, 1))
            # collectives
            for c in COLLECTIVES:
                if re.search(rf"(?:^|\s){c}(?:-start)?\(", rhs):
                    if sh is None:
                        break
                    dtype, dims = sh
                    nbytes = _shape_bytes(dtype, dims)
                    n = _group_size(s)
                    if c == "all-gather":
                        tr = nbytes * (n - 1) / max(n, 1)
                    elif c == "reduce-scatter":
                        tr = nbytes * (n - 1)
                    elif c == "all-reduce":
                        tr = 2 * nbytes * (n - 1) / max(n, 1)
                    elif c == "all-to-all":
                        tr = nbytes * (n - 1) / max(n, 1)
                    else:
                        tr = float(nbytes)
                    st.collective[c]["count"] += 1
                    st.collective[c]["out_bytes"] += float(nbytes)
                    st.collective[c]["traffic"] += float(tr)
                    break
            # elementwise arithmetic flops (one per result element)
            om = _OPCODE_RE.match(rhs)
            if om and om.group(1) in ELEMENTWISE_OPS and sh is not None:
                st.arith_flops += float(math.prod(sh[1]) if sh[1] else 1)
                continue
            # dot flops
            if re.search(r"\sdot\(", rhs) and sh is not None:
                dtype, dims = sh
                res = math.prod(dims) if dims else 1
                ld = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                k = 1
                if ld:
                    inner = rhs.split("dot(", 1)[1]
                    # lhs operand either carries an inline shape prefix
                    # ("f32[256,256]{1,0} %name") or is a bare name whose
                    # shape the definition table knows
                    lsh = _parse_shape(inner)
                    if lsh is None:
                        opm = re.match(r"\s*(%?[\w.\-]+)", inner)
                        lsh = tbl.get(opm.group(1).lstrip("%")) if opm \
                            else None
                    if lsh:
                        for d in ld.group(1).split(","):
                            if d:
                                k *= lsh[1][int(d)] if int(d) < len(lsh[1]) else 1
                st.flops += 2.0 * res * k
        return st, calls

    memo: Dict[str, HloStats] = {}
    visiting = set()

    def total(name: str) -> HloStats:
        if name in memo:
            return memo[name]
        if name in visiting:
            return HloStats()
        visiting.add(name)
        st, calls = local_stats(name)
        agg = HloStats()
        agg.add(st)
        for callee, mult in calls:
            agg.add(total(callee), mult)
        visiting.discard(name)
        memo[name] = agg
        return agg

    entry = "__entry__" if "__entry__" in comps else next(iter(comps))
    return total(entry)
