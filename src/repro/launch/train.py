"""Training driver: mesh setup, sharded train loop, fault tolerance.

Fault-tolerance features (design scales to 1000+ nodes; see README):
* atomic async checkpoints every --ckpt-every steps (CheckpointManager),
* SIGTERM/SIGINT preemption hook -> final synchronous checkpoint,
* heartbeat file per process each step -> external watchdog
  (``launch/watchdog.py``) detects stragglers/hangs and restarts,
* stateless step-indexed data -> exact resume from any step,
* elastic restore: a checkpoint written on one mesh restores onto another
  (params are re-device_put against the new shardings).

Usage (CPU smoke):
    python -m repro.launch.train --arch deepseek-7b --reduced --steps 20
"""

from __future__ import annotations

import argparse
import json
import math
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_config, build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step, apply_param_dtype
from repro.parallel import sharding as SH
from repro.parallel.api import logical_rules
from repro.checkpoint.manager import CheckpointManager
from repro.data.synthetic import TokenLM
from repro.launch.mesh import make_production_mesh, make_host_mesh


def heartbeat(path: str, step: int):
    with open(path, "w") as f:
        json.dump({"step": step, "time": time.time(),
                   "process": jax.process_index()}, f)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--sig-loss", action="store_true",
                    help="attach the signature-kernel auxiliary loss")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.sig_loss:
        cfg = cfg.replace(sig_loss=True)

    multi_pod = args.mesh == "multipod"
    mesh = (make_production_mesh(multi_pod=multi_pod)
            if args.mesh != "host" else make_host_mesh())
    rules = SH.rules_for(cfg, multi_pod)

    model = build_model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, min(100, args.steps // 10 + 1),
                                   args.steps),
                moment_dtype=cfg.moment_dtype)

    data = TokenLM(vocab=cfg.vocab, seq=args.seq, batch=args.batch,
                   n_patches=cfg.n_patches if cfg.family == "vlm" else 0,
                   n_frames=cfg.n_audio_frames if cfg.family == "encdec" else 0,
                   d_model=cfg.d_model,
                   sig_target_dim=cfg.sig_loss_dim if cfg.sig_loss else 0)

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(model.init, key)
    p_shard = SH.param_shardings(params_shape, cfg, mesh, multi_pod)
    opt_shape = jax.eval_shape(opt.init, params_shape)
    o_shard = SH.param_shardings(opt_shape, cfg, mesh, multi_pod)
    p_pspecs = jax.tree.map(lambda s: s.spec, p_shard)

    step_fn = make_train_step(model, opt, num_microbatches=args.microbatches,
                              param_pspecs=p_pspecs)
    jit_step = jax.jit(step_fn, in_shardings=(p_shard, o_shard, None),
                       out_shardings=(p_shard, o_shard, None),
                       donate_argnums=(0, 1))
    jit_init = jax.jit(model.init, out_shardings=p_shard)
    jit_opt_init = jax.jit(opt.init, out_shardings=o_shard)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    with mesh, logical_rules(rules):
        params = apply_param_dtype(jit_init(key), cfg)
        opt_state = jit_opt_init(params)
        if ckpt and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            (params, opt_state), _ = ckpt.restore(
                s, (params, opt_state), (p_shard, o_shard))
            start_step = s
            print(f"resumed from step {s}")

        # preemption hook: checkpoint synchronously, then exit
        state = {"params": params, "opt": opt_state, "step": start_step}

        def on_term(signum, frame):
            print(f"signal {signum}: writing preemption checkpoint", flush=True)
            if ckpt:
                ckpt.save(state["step"], (state["params"], state["opt"]),
                          blocking=True)
            sys.exit(0)

        signal.signal(signal.SIGTERM, on_term)

        t_last, losses = time.time(), []
        for step in range(start_step, args.steps):
            batch = data.batch_at(step)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            state.update(params=params, opt=opt_state, step=step + 1)
            losses.append(metrics["loss"])
            if args.heartbeat:
                heartbeat(args.heartbeat, step)
            if (step + 1) % args.log_every == 0:
                losses = [float(x) for x in losses]
                dt = time.time() - t_last
                print(f"step {step+1:5d}  loss {np.mean(losses):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"{dt/args.log_every*1e3:.0f} ms/step", flush=True)
                t_last, losses = time.time(), []
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state))
        if ckpt:
            ckpt.save(args.steps, (params, opt_state), blocking=True)
    print("done")


if __name__ == "__main__":
    main()
