"""Mesh construction — production pods, Gram meshes, and simulated hosts.

Everything here is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.

The simulated-mesh helpers (:func:`host_device_flags`,
:func:`simulated_mesh_env`) exist because XLA's host-platform device count
is fixed at backend initialisation: a process that wants N fake CPU devices
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before*
jax initialises.  Tests and benches therefore spawn subprocesses with the
env these helpers build (see ``tests/conftest.py`` — the ``simulated_mesh``
fixture — and the ``multidevice`` CI job).
"""

from __future__ import annotations

import math
import os
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

#: the XLA flag that fakes N host (CPU) devices in one process
HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 single pod (16, 16) or 2 pods = 512 chips (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def gram_mesh_shape(n_devices: int) -> Tuple[int, int]:
    """Near-square ``(data, model)`` factorisation of ``n_devices``.

    The Gram engine tiles rows over ``data`` and columns over ``model``; a
    square-ish mesh minimises the replicated stream bytes per device
    (each device holds Bx/nd rows + By/nm columns of prepared streams).
    The larger factor goes to ``data`` — row tiles dominate when the
    symmetric fast path is active.  1 -> (1,1), 4 -> (2,2), 8 -> (4,2),
    12 -> (4,3), primes -> (p, 1).
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    best = 1
    for f in range(1, int(math.isqrt(n_devices)) + 1):
        if n_devices % f == 0:
            best = f
    return (n_devices // best, best)


def make_gram_mesh(n_devices: Optional[int] = None, *,
                   devices: Optional[Sequence] = None,
                   axis_names: Tuple[str, str] = ("data", "model")) -> Mesh:
    """A ``(data, model)`` mesh for the sharded Gram engine.

    Uses the first ``n_devices`` of ``devices`` (default: all local
    devices) arranged by :func:`gram_mesh_shape`.  Built from an explicit
    device array rather than :func:`jax.make_mesh` so *sub*-meshes over a
    device subset work — that is what lets one 8-device process prove
    1-vs-4-vs-8 shard-count invariance (see tests/test_distributed_gram.py).
    """
    if devices is None:
        devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    if n_devices > len(devices):
        raise ValueError(
            f"asked for {n_devices} devices, only {len(devices)} available"
            f" — spawn with XLA_FLAGS={HOST_DEVICE_FLAG}={n_devices} to "
            "simulate a host mesh (docs/api/public.md § Distributed Grams)")
    shape = gram_mesh_shape(n_devices)
    return Mesh(np.asarray(devices[:n_devices]).reshape(shape), axis_names)


def host_device_flags(n_devices: int = 8,
                      base: Optional[str] = None) -> str:
    """An ``XLA_FLAGS`` value forcing ``n_devices`` simulated host devices.

    Preserves every other flag already present in ``base`` (default: the
    current ``XLA_FLAGS``), replacing any existing
    ``--xla_force_host_platform_device_count`` — so callers can layer the
    simulated mesh on top of whatever XLA config the environment carries.
    """
    if base is None:
        base = os.environ.get("XLA_FLAGS", "")
    kept = [f for f in base.split()
            if not f.startswith(HOST_DEVICE_FLAG + "=")]
    kept.append(f"{HOST_DEVICE_FLAG}={int(n_devices)}")
    return " ".join(kept)


def simulated_mesh_env(n_devices: int = 8, env=None) -> dict:
    """Environment dict for a subprocess that should see ``n_devices``
    simulated host devices (a copy — the caller's env is never mutated)."""
    out = dict(os.environ if env is None else env)
    out["XLA_FLAGS"] = host_device_flags(n_devices, out.get("XLA_FLAGS", ""))
    return out
