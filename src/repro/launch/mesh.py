"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 single pod (16, 16) or 2 pods = 512 chips (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh over the local device (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
