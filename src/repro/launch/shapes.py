"""Assigned input-shape presets and per-cell input ShapeDtypeStructs."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.api import padded_vocab


@dataclasses.dataclass(frozen=True)
class ShapePreset:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapePreset("train_4k", "train", 4096, 256),
    "prefill_32k": ShapePreset("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapePreset("decode_32k", "decode", 32768, 128),
    "long_500k": ShapePreset("long_500k", "decode", 524288, 1),
}

# the paper's own workloads (pySigLib Table 2 scaled to pod size):
# sig_gram — forward Gram of 4096×4096 path pairs, L=1024, d=8 (MMD eval /
#            hypothesis testing); sig_mmd_train — differentiated MMD with the
#            exact one-pass backward (512×512 pairs, L=256).
SIG_SHAPES = {
    "sig_gram": ShapePreset("sig_gram", "sig_fwd", 1024, 4096),
    "sig_mmd_train": ShapePreset("sig_mmd_train", "sig_train", 256, 512),
}


def cell_supported(cfg, shape: ShapePreset) -> Optional[str]:
    """None if supported, else the skip reason (recorded in EXPERIMENTS.md)."""
    if cfg.family == "sigkernel":
        if shape.kind not in ("sig_fwd", "sig_train"):
            return "LM shapes do not apply to the sig-kernel workload"
        return None
    if shape.kind in ("sig_fwd", "sig_train"):
        return "sig shapes apply only to the sigkernel-workload arch"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention family: 500k decode requires sub-quadratic "
                "sequence mixing (run for ssm/hybrid only, per spec)")
    return None


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_input_specs(cfg, shape: ShapePreset) -> Dict:
    B, S = shape.batch, shape.seq
    batch = {"tokens": _i32(B, S), "labels": _i32(B, S)}
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, 1024),
                                                jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    if cfg.sig_loss:
        batch["sig_target"] = jax.ShapeDtypeStruct((B, 32, cfg.sig_loss_dim),
                                                   jnp.float32)
    return batch


def prefill_input_specs(cfg, shape: ShapePreset) -> Dict:
    B, S = shape.batch, shape.seq
    batch = {"tokens": _i32(B, S)}
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, 1024),
                                                jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return batch


def decode_input_specs(cfg, shape: ShapePreset, cache_shape) -> Dict:
    B = shape.batch
    return {"caches": cache_shape, "tokens": _i32(B, 1),
            "cur_len": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_shape_for(model, cfg, shape: ShapePreset):
    """Abstract cache pytree for a decode cell (no allocation)."""
    params_shape = jax.eval_shape(
        model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return jax.eval_shape(
        lambda p: model.cache_init(p, shape.batch, shape.seq), params_shape)


def microbatch_policy(cfg, gb: int, batch_shard: int) -> int:
    """Number of sequential microbatches for the train step."""
    if cfg.d_model >= 6000:
        target = 1            # >=30B-class: one sequence per device
    elif cfg.family == "encdec":
        target = 2            # enc-dec holds encoder + decoder activations
    elif cfg.d_model >= 2000:
        target = 4
    else:
        target = 8
    n_mb = max(1, gb // max(batch_shard * target, 1))
    while gb % n_mb or (gb // n_mb) % batch_shard:
        n_mb -= 1
    return max(n_mb, 1)
