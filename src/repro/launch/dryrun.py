import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without real hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Records memory_analysis / cost_analysis / per-collective byte counts for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k [--multipod]
    python -m repro.launch.dryrun --all --out dryrun_results.json
"""

import argparse
import json
import re
import sys
import time
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import get_config, build_model
from repro.models.config import ArchConfig
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.step import make_train_step
from repro.serve.step import make_prefill_step, make_decode_step
from repro.parallel import sharding as SH
from repro.parallel.api import logical_rules
from repro.launch.mesh import make_production_mesh
from repro.launch import shapes as SHP

from repro.launch.hlo_analysis import analyze as analyze_hlo


def build_sig_cell(shape, multi_pod: bool):
    """Dry-run cells for the paper's own workload: pod-scale sig-kernel Gram
    (forward) and exact-gradient MMD (train).  Rows shard over data, columns
    over model — the Gram tiling from DESIGN.md §6."""
    import functools
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core.gram import sigkernel_gram
    from repro.configs.sigkernel_workload import GRAM_ENGINE_DEFAULTS

    mesh = make_production_mesh(multi_pod=multi_pod)
    data_axes = ("pod", "data") if multi_pod else ("data",)
    L, B = shape.seq, shape.batch
    d = 8

    if shape.kind == "sig_fwd":
        # forward Gram, embarrassingly parallel: local blocked solves only
        def gram(X, Y):
            def local(Xl, Yl):
                return sigkernel_gram(Xl, Yl, **GRAM_ENGINE_DEFAULTS)
            fn = shard_map(local, mesh=mesh,
                           in_specs=(P(data_axes), P("model")),
                           out_specs=P(data_axes, "model"), check_rep=False)
            return fn(X, Y)

        X = jax.ShapeDtypeStruct((B, L, d), jnp.float32)
        Y = jax.ShapeDtypeStruct((B, L, d), jnp.float32)
        jitted = jax.jit(gram,
                         in_shardings=(NamedSharding(mesh, P(data_axes)),
                                       NamedSharding(mesh, P("model"))),
                         out_shardings=NamedSharding(mesh, P(data_axes, "model")))
        args = (X, Y)
    else:
        # differentiated MMD via the exact one-pass backward (paper §3.4)
        def mmd_grad(X, Y):
            def loss(X):
                K = sigkernel_gram(X, Y, backend="reference")
                return K.mean()
            return jax.value_and_grad(loss)(X)

        X = jax.ShapeDtypeStruct((B, L, d), jnp.float32)
        Y = jax.ShapeDtypeStruct((B, L, d), jnp.float32)
        jitted = jax.jit(mmd_grad,
                         in_shardings=(NamedSharding(mesh, P(data_axes)),
                                       NamedSharding(mesh, P("model"))),
                         out_shardings=(NamedSharding(mesh, P()),
                                        NamedSharding(mesh, P(data_axes))))
        args = (X, Y)
    return mesh, jitted, args, {}


def build_cell(arch: str, shape_name: str, multi_pod: bool):
    cfg = get_config(arch)
    shape = SHP.SHAPES.get(shape_name) or SHP.SIG_SHAPES[shape_name]
    skip = SHP.cell_supported(cfg, shape)
    if skip:
        return None, skip
    if cfg.family == "sigkernel":
        mesh, jitted, args, meta = build_sig_cell(shape, multi_pod)
        rules = SH.rules_for(None, multi_pod)
        return _make_runner(arch, shape_name, multi_pod, mesh, rules, jitted,
                            args, meta), None
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = SH.rules_for(cfg, multi_pod)
    model = build_model(cfg)
    key_struct = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params_shape = jax.eval_shape(model.init, key_struct)
    if shape.kind == "train":
        from repro.train.step import apply_param_dtype
        params_shape = apply_param_dtype(params_shape, cfg)
    p_shard = SH.param_shardings(params_shape, cfg, mesh, multi_pod)

    if shape.kind == "train":
        opt = AdamW(lr=cosine_schedule(3e-4, 100, 10000),
                    moment_dtype=cfg.moment_dtype)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        o_shard = SH.param_shardings(opt_shape, cfg, mesh, multi_pod)
        batch_spec = SHP.train_input_specs(cfg, shape)
        b_shard = SH.batch_shardings(batch_spec, cfg, mesh, multi_pod)
        bsz = shape.batch
        # batch shard size for the microbatch policy
        bspec = SH.physical_spec(("batch",), (bsz,), mesh, rules)
        import math as _math
        ax = bspec[0]
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        bshard = _math.prod(mesh.shape[a] for a in axes) if axes else 1
        n_mb = SHP.microbatch_policy(cfg, bsz, bshard)
        p_pspecs = jax.tree.map(lambda s: s.spec, p_shard)
        # bf16-master models also accumulate gradients in bf16 (§Perf)
        accum = "bfloat16" if cfg.param_dtype == "bfloat16" else "float32"
        step = make_train_step(model, opt, num_microbatches=n_mb,
                               param_pspecs=p_pspecs, accum_dtype=accum)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        args = (params_shape, opt_shape, batch_spec)
        meta = {"num_microbatches": n_mb, "batch_shard": bshard}
    elif shape.kind == "prefill":
        step = make_prefill_step(model)
        batch_spec = SHP.prefill_input_specs(cfg, shape)
        b_shard = SH.batch_shardings(batch_spec, cfg, mesh, multi_pod)
        cache_shape = jax.eval_shape(lambda p, b: step(p, b)[1],
                                     params_shape, batch_spec)
        c_shard = SH.cache_shardings(cache_shape, cfg, mesh, multi_pod)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, c_shard))
        args = (params_shape, batch_spec)
        meta = {}
    else:  # decode
        step = make_decode_step(model)
        cache_shape = SHP.cache_shape_for(model, cfg, shape)
        c_shard = SH.cache_shardings(cache_shape, cfg, mesh, multi_pod)
        spec = SHP.decode_input_specs(cfg, shape, cache_shape)
        tok_shard = SH.batch_shardings({"tokens": spec["tokens"]},
                                       cfg, mesh, multi_pod)["tokens"]
        jitted = jax.jit(step, in_shardings=(p_shard, c_shard, tok_shard,
                                             SH.replicated(mesh)),
                         out_shardings=(tok_shard, None, c_shard),
                         donate_argnums=(1,))
        args = (params_shape, cache_shape, spec["tokens"], spec["cur_len"])
        meta = {}

    return _make_runner(arch, shape_name, multi_pod, mesh, rules, jitted,
                        args, meta), None


def _make_runner(arch, shape_name, multi_pod, mesh, rules, jitted, args, meta):
    def run():
        t0 = time.time()
        with mesh:
            with logical_rules(rules):
                lowered = jitted.lower(*args)
                compiled = lowered.compile()
        t1 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older jax returns [dict] per device
            cost = cost[0] if cost else {}
        hlo = analyze_hlo(compiled.as_text())
        coll = hlo.collective
        n_chips = 512 if multi_pod else 256
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "compile_s": round(t1 - t0, 1),
            "flops": float(cost.get("flops", -1)),
            "hlo_dot_flops": float(hlo.flops),
            "hlo_bytes": float(cost.get("bytes accessed", -1)),
            "peak_bytes_per_device": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
            "collectives": coll,
            "n_chips": n_chips,
            **meta,
        }
        return result

    return run


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED
    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHP.SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
        for shape in SHP.SIG_SHAPES:           # the paper's own workload
            for mp in (False, True):
                cells.append(("sigkernel-workload", shape, mp))
    else:
        cells.append((args.arch, args.shape, args.multipod))

    results = []
    if args.out and os.path.exists(args.out):  # resume partial sweeps
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    def flush():
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    for arch, shape, mp in cells:
        mesh_name = "2x16x16" if mp else "16x16"
        if (arch, shape, mesh_name) in done:
            continue
        tag = f"{arch} x {shape} x {mesh_name}"
        try:
            run, skip = build_cell(arch, shape, mp)
            if skip:
                print(f"SKIP {tag}: {skip}", flush=True)
                results.append({"arch": arch, "shape": shape,
                                "mesh": mesh_name, "skipped": skip})
                flush()
                continue
            print(f"RUN  {tag} ...", flush=True)
            res = run()
            gb = 1 << 30
            print(f"  ok in {res['compile_s']}s  dot_flops={res['hlo_dot_flops']:.3e}  "
                  f"peak/device={res['peak_bytes_per_device']/gb:.2f}GiB  "
                  f"coll={sum(c['traffic'] for c in res['collectives'].values())/gb:.3f}GiB",
                  flush=True)
            results.append(res)
        except Exception as e:  # record failures, keep sweeping
            import traceback
            print(f"FAIL {tag}: {e}", flush=True)
            traceback.print_exc()
            results.append({"arch": arch, "shape": shape, "mesh": mesh_name,
                            "error": str(e)[:2000]})
        flush()

    if args.out:
        print(f"wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
