"""Straggler / hang watchdog for multi-process training.

Each training process writes a heartbeat file every step; this watchdog
checks staleness and (a) logs stragglers whose step lags the median by more
than ``--lag`` steps, (b) kills-and-restarts the training command when any
heartbeat is older than ``--timeout`` seconds (the checkpoint/resume path
makes restarts cheap).  On a real cluster this runs per-host under the job
manager; the logic is host-count agnostic.

    python -m repro.launch.watchdog --pattern 'hb_*.json' \
        --timeout 300 --restart-cmd 'python -m repro.launch.train ...'
"""

from __future__ import annotations

import argparse
import glob
import json
import subprocess
import sys
import time


def scan(pattern):
    beats = []
    for path in glob.glob(pattern):
        try:
            with open(path) as f:
                beats.append(json.load(f))
        except (OSError, json.JSONDecodeError):
            continue
    return beats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pattern", required=True)
    ap.add_argument("--timeout", type=float, default=300.0)
    ap.add_argument("--lag", type=int, default=5)
    ap.add_argument("--interval", type=float, default=10.0)
    ap.add_argument("--restart-cmd", default=None)
    ap.add_argument("--max-restarts", type=int, default=10)
    args = ap.parse_args(argv)

    restarts = 0
    while True:
        beats = scan(args.pattern)
        now = time.time()
        if beats:
            steps = sorted(b["step"] for b in beats)
            median = steps[len(steps) // 2]
            for b in beats:
                if median - b["step"] > args.lag:
                    print(f"STRAGGLER proc {b.get('process')} at step "
                          f"{b['step']} (median {median})", flush=True)
            stale = [b for b in beats if now - b["time"] > args.timeout]
            if stale:
                print(f"HANG detected ({len(stale)} stale heartbeats)",
                      flush=True)
                if args.restart_cmd and restarts < args.max_restarts:
                    restarts += 1
                    print(f"restart #{restarts}: {args.restart_cmd}",
                          flush=True)
                    subprocess.Popen(args.restart_cmd, shell=True)
                else:
                    sys.exit(1)
        time.sleep(args.interval)


if __name__ == "__main__":
    main()
