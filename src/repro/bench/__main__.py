"""Benchmark suite CLI.

    PYTHONPATH=src python -m repro.bench [--smoke | --quick | --full]
                                         [--repeats N] [--out BENCH_PR10.json]
                                         [--md PATH]

Runs the paper-aligned workloads (signature Table 1, sig-kernel + Gram
Table 2, log-signature Table 3, §3.4 gradient accuracy; ``--smoke`` adds
the all-backend agreement checks and the autotune round-trip), writes the
schema-versioned BENCH JSON, and prints a markdown summary.  Gate a run
against a committed baseline with ``python -m repro.bench.compare``.
"""

from __future__ import annotations

import argparse
import sys

from . import suite


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench", description=__doc__.splitlines()[0])
    mode_group = ap.add_mutually_exclusive_group()
    mode_group.add_argument("--smoke", action="store_true",
                            help="tiny CI shapes + backend agreement + "
                                 "autotune round-trip")
    mode_group.add_argument("--quick", action="store_true",
                            help="scaled-down paper cells (the default; "
                                 "the flag exists so cron jobs can say "
                                 "what they mean)")
    mode_group.add_argument("--full", action="store_true",
                            help="the paper's exact cells (slow on CPU)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats (default: 2 smoke / 3 quick / "
                         "5 full; paper methodology is 50)")
    ap.add_argument("--out", default=None,
                    help="output JSON path, or '-' to skip writing "
                         "(default: BENCH_PR10.json in --smoke mode — the "
                         "committed CI baseline — else BENCH_<mode>.json)")
    ap.add_argument("--md", default=None,
                    help="also write the markdown summary to this path")
    # tolerate (and drop) legacy `benchmarks.run` flags forwarded by the stub
    args, unknown = ap.parse_known_args(argv)
    for flag in unknown:
        print(f"ignoring unknown argument {flag!r}", file=sys.stderr)

    mode = "smoke" if args.smoke else ("full" if args.full else "quick")
    if args.out is None:
        # only smoke mode may touch the committed baseline by default —
        # quick/full documents have a different entry set and would poison
        # the CI compare job if committed accidentally
        args.out = "BENCH_PR10.json" if mode == "smoke" \
            else f"BENCH_{mode}.json"
    doc = suite.run_suite(mode, repeats=args.repeats,
                          progress=lambda m: print(m, file=sys.stderr))
    if args.out != "-":
        suite.write_json(doc, args.out)
        print(f"wrote {args.out} ({len(doc['entries'])} entries)",
              file=sys.stderr)
    md = suite.markdown_summary(doc)
    if args.md:
        with open(args.md, "w", encoding="utf-8") as f:
            f.write(md + "\n")
    print(md)
    return 0


if __name__ == "__main__":
    sys.exit(main())
