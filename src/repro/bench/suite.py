"""Run the benchmark workloads and emit a schema-versioned BENCH JSON.

The JSON document (schema 1):

``{"schema": 1, "mode": "smoke" | "quick" | "full", "repeats": int,
   "created_unix": float, "fingerprint": {...},  # timer.fingerprint()
   "entries": [ ... ]}                            # workloads entry dicts

``BENCH_PR10.json`` at the repo root is the committed baseline, produced by
``python -m repro.bench --smoke``; CI re-runs the same mode and gates on
:mod:`repro.bench.compare`.  See docs/benchmarks.md.
"""

from __future__ import annotations

import json
import time
from typing import Callable, Dict, List, Tuple

from . import timer, workloads

SCHEMA = 1

#: workloads per mode, in run order (smoke adds the CI correctness checks
#: and the autotune round-trip on top of scaled-down paper tables)
WORKLOAD_SETS: Dict[str, Tuple[Callable, ...]] = {
    "smoke": (workloads.calibration, workloads.smoke_checks,
              workloads.autotune_auto, workloads.table1_signatures,
              workloads.table2_sigkernels, workloads.rbf_lift,
              workloads.ragged_gram, workloads.distributed_gram,
              workloads.approx_frontier, workloads.scheme_frontier,
              workloads.path_update,
              workloads.table3_logsignatures, workloads.grad_accuracy),
    "quick": (workloads.calibration, workloads.table1_signatures,
              workloads.table2_sigkernels, workloads.rbf_lift,
              workloads.ragged_gram, workloads.distributed_gram,
              workloads.approx_frontier, workloads.scheme_frontier,
              workloads.path_update,
              workloads.table3_logsignatures,
              workloads.fig1_truncation_sweep, workloads.fig2_length_sweep,
              workloads.grad_accuracy),
    "full": (workloads.calibration, workloads.table1_signatures,
             workloads.table2_sigkernels, workloads.rbf_lift,
             workloads.ragged_gram, workloads.distributed_gram,
             workloads.approx_frontier, workloads.scheme_frontier,
             workloads.path_update,
             workloads.table3_logsignatures,
             workloads.fig1_truncation_sweep, workloads.fig2_length_sweep,
             workloads.grad_accuracy),
}

_DEFAULT_REPEATS = {"smoke": 2, "quick": 3, "full": 5}


def run_suite(mode: str = "quick", repeats: int = None,
              progress: Callable[[str], None] = None) -> dict:
    """Run every workload for ``mode`` and return the BENCH document."""
    if mode not in WORKLOAD_SETS:
        raise ValueError(
            f"mode must be one of {sorted(WORKLOAD_SETS)}, got {mode!r}")
    if repeats is None:
        repeats = _DEFAULT_REPEATS[mode]
    entries: List[dict] = []
    for fn in WORKLOAD_SETS[mode]:
        if progress is not None:
            progress(f"running {fn.__name__} ...")
        entries.extend(fn(mode, repeats))
    names = [e["name"] for e in entries]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:  # names are the compare join key; duplicates poison the gate
        raise RuntimeError(f"duplicate benchmark entry names: {sorted(dupes)}")
    return {
        "schema": SCHEMA,
        "mode": mode,
        "repeats": repeats,
        "created_unix": time.time(),
        "fingerprint": timer.fingerprint(),
        "entries": entries,
    }


def write_json(doc: dict, path: str) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=1, sort_keys=False)
        f.write("\n")


def load_json(path: str) -> dict:
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: not a schema-{SCHEMA} BENCH JSON "
            f"(schema={doc.get('schema') if isinstance(doc, dict) else '?'})")
    return doc


def markdown_summary(doc: dict) -> str:
    """Human-readable summary of one BENCH document."""
    fp = doc.get("fingerprint", {})
    head = [
        f"## bench — mode `{doc.get('mode')}`, repeats {doc.get('repeats')}",
        "",
        f"platform `{fp.get('platform')}` ({fp.get('device_kind')}), "
        f"jax {fp.get('jax')}, python {fp.get('python')}, "
        f"{fp.get('cpu_count')} cpus",
        "",
        "| entry | µs/call | value | notes |",
        "|---|---:|---:|---|",
    ]
    rows = []
    for e in doc["entries"]:
        us = f"{e['seconds'] * 1e6:.1f}" if e["kind"] == "time" else ""
        val = f"{e['value']:.2e}" if e["kind"] == "accuracy" else ""
        rows.append(f"| {e['name']} | {us} | {val} | {e.get('derived', '')} |")
    return "\n".join(head + rows)
