"""Roofline attribution for bench entries: achieved vs. peak FLOPs/bandwidth.

The resurrection of the seed's ``benchmarks/roofline.py``, rebuilt around
the bench suite: every timed BENCH entry carries a ``"roofline"`` dict so a
launch-parameter tuning win (or regression) can be attributed to the
compute- vs. memory-bound regime it happened in rather than guessed.

Two FLOP/byte estimators, in preference order:

* :func:`hlo_counts` — lower + compile the actual benched callable and run
  the trip-count-corrected HLO analysis of
  :mod:`repro.launch.hlo_analysis` (dot FLOPs **plus** the new elementwise
  ``arith_flops``, which dominate the scan-heavy Goursat PDE kernels);
  bytes from XLA's cost analysis with an input+output-buffer fallback.
* :func:`analytic_counts` — closed-form per-op estimates from the entry's
  ``meta`` (op, B, L, d, depth), used when no callable is available
  (checks, subprocess timings) or when lowering fails.  Documented lower
  bounds, same spirit as the seed's ``sig_model_flops``.

Peaks come from :func:`peaks`: TPU uses datasheet constants (v5e bf16 MXU
197 TFLOP/s, 819 GB/s HBM); CPU/GPU run two tiny **measured** probes once
per process (a matmul for peak FLOP/s, a copy for bandwidth) so the
achieved fractions mean something on the machine that produced the JSON.

Everything here is fail-open and non-gating: a roofline field that cannot
be computed degrades to fewer keys, never to an exception, and
``compare.py`` only ever *reports* achieved-fraction deltas.

CLI::

    PYTHONPATH=src python -m repro.bench.roofline BENCH_PR10.json

prints a markdown summary table (the CI perf-smoke artifact) and exits 0
even when entries carry no roofline data (older JSONs).
"""

from __future__ import annotations

import json
import math
import sys
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import timer

#: TPU v5e datasheet peaks (bf16 MXU FLOP/s, HBM bytes/s) — the target
#: machine of the Pallas kernels; other TPU generations are close enough
#: for bound attribution, which only needs order-of-magnitude peaks
PEAK_TPU_FLOPS = 197e12
PEAK_TPU_BW = 819e9

#: elementwise VPU flops per refined PDE cell (the 2nd-order Goursat
#: update: two poly evals in the Δ term + 3 multiply-adds)
_PDE_FLOPS_PER_CELL = 10.0

_peaks_memo: Optional[Dict[str, float]] = None


def _measured_peaks() -> Dict[str, float]:
    """Matmul + copy probes: order-of-magnitude peaks for CPU/GPU hosts."""
    n = 512
    a = jnp.full((n, n), 1.0 / n, jnp.float32)

    @jax.jit
    def mm(x):
        return x @ x

    t_mm = timer.bench(mm, a, repeats=3, warmup=1)
    flops = 2.0 * n ** 3 / max(t_mm, 1e-9)

    big = jnp.zeros((32, 1 << 20), jnp.float32)  # 128 MiB

    @jax.jit
    def cp(x):
        return x + 1.0

    t_cp = timer.bench(cp, big, repeats=3, warmup=1)
    bw = 2.0 * big.size * 4 / max(t_cp, 1e-9)  # read + write
    return {"flops": flops, "bandwidth": bw, "source": "measured"}


def peaks() -> Dict[str, float]:
    """Per-platform peak FLOP/s + bytes/s (memoised once per process)."""
    global _peaks_memo
    if _peaks_memo is None:
        try:
            if jax.default_backend() == "tpu":
                _peaks_memo = {"flops": PEAK_TPU_FLOPS,
                               "bandwidth": PEAK_TPU_BW,
                               "source": "datasheet"}
            else:
                _peaks_memo = _measured_peaks()
        except Exception:
            _peaks_memo = {"flops": 0.0, "bandwidth": 0.0,
                           "source": "unavailable"}
    return _peaks_memo


# ---------------------------------------------------------------------------
# estimators
# ---------------------------------------------------------------------------

_hlo_memo: Dict = {}


def hlo_counts(fn, *args, key=None) -> Optional[Tuple[float, float]]:
    """(flops, bytes) for ``fn(*args)`` from the compiled HLO, or None.

    FLOPs are the trip-count-corrected dot + elementwise total from
    :func:`repro.launch.hlo_analysis.analyze` — XLA's own cost analysis
    counts while-loop bodies once, which undercounts the scanned Goursat
    wavefront by ~the antidiagonal count.  Bytes prefer XLA's
    ``bytes accessed`` and fall back to input+output buffer sizes.
    Memoised on ``key`` (pass the entry's stable name + shape) because a
    lower+compile per call is the expensive part of the estimate.
    """
    if key is not None and key in _hlo_memo:
        return _hlo_memo[key]
    out: Optional[Tuple[float, float]]
    try:
        from repro.launch.hlo_analysis import analyze
        try:
            lowered = fn.lower(*args)       # already-jitted callable
            jitted = fn
        except AttributeError:
            jitted = jax.jit(fn)
            lowered = jitted.lower(*args)
        compiled = lowered.compile()
        st = analyze(compiled.as_text())
        io_bytes = 0.0
        for a in jax.tree_util.tree_leaves(args):
            if hasattr(a, "size") and hasattr(a, "dtype"):
                io_bytes += float(a.size) * jnp.dtype(a.dtype).itemsize
        for s in jax.tree_util.tree_leaves(jax.eval_shape(jitted, *args)):
            io_bytes += float(math.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        nbytes = io_bytes
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0] if cost else {}
            accessed = float(cost.get("bytes accessed", 0.0))
            nbytes = max(accessed, io_bytes)
        except Exception:
            pass
        out = (float(st.total_flops), float(nbytes))
    except Exception:
        out = None
    if key is not None:
        _hlo_memo[key] = out
    return out


def analytic_counts(name: str, meta: dict) -> Optional[Tuple[float, float]]:
    """Closed-form (flops, bytes) lower bound from an entry's meta, or None.

    Per-op models (f32 bytes; ``grad``/``bwd`` entries pay 3× — forward +
    adjoint sweep + cotangent accumulation):

    * signature / logsignature — Horner touches each of the ``sig_dim``
      signature coordinates ~3× per path step;
    * sigkernel — one Δ matmul per pair (``2·L²·d``) + ~10 VPU flops per
      refined PDE cell; bytes stream Δ three times (write + fwd + solve);
    * gram / gram_reduce — the sigkernel model × ``B²`` pairs.
    """
    op = meta.get("op")
    if not isinstance(op, str):
        if name.startswith("calibration_matmul_scan"):
            return 32 * 2.0 * 256 ** 3, 3 * 256 * 256 * 4.0
        return None
    mult = 3.0 if ("bwd" in name or "grad" in name) else 1.0
    lam = int(meta.get("lam", 0))
    bshape = meta.get("shape")
    if "L" not in meta and isinstance(bshape, (list, tuple)):
        # autotune entries carry the per-op cache-key shape instead of
        # B/L/d: sigkernel (nx, ny, d) at the fixed tuning batch, gram
        # (Bx, By, nx, ny, d) — the grid dims are already refined
        try:
            if op == "sigkernel" and len(bshape) == 3:
                nx, ny, d = bshape
                from .autotune import _TUNE_BATCH
                per = 2.0 * nx * ny * d + _PDE_FLOPS_PER_CELL * nx * ny
                return _TUNE_BATCH * per * mult, \
                    4.0 * _TUNE_BATCH * (2 * nx * d + 3 * nx * ny)
            if op == "gram" and len(bshape) == 5:
                bx, by, nx, ny, d = bshape
                per = 2.0 * nx * ny * d + _PDE_FLOPS_PER_CELL * nx * ny
                return float(bx) * by * per * mult, \
                    4.0 * ((bx + by) * nx * d + bx * by * 3 * nx * ny)
        except (TypeError, ValueError):
            return None
        return None
    try:
        if op in ("signature", "logsignature"):
            from repro.core.tensoralg import sig_dim
            B, L, d = meta["B"], meta["L"], meta["d"]
            sd = sig_dim(d, int(meta["depth"]))
            flops = 3.0 * B * L * sd * mult
            nbytes = 4.0 * B * (L * d + sd)
            return flops, nbytes
        if op in ("sigkernel", "sigkernel_grad"):
            B, L, d = meta.get("B", 4), meta["L"], meta.get("d", 3)
            n = L << lam
            per_pair = 2.0 * L * L * d + _PDE_FLOPS_PER_CELL * n * n
            nbytes = 4.0 * B * (2 * L * d + 3 * L * L)
            return B * per_pair * mult, nbytes
        if op in ("gram", "gram_reduce", "gram_sharded"):
            B, L, d = meta["B"], meta["L"], meta["d"]
            n = L << lam
            pairs = float(B) * B
            per_pair = 2.0 * L * L * d + _PDE_FLOPS_PER_CELL * n * n
            nbytes = 4.0 * (2 * B * L * d + pairs * 3 * L * L)
            return pairs * per_pair * mult, nbytes
    except (KeyError, TypeError, ValueError):
        return None
    return None


def entry_fields(flops: Optional[float], nbytes: Optional[float],
                 seconds: Optional[float], source: str) -> dict:
    """The ``"roofline"`` dict for one bench entry.

    Always contains ``peak_flops`` / ``peak_bandwidth`` / ``source``;
    adds ``flops`` / ``bytes`` / ``bound`` when an estimator produced
    counts and ``achieved_*`` / ``frac_*`` when the entry was timed.
    """
    pk = peaks()
    out: dict = {"peak_flops": pk["flops"], "peak_bandwidth": pk["bandwidth"],
                 "source": source}
    if flops is None or nbytes is None:
        return out
    out["flops"] = float(flops)
    out["bytes"] = float(nbytes)
    t_c = flops / pk["flops"] if pk["flops"] else 0.0
    t_m = nbytes / pk["bandwidth"] if pk["bandwidth"] else 0.0
    out["bound"] = "compute" if t_c >= t_m else "memory"
    if seconds and seconds > 0:
        out["achieved_flops"] = flops / seconds
        out["achieved_bandwidth"] = nbytes / seconds
        if pk["flops"]:
            out["frac_flops"] = out["achieved_flops"] / pk["flops"]
        if pk["bandwidth"]:
            out["frac_bandwidth"] = out["achieved_bandwidth"] / pk["bandwidth"]
    return out


def attach(entry: dict, fn=None, args: tuple = ()) -> dict:
    """Set ``entry["roofline"]`` in place (fail-open) and return the entry.

    With ``fn`` the HLO estimator runs first (memoised on the entry name);
    otherwise — or when lowering fails — the analytic model from the
    entry's meta applies; when even that has nothing, the dict still
    carries the platform peaks so every bench entry has roofline fields.
    """
    try:
        seconds = entry.get("seconds")
        counts = None
        source = "analytic"
        if fn is not None:
            counts = hlo_counts(fn, *args, key=entry["name"])
            if counts is not None:
                source = "hlo"
        if counts is None:
            counts = analytic_counts(entry["name"], entry.get("meta", {}))
        if counts is None:
            entry["roofline"] = entry_fields(None, None, seconds, "none")
        else:
            entry["roofline"] = entry_fields(counts[0], counts[1], seconds,
                                             source)
    except Exception:
        entry["roofline"] = {"source": "error"}
    return entry


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------

def _fmt_rate(x: Optional[float], unit: str) -> str:
    if x is None:
        return "—"
    for scale, prefix in ((1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "K")):
        if x >= scale:
            return f"{x / scale:.2f} {prefix}{unit}"
    return f"{x:.2f} {unit}"


def markdown_summary(doc: dict) -> str:
    """Roofline table over a BENCH document's timed entries."""
    fp = doc.get("fingerprint", {})
    head = [
        f"## roofline — mode `{doc.get('mode')}`, "
        f"platform `{fp.get('platform')}` ({fp.get('device_kind')})",
        "",
        "| entry | µs/call | FLOPs | achieved | frac of peak | "
        "bandwidth | frac of peak | bound | src |",
        "|---|---:|---:|---:|---:|---:|---:|---|---|",
    ]
    rows = []
    for e in doc.get("entries", []):
        if e.get("kind") != "time":
            continue
        r = e.get("roofline") or {}
        us = f"{e['seconds'] * 1e6:.1f}"
        rows.append(
            f"| {e['name']} | {us} "
            f"| {_fmt_rate(r.get('flops'), 'F')} "
            f"| {_fmt_rate(r.get('achieved_flops'), 'FLOP/s')} "
            f"| {r.get('frac_flops', 0.0) * 100:.2f}% "
            f"| {_fmt_rate(r.get('achieved_bandwidth'), 'B/s')} "
            f"| {r.get('frac_bandwidth', 0.0) * 100:.2f}% "
            f"| {r.get('bound', '—')} | {r.get('source', '—')} |")
    if not rows:
        rows = ["| (no timed entries with roofline data) | | | | | | | | |"]
    pk = peaks()
    tail = ["", f"peaks: {_fmt_rate(pk['flops'], 'FLOP/s')} compute, "
                f"{_fmt_rate(pk['bandwidth'], 'B/s')} bandwidth "
                f"({pk['source']})"]
    return "\n".join(head + rows + tail)


def main(argv=None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    if not args or args[0] in ("-h", "--help"):
        print(__doc__)
        return 0
    with open(args[0], encoding="utf-8") as f:
        doc = json.load(f)
    print(markdown_summary(doc))
    if len(args) > 1:
        with open(args[1], "w", encoding="utf-8") as f:
            f.write(markdown_summary(doc) + "\n")
        print(f"\nwrote {args[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
