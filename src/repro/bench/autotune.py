"""Measurement-driven backend selection and launch-parameter search.

:func:`tune` benchmarks every backend from :mod:`repro.core.dispatch` that
can serve an op on the current platform (TPU-only backends are skipped off
TPU — interpret mode measures nothing meaningful), then sweeps a small
bounded set of :class:`repro.core.config.LaunchConfig` candidates for the
winning backend, and persists both in an on-disk JSON cache keyed by
``(op, platform, dtype, shape-bucket)``.  :func:`lookup` /
:func:`lookup_launch` are the read side: :func:`repro.core.dispatch.resolve`
and :func:`repro.core.dispatch.resolve_launch` consult them when resolving
``"auto"`` / an unset ``launch=`` and fall back to the static shape
heuristics / library-default launch parameters whenever the answer is
``None`` (cache cold, autotuning disabled, a stale/corrupt cache file, or
— launch parameters only — a cache tuned on a different machine).

Design points:

* **Shape buckets** — batch/length-like dimensions of the key shape are
  rounded up to the next power of two, so nearby problem sizes share one
  cache entry and one tuning run; channel count and truncation depth stay
  exact (cost is exponential in depth — bucketing it would tune a
  different problem).  :func:`tune` measures at the *bucketed* shape, so
  the entry is honest for the whole bucket.
* **Lookups never time anything** — a warm cache costs one (memoised) JSON
  read per process; ``tune`` on a warm key returns the cached winner
  without running a single measurement unless ``force=True``.
* **Fail open** — a corrupted cache file, an unknown schema version, or an
  entry naming a backend that no longer exists are all treated as a cold
  cache, never an error.
* **Launch winners are machine-scoped** — tile shapes that win on one
  box (VMEM budget, cache sizes, core count) can lose on another, so every
  tuned entry is stamped with :func:`repro.bench.timer.machine_key`
  (platform | device kind | device memory) and :func:`lookup_launch`
  drops the launch parameters (never the whole entry path — fail-open to
  the library defaults) when the stamp does not match the current machine.
  Launch parameters never change the math, only the speed, so a wrong
  fallback is a performance question, not a correctness one.

Environment variables:

``REPRO_DISABLE_AUTOTUNE=1``
    Disables the cache entirely: ``lookup`` returns ``None`` (so ``auto``
    uses the static heuristics) and ``tune`` still measures when called
    explicitly but does not persist.
``REPRO_AUTOTUNE_CACHE=/path/to/cache.json``
    Overrides the cache location (default ``~/.cache/repro/autotune.json``).
"""

from __future__ import annotations

import json
import os
import tempfile
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dispatch
from . import timer

SCHEMA = 3  # v3: scheme-frontier entries ("|scheme" keys, "scheme_frontier");
#             v2 added "launch" / "launch_timings" / "machine".  Old files
#             fail open (treated as cold — _entries checks the version), so
#             a schema bump costs one re-tune, never an error.

ENV_DISABLE = "REPRO_DISABLE_AUTOTUNE"
ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_CACHE = os.path.join("~", ".cache", "repro", "autotune.json")

#: batch size tuning runners use for ops whose key shape carries no batch dim
_TUNE_BATCH = 8


def enabled() -> bool:
    """Autotuning is on unless REPRO_DISABLE_AUTOTUNE is truthy."""
    return os.environ.get(ENV_DISABLE, "").strip().lower() not in (
        "1", "true", "yes", "on")


def cache_path() -> str:
    return os.path.expanduser(os.environ.get(ENV_CACHE) or _DEFAULT_CACHE)


# ---------------------------------------------------------------------------
# keys
# ---------------------------------------------------------------------------

def bucket(shape) -> Tuple[int, ...]:
    """Round every dimension up to the next power of two (min 1)."""
    return tuple(1 if s <= 1 else 1 << (int(s) - 1).bit_length()
                 for s in shape)


#: how many leading dims of each op's key shape are batch/length-like and
#: safe to bucket to powers of two.  The trailing dims (channel count d,
#: truncation depth) stay EXACT: cost is exponential in depth and
#: polynomial of high degree in d, so bucketing them would tune a wildly
#: different problem (e.g. depth 5 -> 8 is ~d^3 more work).
_BUCKETED_DIMS = {"signature": 1, "logsignature": 1, "sigkernel": 2,
                  "gram": 4}


def key_shape(op: str, shape) -> Tuple[int, ...]:
    """Canonical (bucketed) key shape for ``op``; tuning measures this.

    The per-op meaning of ``shape`` (what the dispatch call sites pass):

    * ``signature`` / ``logsignature``: ``(L, d, depth)`` — increments per
      path, *transformed* channel count, truncation level;
    * ``sigkernel``: ``(nx, ny, d)`` — the *refined* PDE grid
      ``(Lx<<lam1, Ly<<lam2)`` and transformed channel count;
    * ``gram``: ``(Bx, By, nx, ny, d)``.
    """
    if op not in dispatch.OPS:
        raise ValueError(f"unknown op {op!r}; known: {dispatch.OPS}")
    n = _BUCKETED_DIMS[op]
    return bucket(shape[:n]) + tuple(int(s) for s in shape[n:])


def cache_key(op: str, shape, dtype="float32", *, ragged: bool = False,
              approx: bool = False, scheme: bool = False) -> str:
    """``op|platform|dtype|b1xb2x...[|ragged][|approx|scheme]`` on-disk key.

    ``ragged=True`` (variable-length ``lengths=`` workloads) is part of the
    key: the same padded shape does very different work when most of it is
    masked, so a dense winner must never shadow the ragged measurement and
    vice versa.

    ``approx=True`` keys the accuracy-vs-speed *frontier* entry
    (:func:`tune_frontier`) for the same problem.  Frontier entries answer
    a different question than exact-winner entries ("cheapest within a
    caller error budget" vs "fastest exact"), so they live under their own
    suffix and neither lookup can ever shadow the other.

    ``scheme=True`` keys the *discretisation* frontier
    (:func:`tune_scheme_frontier`): measured (scheme, coarsen,
    interior_dtype) points of the exact engine.  Same separation argument —
    it answers "cheapest exact discretisation within a budget", a third
    question with its own suffix.  ``approx`` and ``scheme`` are mutually
    exclusive.
    """
    if approx and scheme:
        raise ValueError("cache_key: approx and scheme are separate "
                         "frontiers — pass at most one")
    dims = "x".join(str(s) for s in key_shape(op, shape))
    key = f"{op}|{jax.default_backend()}|{jnp.dtype(dtype).name}|{dims}"
    if ragged:
        key += "|ragged"
    if approx:
        key += "|approx"
    if scheme:
        key += "|scheme"
    return key


# ---------------------------------------------------------------------------
# cache I/O (memoised by mtime; fail-open on anything unexpected)
# ---------------------------------------------------------------------------

_memo: Dict[str, Tuple[Optional[float], Dict]] = {}


def invalidate_memo() -> None:
    """Drop the in-process cache-file memo (tests, post-write refresh)."""
    _memo.clear()


def _entries(path: str) -> Dict[str, dict]:
    """Entries dict from ``path``; {} for missing/corrupt/stale-schema."""
    try:
        mtime: Optional[float] = os.stat(path).st_mtime
    except OSError:
        mtime = None
    hit = _memo.get(path)
    if hit is not None and hit[0] == mtime:
        return hit[1]
    entries: Dict[str, dict] = {}
    if mtime is not None:
        try:
            with open(path, encoding="utf-8") as f:
                doc = json.load(f)
            if (isinstance(doc, dict) and doc.get("schema") == SCHEMA
                    and isinstance(doc.get("entries"), dict)):
                entries = doc["entries"]
        except (OSError, ValueError):
            entries = {}
    _memo[path] = (mtime, entries)
    return entries


def _store(key: str, entry: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    entries = dict(_entries(path))
    entries[key] = entry
    doc = {"schema": SCHEMA, "entries": entries}
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               prefix=".autotune-")
    try:
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    invalidate_memo()


def cache_entry(op: str, shape, dtype="float32", *, ragged: bool = False,
                approx: bool = False, scheme: bool = False) -> Optional[dict]:
    """Full cached record (backend, timings, tuned_at) or None.

    ``approx=True`` reads the feature-map frontier entry
    (:func:`tune_frontier`), ``scheme=True`` the discretisation frontier
    (:func:`tune_scheme_frontier`), instead of the exact-winner entry.
    """
    if not enabled():
        return None
    entry = _entries(cache_path()).get(
        cache_key(op, shape, dtype, ragged=ragged, approx=approx,
                  scheme=scheme))
    return entry if isinstance(entry, dict) else None


def lookup(op: str, shape, dtype="float32", *,
           ragged: bool = False) -> Optional[str]:
    """Cached winning backend name for this key, or None (cold/disabled).

    Never runs a measurement.  The caller (``dispatch.resolve``) validates
    the name against the live registry, so stale entries degrade to the
    static heuristics rather than erroring.
    """
    entry = cache_entry(op, shape, dtype, ragged=ragged)
    if entry is None:
        return None
    name = entry.get("backend")
    return name if isinstance(name, str) else None


def lookup_launch(op: str, shape, dtype="float32", *, ragged: bool = False):
    """Cached winning :class:`LaunchConfig` for this key, or None.

    Never measures.  Returns ``None`` — the library defaults — when the
    cache is cold/disabled, when the entry predates launch sweeps (no
    ``"launch"`` field or an all-default one), when the stored dict fails
    :meth:`LaunchConfig.from_dict` validation, or when the entry's
    ``"machine"`` stamp names a different machine (tile winners do not
    travel).  Entries without a ``"machine"`` stamp are accepted: they can
    only come from a hand-written cache, and rejecting them would make the
    stamp impossible to test.
    """
    from repro.core.config import LaunchConfig
    entry = cache_entry(op, shape, dtype, ragged=ragged)
    if entry is None:
        return None
    raw = entry.get("launch")
    if not isinstance(raw, dict) or not raw:
        return None
    stamp = entry.get("machine")
    if isinstance(stamp, str) and stamp != timer.machine_key():
        return None  # tuned on another box: fail open to defaults
    try:
        launch = LaunchConfig.from_dict(raw)
    except (ValueError, TypeError):
        return None
    return None if launch.is_default else launch


# ---------------------------------------------------------------------------
# tuning
# ---------------------------------------------------------------------------

def candidates(op: str) -> Tuple[str, ...]:
    """Backends worth measuring for ``op`` on the current platform.

    Approximate feature-map backends are excluded: the exact-winner sweep
    compares like-for-like results; approximations compete on the separate
    accuracy-vs-speed frontier (:func:`tune_frontier` / ``approx=True``
    cache keys).
    """
    names = tuple(n for n in dispatch.backends_for(op)
                  if not dispatch.get(n).approximate)
    if not dispatch.on_tpu():
        names = tuple(n for n in names if not dispatch.get(n).needs_tpu)
    return names or tuple(n for n in dispatch.backends_for(op)
                          if not dispatch.get(n).approximate)


def launch_candidates(op: str, backend: str) -> Tuple:
    """Bounded :class:`LaunchConfig` sweep for ``op`` on ``backend``.

    The first candidate is always the all-default config (today's module
    constants), so a sweep can only ever match or beat the untuned
    library.  The lists are deliberately tiny — a handful of power-of-two
    tile shapes per knob — because the sweep runs per cache key and every
    candidate costs ``warmup + repeats`` full op executions.  Knobs that
    the backend ignores are not swept (the reference scan has no tiles).
    """
    from repro.core.config import LaunchConfig
    cands = [LaunchConfig()]
    if op in ("signature", "logsignature"):
        if backend == "pallas":
            cands += [LaunchConfig(sig_bt=64),
                      LaunchConfig(sig_lb=128),
                      LaunchConfig(sig_bt=64, sig_lb=128)]
    elif op == "sigkernel":
        if backend == "pallas":
            cands += [LaunchConfig(pde_strip=64), LaunchConfig(pde_strip=32)]
        elif backend == "antidiag":
            cands += [LaunchConfig(band_chunk=8), LaunchConfig(band_chunk=32)]
    elif op == "gram":
        if backend in ("pallas", "pallas_fused"):
            cands += [LaunchConfig(pde_strip=64),
                      LaunchConfig(gram_row_block=8),
                      LaunchConfig(gram_row_block=32)]
        else:
            cands += [LaunchConfig(gram_row_block=8),
                      LaunchConfig(gram_row_block=32)]
    return tuple(cands)


def _ragged_lengths(batch: int, points: int):
    """Deterministic length spread for ragged tuning runs: [~P/2, P]."""
    import numpy as np
    lo = max(2, points // 2)
    return jnp.asarray(np.linspace(lo, points, batch).round().astype("int32"))


def _ragged_points(n: int) -> int:
    """Point count for a ragged runner targeting a length-like key dim ``n``.

    Ragged call sites compute their cache-key shape *after*
    ``pad_ragged`` bucketing, so the key's length dims are already padded
    (power-of-two) sizes.  The runner must therefore build a batch whose
    padded length axis equals the key dim — ``bucket_length(n)`` points, a
    no-op re-pad — rather than the dense runner's ``n + 1`` points, which
    would re-bucket to ~2n and measure twice the workload the key denotes.
    """
    from repro.core.transforms import bucket_length
    return bucket_length(n)


def _runner(op: str, shape, dtype, backend: str, ragged: bool = False,
            launch=None):
    """Zero-arg jitted callable exercising ``op`` at the bucketed shape.

    With ``ragged=True`` the runner passes a representative ``lengths=``
    spread (half- to full-length) so the measurement reflects the masked
    variable-length workload the key denotes.  ``launch`` (a
    :class:`LaunchConfig`) is forwarded verbatim so launch sweeps measure
    exactly what :func:`lookup_launch` will later apply.
    """
    from repro.core.gram import sigkernel_gram
    from repro.core.logsignature import logsignature
    from repro.core.signature import signature
    from repro.core.sigkernel import sigkernel

    key = jax.random.PRNGKey(0)
    if op in ("signature", "logsignature"):
        L, d, depth = shape
        pts = _ragged_points(max(L, 2)) if ragged else max(L, 2) + 1
        path = (jax.random.normal(key, (_TUNE_BATCH, pts, d))
                * 0.2).astype(dtype)
        lens = _ragged_lengths(_TUNE_BATCH, pts) if ragged else None
        fn = signature if op == "signature" else logsignature
        f = jax.jit(lambda p: fn(p, depth, backend=backend, lengths=lens,
                                 launch=launch))
        return lambda: f(path)
    if op == "sigkernel":
        nx, ny, d = shape
        px = _ragged_points(nx) if ragged else nx + 1
        py = _ragged_points(ny) if ragged else ny + 1
        x = (jax.random.normal(key, (_TUNE_BATCH, px, d)) * 0.1
             ).astype(dtype)
        y = (jax.random.normal(jax.random.PRNGKey(1),
                               (_TUNE_BATCH, py, d)) * 0.1).astype(dtype)
        lx = _ragged_lengths(_TUNE_BATCH, px) if ragged else None
        ly = _ragged_lengths(_TUNE_BATCH, py) if ragged else None
        f = jax.jit(lambda a, b: sigkernel(a, b, backend=backend,
                                           lengths_x=lx, lengths_y=ly,
                                           launch=launch))
        return lambda: f(x, y)
    if op == "gram":
        Bx, By, nx, ny, d = shape
        px = _ragged_points(nx) if ragged else nx + 1
        py = _ragged_points(ny) if ragged else ny + 1
        X = (jax.random.normal(key, (Bx, px, d)) * 0.1).astype(dtype)
        Y = (jax.random.normal(jax.random.PRNGKey(1), (By, py, d)) * 0.1
             ).astype(dtype)
        lx = _ragged_lengths(Bx, px) if ragged else None
        ly = _ragged_lengths(By, py) if ragged else None
        f = jax.jit(lambda a, b: sigkernel_gram(
            a, b, backend=backend, symmetric=False,
            lengths=lx, lengths_y=ly, launch=launch))
        return lambda: f(X, Y)
    raise ValueError(f"no tuning runner for op {op!r}")


def measure(op: str, shape, dtype="float32", *, repeats: int = 3,
            warmup: int = 1, ragged: bool = False) -> Dict[str, float]:
    """Steady-state seconds per call for every candidate backend."""
    shape = key_shape(op, shape)
    return {b: timer.bench(_runner(op, shape, dtype, b, ragged),
                           repeats=repeats, warmup=warmup)
            for b in candidates(op)}


def _launch_json_key(launch) -> str:
    """Stable string key for a launch candidate in ``launch_timings``."""
    return json.dumps(launch.to_dict(), sort_keys=True)


def measure_launch(op: str, shape, dtype, backend: str, *,
                   repeats: int = 3, warmup: int = 1,
                   ragged: bool = False) -> Dict:
    """Seconds per call for every launch candidate of the chosen backend.

    Keys are :class:`LaunchConfig` instances (hashable).  A candidate that
    fails to run — e.g. a tile shape the current kernel geometry rejects —
    is skipped, never raised: the sweep must fail open to the defaults.
    """
    shape = key_shape(op, shape)
    out = {}
    for cand in launch_candidates(op, backend):
        try:
            out[cand] = timer.bench(
                _runner(op, shape, dtype, backend, ragged, cand),
                repeats=repeats, warmup=warmup)
        except Exception:
            continue
    return out


def tune(op: str, shape, dtype="float32", *, repeats: int = 3,
         warmup: int = 1, force: bool = False, ragged: bool = False,
         sweep_launch: bool = True) -> str:
    """Measure candidates, persist the winner, return its name.

    A warm cache key returns the stored winner with **zero** timed runs
    unless ``force=True``.  With autotuning disabled the measurement still
    happens (this is an explicit call) but nothing is persisted.

    With ``sweep_launch=True`` (default) the winning backend's bounded
    :func:`launch_candidates` are also measured and the fastest
    :class:`LaunchConfig` is stored under the same key (``"launch"``),
    stamped with :func:`repro.bench.timer.machine_key` so it never travels
    to a different machine.  The all-default config is always a candidate,
    so a tuned entry is never slower than the untuned library *on the
    machine that tuned it*.
    """
    from repro.core.config import LaunchConfig
    if not force:
        cached = lookup(op, shape, dtype, ragged=ragged)
        if cached is not None and cached in candidates(op):
            return cached
    times = measure(op, shape, dtype, repeats=repeats, warmup=warmup,
                    ragged=ragged)
    winner = min(times, key=times.get)
    best_launch = LaunchConfig()
    launch_times: Dict = {}
    if sweep_launch:
        launch_times = measure_launch(op, shape, dtype, winner,
                                      repeats=repeats, warmup=warmup,
                                      ragged=ragged)
        if launch_times:
            best_launch = min(launch_times, key=launch_times.get)
    if enabled():
        _store(cache_key(op, shape, dtype, ragged=ragged), {
            "backend": winner,
            "timings": times,
            "launch": best_launch.to_dict(),
            "launch_timings": {_launch_json_key(c): t
                               for c, t in launch_times.items()},
            "machine": timer.machine_key(),
            "tuned_at": time.time(),
            "repeats": repeats,
        })
    return winner


# ---------------------------------------------------------------------------
# accuracy-vs-speed frontier (approximate feature-map backends)
# ---------------------------------------------------------------------------

#: default rank sweep for frontier tuning — a few octaves, because the RFF
#: error shrinks like 1/sqrt(rank): doubling twice per point covers the
#: useful budget range without turning the sweep into a benchmark itself
_FRONTIER_RANKS = (8, 32, 128)


def _frontier_data(shape, dtype, ragged: bool):
    """Deterministic Gram inputs at the bucketed key shape (cf. _runner)."""
    Bx, By, nx, ny, d = shape
    key = jax.random.PRNGKey(0)
    px = _ragged_points(nx) if ragged else nx + 1
    py = _ragged_points(ny) if ragged else ny + 1
    X = (jax.random.normal(key, (Bx, px, d)) * 0.1).astype(dtype)
    Y = (jax.random.normal(jax.random.PRNGKey(1), (By, py, d)) * 0.1
         ).astype(dtype)
    lx = _ragged_lengths(Bx, px) if ragged else None
    ly = _ragged_lengths(By, py) if ragged else None
    return X, Y, lx, ly


def tune_frontier(op: str, shape, dtype="float32", *, ranks=_FRONTIER_RANKS,
                  repeats: int = 3, warmup: int = 1, ragged: bool = False,
                  force: bool = False) -> dict:
    """Measure the method × rank accuracy-vs-speed frontier; persist it.

    ``op`` must be ``"gram"`` — the feature maps in
    :mod:`repro.core.features` approximate Gram inner products, nothing
    else.  For every approximate backend in the registry and every rank in
    ``ranks`` this measures steady-state seconds per call and the relative
    Frobenius error against the exact engine's Gram at the *bucketed* key
    shape, plus the exact engine's own wall clock as the bar every frontier
    point must beat.  The result is stored under the ``approx=True`` cache
    key (:func:`cache_key`), machine-stamped: the seconds — both the
    "beats exact" gate and the cheapest-point ordering — only mean anything
    on the box that measured them.

    A warm key returns the stored entry with zero measurements unless
    ``force=True``; with autotuning disabled the measurement still happens
    but nothing is persisted.  A (method, rank) point that fails to run is
    skipped, never raised — an absent point can only make
    :func:`lookup_budget` more conservative.
    """
    from repro.core import features as ft
    from repro.core.gram import sigkernel_gram
    if op != "gram":
        raise ValueError(
            f"frontier tuning only supports op='gram' (got {op!r}): the "
            "feature maps approximate Gram inner products only")
    shape = key_shape(op, shape)
    key = cache_key(op, shape, dtype, ragged=ragged, approx=True)
    if not force:
        entry = _entries(cache_path()).get(key)
        if isinstance(entry, dict) and isinstance(entry.get("frontier"),
                                                  list):
            return entry
    X, Y, lx, ly = _frontier_data(shape, dtype, ragged)
    exact_backend = dispatch.resolve("auto", op="gram", shape=shape,
                                     dtype=dtype, ragged=ragged)
    f_exact = jax.jit(lambda a, b: sigkernel_gram(
        a, b, backend=exact_backend, symmetric=False,
        lengths=lx, lengths_y=ly))
    exact_seconds = timer.bench(lambda: f_exact(X, Y), repeats=repeats,
                                warmup=warmup)
    K = f_exact(X, Y)
    k_norm = max(float(jnp.linalg.norm(K)), 1e-30)
    methods = tuple(n for n in dispatch.backends_for("gram")
                    if dispatch.get(n).approximate)
    points = []
    for method in methods:
        for rank in ranks:
            feats = ft.FeatureConfig(method=method, rank=int(rank))
            f = jax.jit(lambda a, b, fc=feats: sigkernel_gram(
                a, b, features=fc, symmetric=False,
                lengths=lx, lengths_y=ly))
            try:
                Ka = jax.block_until_ready(f(X, Y))
                secs = timer.bench(lambda: f(X, Y), repeats=repeats,
                                   warmup=0)
            except Exception:
                continue  # absent point = conservative, not fatal
            rel = float(jnp.linalg.norm(Ka - K)) / k_norm
            points.append({"backend": method, "rank": int(rank),
                           "rel_err": rel, "seconds": secs})
    entry = {
        "frontier": points,
        "exact_backend": exact_backend,
        "exact_seconds": exact_seconds,
        "machine": timer.machine_key(),
        "tuned_at": time.time(),
        "repeats": repeats,
    }
    if enabled():
        _store(key, entry)
    return entry


def lookup_budget(op: str, shape, dtype="float32", error_budget=None, *,
                  ragged: bool = False) -> Optional[Tuple[str, int]]:
    """Cheapest measured frontier point fitting ``error_budget``, or None.

    Never measures.  Returns ``(backend_name, rank)`` for the fastest
    frontier point whose measured relative error is ``<= error_budget``
    *and* whose wall clock beat the exact engine's — an approximation that
    is both less accurate and slower has no reason to exist.  Fail-open on
    everything else: cold/disabled cache, malformed entry, no qualifying
    point, or a ``"machine"`` stamp naming a different box (the seconds in
    a frontier do not travel; entries without a stamp are accepted, as in
    :func:`lookup_launch`, so hand-written caches remain testable).
    """
    if error_budget is None:
        return None
    budget = float(error_budget)
    entry = cache_entry(op, shape, dtype, ragged=ragged, approx=True)
    if entry is None:
        return None
    stamp = entry.get("machine")
    if isinstance(stamp, str) and stamp != timer.machine_key():
        return None
    points = entry.get("frontier")
    exact_s = entry.get("exact_seconds")
    if not isinstance(points, list) or not isinstance(exact_s, (int, float)):
        return None
    best = None
    for p in points:
        if not isinstance(p, dict):
            continue
        try:
            name = str(p["backend"])
            rank = int(p["rank"])
            rel = float(p["rel_err"])
            secs = float(p["seconds"])
        except (KeyError, TypeError, ValueError):
            continue
        if rel <= budget and secs <= exact_s and (
                best is None or secs < best[2]):
            best = (name, rank, secs)
    return None if best is None else (best[0], best[1])


# ---------------------------------------------------------------------------
# discretisation frontier (scheme × grid coarseness × interior precision)
# ---------------------------------------------------------------------------

#: every non-default discretisation point the scheme frontier measures:
#: (scheme, coarsen, interior_dtype).  ``coarsen=1`` halves the PDE grid
#: (one dyadic level / a stride-2 path subsample) — the order-2 stencil's
#: selling point is matching order-1 accuracy on the coarser grid at ~1/4
#: the cells; bf16 interiors compose with either scheme.  The identity
#: point (order1, 0, float32) IS the baseline and is never listed.
_SCHEME_POINTS = tuple(
    (s, c, dt)
    for s in ("order1", "order2") for c in (0, 1)
    for dt in ("float32", "bfloat16")
    if (s, c, dt) != ("order1", 0, "float32"))


def tune_scheme_frontier(op: str, shape, dtype="float32", *,
                         points=_SCHEME_POINTS, repeats: int = 3,
                         warmup: int = 1, ragged: bool = False,
                         force: bool = False) -> dict:
    """Measure the (scheme, coarsen, interior_dtype) frontier; persist it.

    The exact-engine sibling of :func:`tune_frontier`: every point still
    solves the Goursat PDE — no feature maps — but with a different
    discretisation.  For each point this measures steady-state seconds per
    call and the relative Frobenius error against the order-1 fine-grid
    f32 Gram at the bucketed key shape, plus that baseline's own wall
    clock as the bar every point must beat.  ``coarsen=c`` is applied the
    way the Gram engine will replay it (stride-``2^c`` path subsampling at
    the default refinement; the engine prefers dropping dyadic levels when
    the caller's ``GridConfig`` has them).  Coarsened points are skipped
    for ragged keys — the engine cannot stride-subsample masked batches,
    so measuring them would advertise a point the lookup can never serve.

    Stored under the ``scheme=True`` cache key, machine-stamped.  Warm
    keys return the stored entry with zero measurements unless
    ``force=True``; with autotuning disabled the measurement still happens
    but nothing is persisted.  A point that fails to run is skipped, never
    raised — an absent point only makes :func:`lookup_scheme_budget` more
    conservative.
    """
    from repro.core.config import GridConfig
    from repro.core.gram import sigkernel_gram
    if op != "gram":
        raise ValueError(
            f"scheme-frontier tuning only supports op='gram' (got {op!r}): "
            "the budgeted discretisation swap lives in the Gram engine")
    shape = key_shape(op, shape)
    key = cache_key(op, shape, dtype, ragged=ragged, scheme=True)
    if not force:
        entry = _entries(cache_path()).get(key)
        if isinstance(entry, dict) and isinstance(
                entry.get("scheme_frontier"), list):
            return entry
    X, Y, lx, ly = _frontier_data(shape, dtype, ragged)
    exact_backend = dispatch.resolve("auto", op="gram", shape=shape,
                                     dtype=dtype, ragged=ragged)
    f_exact = jax.jit(lambda a, b: sigkernel_gram(
        a, b, backend=exact_backend, symmetric=False,
        lengths=lx, lengths_y=ly))
    exact_seconds = timer.bench(lambda: f_exact(X, Y), repeats=repeats,
                                warmup=warmup)
    K = f_exact(X, Y)
    k_norm = max(float(jnp.linalg.norm(K)), 1e-30)
    measured = []
    for sch, coarsen, idt in points:
        if ragged and coarsen:
            continue
        step = 1 << int(coarsen)
        Xc, Yc = X[:, ::step], Y[:, ::step]
        if Xc.shape[1] < 2 or Yc.shape[1] < 2:
            continue
        g = GridConfig(scheme=sch, interior_dtype=idt)
        f = jax.jit(lambda a, b, gc=g: sigkernel_gram(
            a, b, backend=exact_backend, symmetric=False, grid=gc,
            lengths=lx, lengths_y=ly))
        try:
            Ka = jax.block_until_ready(f(Xc, Yc))
            secs = timer.bench(lambda: f(Xc, Yc), repeats=repeats, warmup=0)
        except Exception:
            continue  # absent point = conservative, not fatal
        rel = float(jnp.linalg.norm(Ka - K)) / k_norm
        measured.append({"scheme": sch, "coarsen": int(coarsen),
                         "interior_dtype": idt, "rel_err": rel,
                         "seconds": secs})
    entry = {
        "scheme_frontier": measured,
        "exact_backend": exact_backend,
        "exact_seconds": exact_seconds,
        "machine": timer.machine_key(),
        "tuned_at": time.time(),
        "repeats": repeats,
    }
    if enabled():
        _store(key, entry)
    return entry


def lookup_scheme_budget(op: str, shape, dtype="float32", error_budget=None,
                         *, ragged: bool = False
                         ) -> Optional[Tuple[str, int, str]]:
    """Cheapest measured discretisation fitting ``error_budget``, or None.

    Never measures.  Returns ``(scheme, coarsen, interior_dtype)`` for the
    fastest :func:`tune_scheme_frontier` point whose measured relative
    error is ``<= error_budget`` *and* whose wall clock beat the order-1
    fine-grid f32 baseline — a discretisation that is both less accurate
    and slower has no reason to exist.  Fail-open on everything else,
    including a foreign ``"machine"`` stamp (seconds do not travel;
    stampless hand-written entries are accepted, as in
    :func:`lookup_launch`).
    """
    if error_budget is None:
        return None
    budget = float(error_budget)
    entry = cache_entry(op, shape, dtype, ragged=ragged, scheme=True)
    if entry is None:
        return None
    stamp = entry.get("machine")
    if isinstance(stamp, str) and stamp != timer.machine_key():
        return None
    points = entry.get("scheme_frontier")
    exact_s = entry.get("exact_seconds")
    if not isinstance(points, list) or not isinstance(exact_s, (int, float)):
        return None
    best = None
    for p in points:
        if not isinstance(p, dict):
            continue
        try:
            sch = str(p["scheme"])
            coarsen = int(p["coarsen"])
            idt = str(p["interior_dtype"])
            rel = float(p["rel_err"])
            secs = float(p["seconds"])
        except (KeyError, TypeError, ValueError):
            continue
        if rel <= budget and secs <= exact_s and (
                best is None or secs < best[3]):
            best = (sch, coarsen, idt, secs)
    return None if best is None else (best[0], best[1], best[2])
