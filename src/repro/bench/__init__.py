"""Persistent benchmark + autotuning subsystem.

The paper's core claim is raw speed, so this package makes performance a
first-class, *recorded* artifact instead of a side effect:

``timer``
    Steady-state timing (jit warmup + ``block_until_ready``, min over
    repeats — paper §5 methodology) and a machine fingerprint.
``autotune``
    Measures every capable backend from :mod:`repro.core.dispatch` for a
    given (op, shape, dtype, platform) key — and, per winning backend, a
    small bounded sweep of :class:`repro.LaunchConfig` launch parameters —
    caches the winners in an on-disk JSON cache, and backs
    ``backend="auto"`` (plus ``launch=None`` resolution) when the cache is
    warm.
``roofline``
    Achieved vs. peak FLOPs/bandwidth attribution for every bench entry
    (HLO-derived counts via :mod:`repro.launch.hlo_analysis` where cheap,
    analytic per-op models otherwise).  CLI:
    ``python -m repro.bench.roofline BENCH_PR10.json``.
``workloads``
    The paper-aligned workload cells (signature Table 1, sig-kernel Table 2
    + Gram rows, log-signature Table 3, §3.4 gradient accuracy) at smoke /
    quick / full sizes, plus the CI smoke checks.
``suite``
    Runs a set of workloads and emits a schema-versioned BENCH JSON
    (``BENCH_PR10.json`` at the repo root is the committed baseline) and a
    markdown summary.  CLI: ``python -m repro.bench [--smoke|--full]``.
``compare``
    Diffs two BENCH JSONs with machine-speed normalisation and per-entry
    tolerances; exits nonzero on regression.  CLI:
    ``python -m repro.bench.compare OLD NEW``.

See docs/benchmarks.md for the JSON schema and the CI perf gate.
"""

import importlib

__all__ = ["autotune", "compare", "roofline", "suite", "timer", "workloads"]


def __getattr__(name):
    # lazy submodule access (PEP 562): keeps `import repro.bench` light and
    # avoids runpy's double-import warning for `python -m repro.bench.compare`
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
