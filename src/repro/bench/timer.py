"""Steady-state timing and machine fingerprinting.

``bench`` is the paper §5 methodology: jit-warm the callable, then take the
minimum wall time over ``repeats`` runs with ``jax.block_until_ready`` so
async dispatch never hides work.  The paper takes min-over-50; CPU callers
default to far fewer to keep suites fast — pass ``repeats=50`` for
paper-exact numbers.

``fingerprint`` records enough about the machine that a committed BENCH
JSON can be compared against a run from a different box with eyes open
(compare.py normalises away uniform machine-speed differences; the
fingerprint is for humans reading the artifact).

``machine_key`` is the compact subset of the fingerprint that launch
parameters actually depend on (platform, device kind, device memory):
the autotune cache stamps it into every tuned entry so persisted
launch-parameter winners are dropped — fail-open, back to the library
defaults — when the cache file moves between machines.
"""

from __future__ import annotations

import os
import platform as _platform
import time
from typing import Dict

import jax
import numpy as np


def bench(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Minimum wall time (seconds) of ``fn(*args)`` over ``repeats`` runs.

    ``warmup`` untimed calls first absorb jit compilation; every timed call
    is fenced with ``jax.block_until_ready``.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def device_memory_bytes() -> int:
    """Accelerator (or host, on CPU backends) memory in bytes; 0 if unknown.

    Tries the device's own accounting first (``memory_stats`` — present on
    TPU/GPU and recent CPU runtimes), then the POSIX physical-memory
    sysconf.  Never raises: an unknown size reports 0, which still
    round-trips through :func:`machine_key` deterministically.
    """
    dev = jax.devices()[0]
    try:
        stats = dev.memory_stats() or {}
        limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
        if limit:
            return int(limit)
    except Exception:
        pass
    try:
        return int(os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_PHYS_PAGES"))
    except (ValueError, OSError, AttributeError):
        return 0


def fingerprint() -> Dict[str, object]:
    """Machine/runtime identity stamped into every BENCH JSON."""
    dev = jax.devices()[0]
    return {
        "platform": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "device_memory": device_memory_bytes(),
        "n_devices": jax.device_count(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": _platform.python_version(),
        "system": _platform.system(),
        "machine": _platform.machine(),
        "cpu_count": os.cpu_count(),
    }


def machine_key() -> str:
    """``platform|device_kind|device_memory`` — the part of the fingerprint
    launch parameters depend on.  Stamped into tuned autotune-cache entries;
    a mismatch at lookup time drops the entry's launch parameters
    (fail-open) instead of applying tiles sized for another machine."""
    dev = jax.devices()[0]
    return "|".join((
        str(jax.default_backend()),
        str(getattr(dev, "device_kind", "unknown")),
        str(device_memory_bytes()),
    ))
