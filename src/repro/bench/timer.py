"""Steady-state timing and machine fingerprinting.

``bench`` is the paper §5 methodology: jit-warm the callable, then take the
minimum wall time over ``repeats`` runs with ``jax.block_until_ready`` so
async dispatch never hides work.  The paper takes min-over-50; CPU callers
default to far fewer to keep suites fast — pass ``repeats=50`` for
paper-exact numbers.

``fingerprint`` records enough about the machine that a committed BENCH
JSON can be compared against a run from a different box with eyes open
(compare.py normalises away uniform machine-speed differences; the
fingerprint is for humans reading the artifact).
"""

from __future__ import annotations

import os
import platform as _platform
import time
from typing import Dict

import jax
import numpy as np


def bench(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Minimum wall time (seconds) of ``fn(*args)`` over ``repeats`` runs.

    ``warmup`` untimed calls first absorb jit compilation; every timed call
    is fenced with ``jax.block_until_ready``.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def fingerprint() -> Dict[str, object]:
    """Machine/runtime identity stamped into every BENCH JSON."""
    dev = jax.devices()[0]
    return {
        "platform": jax.default_backend(),
        "device_kind": getattr(dev, "device_kind", "unknown"),
        "n_devices": jax.device_count(),
        "jax": jax.__version__,
        "numpy": np.__version__,
        "python": _platform.python_version(),
        "system": _platform.system(),
        "machine": _platform.machine(),
        "cpu_count": os.cpu_count(),
    }
