"""Paper-aligned benchmark workloads at smoke / quick / full sizes.

Every workload returns a list of *entries* — plain dicts the suite
serialises into the BENCH JSON:

``{"name": str, "kind": "time" | "accuracy" | "check",
   "seconds": float,          # kind == "time"
   "value": float,            # kind == "accuracy" (relative error)
   "derived": str,            # human-readable extras
   "meta": {...}}             # shape/op context; meta["gate"] = False
                              # excludes an entry from the CI perf gate

Names are stable across runs — :mod:`repro.bench.compare` matches entries
by name.  The cells are the paper's Tables 1–3 and the §3.4
gradient-accuracy study; ``full`` uses the paper's exact (B, L, d, N)
cells, ``quick`` scales them down but keeps every comparison intact, and
``smoke`` is the tiny CI gate.

The legacy ``benchmarks/`` scripts are thin CSV wrappers over this module.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.config import RBF, delta_from_gram
from repro.core.gram import sigkernel_gram, sigkernel_gram_reduce
from repro.core.logsignature import logsignature
from repro.core.lyndon import logsig_dim
from repro.core.signature import signature, signature_direct
from repro.core.sigkernel import (delta_matrix, sigkernel, solve_goursat,
                                  solve_goursat_antidiag, solve_goursat_grad,
                                  solve_goursat_grad_pde_approx)
from repro.core.tensoralg import sig_dim

from . import autotune, roofline, timer

MODES = ("smoke", "quick", "full")


def _check_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    return mode


def _t(name: str, seconds: float, derived: str = "", _fn=None, _args=(),
       **meta) -> dict:
    """Timed entry; every one carries a ``"roofline"`` dict (achieved vs.
    peak FLOPs/bandwidth).  Pass ``_fn``/``_args`` — the benched callable —
    to upgrade the analytic counts to HLO-derived ones (one extra
    lower+compile, memoised on the entry name)."""
    e = {"name": name, "kind": "time", "seconds": float(seconds),
         "derived": derived, "meta": meta}
    return roofline.attach(e, _fn, _args)


def _acc(name: str, value: float, derived: str = "", **meta) -> dict:
    e = {"name": name, "kind": "accuracy", "value": float(value),
         "derived": derived, "meta": meta}
    return roofline.attach(e)


def _chk(name: str, derived: str = "ok", **meta) -> dict:
    e = {"name": name, "kind": "check", "derived": derived, "meta": meta}
    return roofline.attach(e)


def _paths(seed: int, B: int, L: int, d: int, scale: float) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


# ---------------------------------------------------------------------------
# calibration — a fixed machine-speed probe every BENCH JSON carries, so
# compare.py can normalise away uniform box-speed differences
# ---------------------------------------------------------------------------

def calibration(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    _check_mode(mode)
    x = jnp.full((256, 256), 1.0 / 256.0, jnp.float32)

    @jax.jit
    def probe(x):
        def body(c, _):
            return c @ x, None
        c, _ = jax.lax.scan(body, x, None, length=32)
        return c.sum()

    t = timer.bench(probe, x, repeats=max(repeats, 3))
    return [_t("calibration_matmul_scan", t,
               "fixed 256x256 matmul scan (machine-speed probe)",
               _fn=probe, _args=(x,), gate=False)]


# ---------------------------------------------------------------------------
# Table 1 — truncated signatures: direct (Alg 1) vs Horner (Alg 2),
# autodiff vs time-reversed exact backward
# ---------------------------------------------------------------------------

_TABLE1_CELLS = {
    "smoke": [(4, 32, 3, 4)],
    "quick": [(16, 64, 4, 6), (16, 128, 8, 5), (16, 256, 16, 4)],
    "full": [(128, 256, 4, 6), (128, 512, 8, 5), (128, 1024, 16, 4)],
}


def table1_signatures(mode: str = "quick", repeats: int = 5) -> List[dict]:
    entries = []
    for (B, L, d, N) in _TABLE1_CELLS[_check_mode(mode)]:
        path = _paths(0, B, L, d, 0.2)
        tag = f"table1_B{B}_L{L}_d{d}_N{N}"
        meta = dict(op="signature", B=B, L=L, d=d, depth=N)

        f_direct = jax.jit(lambda p: signature_direct(p, N))
        f_horner = jax.jit(lambda p: signature(p, N, backend="reference"))
        t_dir = timer.bench(f_direct, path, repeats=repeats)
        t_hor = timer.bench(f_horner, path, repeats=repeats)
        entries.append(_t(f"{tag}_fwd_direct", t_dir, **meta))
        entries.append(_t(f"{tag}_fwd_horner", t_hor,
                          f"speedup_vs_direct={t_dir / t_hor:.2f}x",
                          _fn=f_horner, _args=(path,), **meta))

        g_auto = jax.jit(jax.grad(lambda p: signature_direct(p, N).sum()))
        g_rev = jax.jit(jax.grad(
            lambda p: signature(p, N, backend="reference").sum()))
        t_ga = timer.bench(g_auto, path, repeats=repeats)
        t_gr = timer.bench(g_rev, path, repeats=repeats)
        entries.append(_t(f"{tag}_bwd_autodiff", t_ga, **meta))
        entries.append(_t(f"{tag}_bwd_timereversed", t_gr,
                          f"speedup_vs_autodiff={t_ga / t_gr:.2f}x", **meta))
    return entries


# ---------------------------------------------------------------------------
# Table 2 — signature kernels: row-scan vs wavefront forward, autodiff vs
# exact one-pass backward, plus the Gram engine through every usable backend
# ---------------------------------------------------------------------------

_TABLE2_CELLS = {
    "smoke": [(4, 16, 4)],
    "quick": [(16, 64, 8), (16, 128, 16), (8, 256, 32)],
    "full": [(128, 256, 8), (128, 512, 16), (128, 1024, 32)],
}

_GRAM_CELLS = {
    "smoke": [(4, 12, 3)],
    "quick": [(8, 32, 4)],
    "full": [(32, 128, 8)],
}


def _usable_gram_backends() -> List[str]:
    # approximate feature-map backends answer a different question (an
    # approximation of the Gram); they get their own frontier workload
    backends = [b for b in dispatch.backends_for("gram")
                if not dispatch.get(b).approximate]
    if not dispatch.on_tpu():
        # interpret-mode Pallas timings measure nothing meaningful and
        # dominate CPU wall-clock; smoke_checks covers those for correctness
        backends = [b for b in backends if not dispatch.get(b).needs_tpu]
    # reference first so the other rows can report their speedup against it
    return (["reference"] if "reference" in backends else []) + \
        [b for b in backends if b != "reference"]


def table2_sigkernels(mode: str = "quick", repeats: int = 5) -> List[dict]:
    entries = []
    for (B, L, d) in _TABLE2_CELLS[_check_mode(mode)]:
        kx = _paths(0, B, L, d, 0.1)
        ky = _paths(1, B, L, d, 0.1)
        tag = f"table2_B{B}_L{L}_d{d}"
        meta = dict(op="sigkernel", B=B, L=L, d=d)

        f_scan = jax.jit(lambda x, y: solve_goursat(delta_matrix(x, y)))
        f_wave = jax.jit(
            lambda x, y: solve_goursat_antidiag(delta_matrix(x, y)))
        t_scan = timer.bench(f_scan, kx, ky, repeats=repeats)
        t_wave = timer.bench(f_wave, kx, ky, repeats=repeats)
        entries.append(_t(f"{tag}_fwd_rowscan", t_scan, **meta))
        entries.append(_t(f"{tag}_fwd_wavefront", t_wave,
                          f"speedup_vs_rowscan={t_scan / t_wave:.2f}x",
                          _fn=f_wave, _args=(kx, ky), **meta))

        g_auto = jax.jit(jax.grad(
            lambda x, y: solve_goursat(delta_matrix(x, y)).sum()))
        g_exact = jax.jit(jax.grad(lambda x, y: sigkernel(x, y).sum()))
        t_ga = timer.bench(g_auto, kx, ky, repeats=repeats)
        t_ge = timer.bench(g_exact, kx, ky, repeats=repeats)
        entries.append(_t(f"{tag}_bwd_autodiff", t_ga, **meta))
        entries.append(_t(f"{tag}_bwd_exact_alg4", t_ge,
                          f"speedup_vs_autodiff={t_ga / t_ge:.2f}x", **meta))

    entries.extend(gram_backends(mode=mode, repeats=repeats))
    return entries


def gram_backends(mode: str = "quick", repeats: int = 5,
                  backends=None) -> List[dict]:
    """Gram engine entries: every usable backend × {dense, symmetric}."""
    if backends is None:
        backends = _usable_gram_backends()
    entries = []
    for (B, L, d) in _GRAM_CELLS[_check_mode(mode)]:
        X = _paths(2, B, L, d, 0.1)
        Y = _paths(3, B, L, d, 0.1)
        tag = f"table2_gram_B{B}_L{L}_d{d}"
        meta = dict(op="gram", B=B, L=L, d=d)
        t_ref = None
        for b in backends:
            f = jax.jit(lambda x, y, b=b: sigkernel_gram(
                x, y, backend=b, symmetric=False))
            t = timer.bench(f, X, Y, repeats=repeats)
            derived = "" if t_ref is None else \
                f"speedup_vs_reference={t_ref / t:.2f}x"
            if b == "reference":
                t_ref = t
            # HLO-derived counts for the cheap-to-lower CPU backends; the
            # interpret-mode Pallas rows fall back to the analytic model
            hlo_fn = f if b in ("reference", "antidiag") else None
            entries.append(_t(f"{tag}_dense_{b}", t, derived,
                              _fn=hlo_fn, _args=(X, Y), backend=b, **meta))
        # symmetric fast path: ~half the PDE solves of the dense Kxx
        for b in backends:
            f_sym = jax.jit(lambda x, b=b: sigkernel_gram(x, backend=b))
            t_sym = timer.bench(f_sym, X, repeats=repeats)
            entries.append(_t(f"{tag}_symmetric_{b}", t_sym,
                              backend=b, **meta))
    return entries


# ---------------------------------------------------------------------------
# RBF static-kernel lift — the Δ-from-Gram path (API v1), regression-gated
# from day one: one timed Gram entry per mode + an oracle agreement check
# ---------------------------------------------------------------------------

_RBF_CELLS = {
    "smoke": [(4, 12, 3)],
    "quick": [(8, 32, 4)],
    "full": [(32, 128, 8)],
}


def rbf_lift(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    entries = []
    for (B, L, d) in _RBF_CELLS[_check_mode(mode)]:
        X = _paths(4, B, L, d, 0.3)
        Y = _paths(5, B, L, d, 0.3)
        kernel = RBF(sigma=1.0)
        tag = f"rbf_lift_B{B}_L{L}_d{d}"
        meta = dict(op="gram", B=B, L=L, d=d, static_kernel="rbf")

        f = jax.jit(lambda x, y: sigkernel_gram(
            x, y, static_kernel=kernel, symmetric=False))
        t = timer.bench(f, X, Y, repeats=repeats)
        entries.append(_t(f"{tag}_gram", t, **meta))
        g = jax.jit(jax.grad(lambda x, y: sigkernel_gram(
            x, y, static_kernel=kernel, symmetric=False).sum()))
        entries.append(_t(f"{tag}_gram_grad",
                          timer.bench(g, X, Y, repeats=repeats), **meta))

        # oracle: Δ as the double increment of the pointwise RBF Gram,
        # solved pairwise by the reference row scan
        G = kernel.gram(X[:, None], Y[None, :])
        K_oracle = solve_goursat(delta_from_gram(G))
        np.testing.assert_allclose(f(X, Y), K_oracle, rtol=5e-4, atol=1e-5,
                                   err_msg="rbf lift disagrees with oracle")
        entries.append(_chk(f"{tag}_agreement", **meta))
    return entries


# ---------------------------------------------------------------------------
# ragged Gram — variable-length (lengths=) batches through the Gram engine;
# timed per usable backend and agreement-checked against the per-path
# truncated oracle, so the masked hot path is regression-gated like the
# dense one (see docs/solver_guide.md § Ragged batches)
# ---------------------------------------------------------------------------

_RAGGED_CELLS = {
    "smoke": [(4, 12, 3)],
    "quick": [(8, 32, 4)],
    "full": [(32, 128, 8)],
}


def _ragged_spread(B: int, L: int, reverse: bool = False) -> jax.Array:
    """Deterministic half-to-full length spread — the one policy autotune
    measures ragged keys with, so the bench times what the cache tuned."""
    lens = autotune._ragged_lengths(B, L)
    return lens[::-1] if reverse else lens


def ragged_gram(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    from repro.core.config import TransformPipeline
    cfg = TransformPipeline(time_aug=True)
    entries = []
    for (B, L, d) in _RAGGED_CELLS[_check_mode(mode)]:
        X = _paths(6, B, L, d, 0.1)
        Y = _paths(7, B, L, d, 0.1)
        lx = _ragged_spread(B, L)
        ly = _ragged_spread(B, L, reverse=True)
        tag = f"ragged_gram_B{B}_L{L}_d{d}"
        meta = dict(op="gram", B=B, L=L, d=d, ragged=True)

        t_ref = None
        for b in _usable_gram_backends():
            f = jax.jit(lambda x, y, b=b: sigkernel_gram(
                x, y, backend=b, transforms=cfg, symmetric=False,
                lengths=lx, lengths_y=ly))
            t = timer.bench(f, X, Y, repeats=repeats)
            derived = "" if t_ref is None else \
                f"speedup_vs_reference={t_ref / t:.2f}x"
            if b == "reference":
                t_ref = t
            entries.append(_t(f"{tag}_{b}", t, derived, backend=b, **meta))
        g = jax.jit(jax.grad(lambda x, y: sigkernel_gram(
            x, y, transforms=cfg, symmetric=False,
            lengths=lx, lengths_y=ly).sum()))
        entries.append(_t(f"{tag}_grad",
                          timer.bench(g, X, Y, repeats=repeats), **meta))
        f_sym = jax.jit(lambda x: sigkernel_gram(
            x, transforms=cfg, lengths=lx))
        entries.append(_t(f"{tag}_symmetric",
                          timer.bench(f_sym, X, repeats=repeats), **meta))

        # agreement vs the per-path truncated oracle on a sampled pair set
        # (bitwise for the linear lift).  Only smoke — whose cells are tiny
        # — sweeps EVERY registered backend; quick/full would drag
        # interpret-mode Pallas through big grids for hours on CPU, so they
        # check the usable set (same policy as smoke_checks vs gram timing)
        agree_backends = [
            b for b in dispatch.backends_for("gram")
            if not dispatch.get(b).approximate] if mode == "smoke" \
            else _usable_gram_backends()
        lx_np, ly_np = np.asarray(lx), np.asarray(ly)
        pairs = [(i, (i + 1) % B) for i in range(min(B, 4))]
        for b in agree_backends:
            K = sigkernel_gram(X, Y, backend=b, transforms=cfg,
                               symmetric=False, lengths=lx, lengths_y=ly)
            for (i, j) in pairs:
                want = sigkernel_gram(
                    X[i:i + 1, :lx_np[i]], Y[j:j + 1, :ly_np[j]],
                    backend=b, transforms=cfg, symmetric=False)
                np.testing.assert_allclose(
                    float(K[i, j]), float(want[0, 0]), rtol=1e-6,
                    err_msg=f"ragged gram {b} disagrees with truncated "
                            f"oracle at pair ({i},{j})")
            entries.append(_chk(f"{tag}_agreement_{b}", backend=b, **meta))
    return entries


# ---------------------------------------------------------------------------
# distributed / streaming Gram — the PR6 engine: streaming reduce vs dense
# sum (timed + agreement-checked, forward and gradient), plus one subprocess
# on a simulated 8-device mesh proving shard-count invariance of
# sigkernel_gram_sharded.  Subprocess wall-clock includes jax startup, so
# its timing entry is gate=False; the in-process entries are gated normally.
# ---------------------------------------------------------------------------

_DISTGRAM_CELLS = {
    "smoke": [(6, 12, 3, 2)],
    "quick": [(16, 32, 4, 4)],
    "full": [(64, 128, 8, 8)],
}

_MESH_PROG = textwrap.dedent("""\
    import jax, numpy as np
    from repro.core.gram import sigkernel_gram, sigkernel_gram_sharded
    from repro.launch.mesh import make_gram_mesh
    assert len(jax.devices()) == 8, len(jax.devices())
    B, L, d = {B}, {L}, {d}
    X = jax.random.normal(jax.random.PRNGKey(0), (B, L, d)) * 0.1
    Y = jax.random.normal(jax.random.PRNGKey(1), (B + 1, L, d)) * 0.1
    want = sigkernel_gram(X, Y, symmetric=False)
    for n in (1, 4, 8):
        K = sigkernel_gram_sharded(X, Y, mesh=make_gram_mesh(n))
        np.testing.assert_allclose(np.asarray(K), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
    Ks = sigkernel_gram_sharded(X, mesh=make_gram_mesh(8))
    np.testing.assert_allclose(np.asarray(Ks), np.asarray(Ks).T,
                               rtol=1e-6, atol=1e-7)
    print('DIST-OK')
""")


def distributed_gram(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    entries = []
    for (B, L, d, rb) in _DISTGRAM_CELLS[_check_mode(mode)]:
        X = _paths(8, B, L, d, 0.1)
        Y = _paths(9, B, L, d, 0.1)
        tag = f"distgram_B{B}_L{L}_d{d}"
        meta = dict(op="gram_reduce", B=B, L=L, d=d, row_block=rb)

        f_dense = jax.jit(
            lambda x, y: sigkernel_gram(x, y, symmetric=False).sum())
        f_stream = jax.jit(lambda x, y: sigkernel_gram_reduce(
            x, y, row_block=rb))
        t_dense = timer.bench(f_dense, X, Y, repeats=repeats)
        t_stream = timer.bench(f_stream, X, Y, repeats=repeats)
        entries.append(_t(f"{tag}_reduce_dense", t_dense, **meta))
        entries.append(_t(f"{tag}_reduce_stream", t_stream,
                          f"vs_dense={t_dense / t_stream:.2f}x", **meta))
        g_stream = jax.jit(jax.grad(lambda x, y: sigkernel_gram_reduce(
            x, y, row_block=rb), argnums=(0, 1)))
        entries.append(_t(f"{tag}_reduce_stream_grad",
                          timer.bench(g_stream, X, Y, repeats=repeats),
                          **meta))
        # symmetric streaming: upper-triangle pairs with 2/1/0 weights
        f_sym = jax.jit(lambda x: sigkernel_gram_reduce(x, row_block=rb))
        entries.append(_t(f"{tag}_reduce_stream_symmetric",
                          timer.bench(f_sym, X, repeats=repeats), **meta))

        # agreement: streaming == dense oracle, values and gradients
        np.testing.assert_allclose(
            float(f_stream(X, Y)), float(f_dense(X, Y)), rtol=1e-5,
            err_msg="streaming reduce disagrees with dense sum")
        gx, _ = g_stream(X, Y)
        gx_d = jax.grad(lambda x: sigkernel_gram(
            x, Y, symmetric=False).sum())(X)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_d),
                                   rtol=1e-4, atol=1e-6,
                                   err_msg="streaming grad disagrees")
        np.testing.assert_allclose(
            float(f_sym(X)), float(sigkernel_gram(X).sum()), rtol=1e-5,
            err_msg="symmetric streaming reduce disagrees")
        entries.append(_chk(f"{tag}_agreement", **meta))

    # one subprocess on a simulated 8-device host mesh: shard-count
    # invariance (1 vs 4 vs 8 devices) of the sharded engine.  Wall-clock
    # includes jax startup + compilation — informative, never gated.
    B, L, d, _ = _DISTGRAM_CELLS[_check_mode(mode)][0]
    from repro.launch.mesh import simulated_mesh_env
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = {**simulated_mesh_env(8), "PYTHONPATH": src_dir}
    prog = _MESH_PROG.format(B=B, L=L, d=d)
    t0 = time.perf_counter()
    try:
        r = subprocess.run([sys.executable, "-c", prog],
                           capture_output=True, text=True, timeout=900,
                           env=env)
        ok = "DIST-OK" in r.stdout
        detail = "" if ok else (r.stdout[-500:] + r.stderr[-500:])
    except (OSError, subprocess.TimeoutExpired) as e:
        ok, detail = False, repr(e)
    if ok:
        entries.append(_t("distgram_mesh_invariance_wall",
                          time.perf_counter() - t0,
                          "1/4/8-device sharded == single-device (subproc)",
                          gate=False, op="gram_sharded", B=B, L=L, d=d))
        entries.append(_chk("distgram_mesh_invariance",
                            op="gram_sharded", B=B, L=L, d=d))
    else:
        # a host that cannot simulate the mesh is an environment limit,
        # not a regression — record it visibly but never gate on it
        entries.append(_chk("distgram_mesh_invariance",
                            f"skipped: {detail[:200]!r}", gate=False,
                            op="gram_sharded", B=B, L=L, d=d))
    return entries


# ---------------------------------------------------------------------------
# Table 3 — log-signatures: epilogue cost per mode + compression ratio
# ---------------------------------------------------------------------------

_TABLE3_CELLS = {
    "smoke": [(4, 32, 3, 3)],
    "quick": [(16, 64, 4, 6), (16, 128, 8, 5), (16, 256, 16, 4)],
    "full": [(128, 256, 4, 6), (128, 512, 8, 5), (128, 1024, 16, 4)],
}


def table3_logsignatures(mode: str = "quick", repeats: int = 5) -> List[dict]:
    entries = []
    for (B, L, d, N) in _TABLE3_CELLS[_check_mode(mode)]:
        path = _paths(0, B, L, d, 0.2)
        tag = f"table3_B{B}_L{L}_d{d}_N{N}"
        meta = dict(op="logsignature", B=B, L=L, d=d, depth=N)
        ratio = f"compress={logsig_dim(d, N)}/{sig_dim(d, N)}"

        f_sig = jax.jit(lambda p: signature(p, N, backend="reference"))
        t_sig = timer.bench(f_sig, path, repeats=repeats)
        entries.append(_t(f"{tag}_signature", t_sig, ratio, **meta))

        for lmode in ("lyndon", "brackets", "expand"):
            f_ls = jax.jit(lambda p, m=lmode: logsignature(
                p, N, mode=m, backend="reference"))
            t_ls = timer.bench(f_ls, path, repeats=repeats)
            entries.append(_t(
                f"{tag}_logsig_{lmode}", t_ls,
                f"epilogue_x{t_ls / max(t_sig, 1e-12):.2f}", **meta))

        f_grad = jax.jit(jax.grad(
            lambda p: logsignature(p, N, backend="reference").sum()))
        entries.append(_t(f"{tag}_logsig_grad",
                          timer.bench(f_grad, path, repeats=repeats), **meta))
    return entries


# ---------------------------------------------------------------------------
# Figure 1 / Figure 2 sweeps — runtime vs truncation level / stream length
# ---------------------------------------------------------------------------

def fig1_truncation_sweep(mode: str = "quick", repeats: int = 3
                          ) -> List[dict]:
    """Signature runtime vs truncation level (paper: B=32, L=1024, d=5)."""
    if _check_mode(mode) == "smoke":
        return []
    B, L, d = (8, 128, 5) if mode == "quick" else (32, 1024, 5)
    path = _paths(0, B, L, d, 0.2)
    entries = []
    for N in range(2, 8):
        f_h = jax.jit(lambda p, N=N: signature(p, N, backend="reference"))
        f_d = jax.jit(lambda p, N=N: signature_direct(p, N))
        g_h = jax.jit(jax.grad(
            lambda p, N=N: signature(p, N, backend="reference").sum()))
        t_h = timer.bench(f_h, path, repeats=repeats)
        t_d = timer.bench(f_d, path, repeats=repeats)
        t_g = timer.bench(g_h, path, repeats=repeats)
        meta = dict(op="signature", B=B, L=L, d=d, depth=N)
        entries.append(_t(f"fig1_N{N}_fwd_horner", t_h,
                          f"direct/horner={t_d / t_h:.2f}", **meta))
        entries.append(_t(f"fig1_N{N}_bwd", t_g, **meta))
    return entries


def fig2_length_sweep(mode: str = "quick", repeats: int = 3) -> List[dict]:
    """Sig-kernel runtime vs stream length (paper: B=32, d=5)."""
    if _check_mode(mode) == "smoke":
        return []
    B, d = (8, 5) if mode == "quick" else (32, 5)
    lengths = [32, 64, 128, 256] if mode == "quick" else \
        [128, 256, 512, 1024, 2048]
    entries = []
    for L in lengths:
        kx = _paths(0, B, L, d, 0.1)
        ky = _paths(1, B, L, d, 0.1)
        f_wave = jax.jit(
            lambda x, y: solve_goursat_antidiag(delta_matrix(x, y)))
        g_exact = jax.jit(jax.grad(lambda x, y: sigkernel(x, y).sum()))
        t_f = timer.bench(f_wave, kx, ky, repeats=repeats)
        t_g = timer.bench(g_exact, kx, ky, repeats=repeats)
        meta = dict(op="sigkernel", B=B, L=L, d=d)
        entries.append(_t(f"fig2_L{L}_fwd", t_f,
                          f"per_pair_us={t_f / B * 1e6:.1f}", **meta))
        entries.append(_t(f"fig2_L{L}_bwd_exact", t_g, **meta))
    return entries


# ---------------------------------------------------------------------------
# §3.4 gradient accuracy — exact one-pass backward vs the second-PDE
# approximation of [30]
# ---------------------------------------------------------------------------

_GRADACC_CELLS = {
    "smoke": ([4, 8], [0, 1]),
    "quick": ([4, 8, 16], [0, 1]),
    "full": ([4, 8, 16, 32, 64], [0, 1, 2]),
}


def grad_accuracy(mode: str = "quick", repeats: int = 0) -> List[dict]:
    del repeats  # deterministic accuracy study, nothing to repeat
    lengths, lams = _GRADACC_CELLS[_check_mode(mode)]
    entries = []
    for L in lengths:
        for lam in lams:
            x = _paths(0, 4, L, 3, 0.3)
            y = _paths(1, 4, L, 3, 0.3)
            delta = delta_matrix(x, y)
            grid = solve_goursat(delta, lam, lam, return_grid=True)
            gbar = jnp.ones(delta.shape[:-2])
            d_true = jax.grad(
                lambda d: solve_goursat(d, lam, lam).sum())(delta)
            d_exact = solve_goursat_grad(delta, grid, gbar, lam, lam)
            d_approx = solve_goursat_grad_pde_approx(
                delta, grid, gbar, lam, lam)
            scale = float(jnp.abs(d_true).max())
            e_exact = float(jnp.abs(d_exact - d_true).max()) / scale
            e_approx = float(jnp.abs(d_approx - d_true).max()) / scale
            meta = dict(op="sigkernel_grad", L=L, lam=lam)
            entries.append(_acc(f"gradacc_L{L}_lam{lam}_exact", e_exact,
                                f"rel_err={e_exact:.2e}", **meta))
            entries.append(_acc(
                f"gradacc_L{L}_lam{lam}_pde_approx", e_approx,
                f"rel_err={e_approx:.2e}", gate=False, **meta))
    return entries


# ---------------------------------------------------------------------------
# smoke checks — tiny shapes through EVERY registered backend (forward +
# grad + the symmetric pair-solve budget); any dispatch regression fails
# here in seconds.  Correctness only: no timing entries.
# ---------------------------------------------------------------------------

def smoke_checks(mode: str = "smoke", repeats: int = 1) -> List[dict]:
    del mode, repeats
    B, L, d = 3, 8, 2
    X = _paths(0, B, L, d, 0.1)
    Y = _paths(1, B, L, d, 0.1)
    entries = []
    K_ref = sigkernel_gram(X, Y, backend="reference", symmetric=False)
    for b in dispatch.backends_for("gram"):
        if dispatch.get(b).approximate:
            # feature-map backends approximate K_ref, they don't match it
            # within exact tolerances — checked separately below
            continue
        K = sigkernel_gram(X, Y, backend=b, symmetric=False)
        np.testing.assert_allclose(K, K_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"smoke: {b} disagrees")
        g = jax.grad(
            lambda q: sigkernel_gram(q, Y, backend=b,
                                     symmetric=False).sum())(X)
        assert np.isfinite(np.asarray(g)).all(), \
            f"smoke: {b} grad not finite"
        entries.append(_chk(f"smoke_gram_{b}", backend=b))
    # approximate feature-map backends: finite + in the right ballpark of
    # the exact Gram (the frontier workload measures the error precisely),
    # with a differentiable path and — for rff — zero PDE pair-solves
    from repro.core.features import FeatureConfig
    for b, feats in (("rff", FeatureConfig("rff", rank=128, depth=4)),
                     ("nystroem", FeatureConfig("nystroem", rank=B))):
        with dispatch.count_pair_solves() as c:
            Ka = sigkernel_gram(X, Y, backend=b, symmetric=False,
                                features=feats)
        rel = float(np.abs(np.asarray(Ka) - np.asarray(K_ref)).max()
                    / np.abs(np.asarray(K_ref)).max())
        assert rel < 0.5, f"smoke: {b} rel err {rel:.2f} out of ballpark"
        if b == "rff":
            assert c.total == 0, f"smoke: rff issued {c.total} PDE solves"
        ga = jax.grad(lambda q: sigkernel_gram(
            q, Y, backend=b, symmetric=False, features=feats).sum())(X)
        assert np.isfinite(np.asarray(ga)).all(), \
            f"smoke: {b} grad not finite"
        entries.append(_chk(f"smoke_gram_{b}",
                            f"rel_err={rel:.2e};solves={c.total}",
                            backend=b))
    with dispatch.count_pair_solves() as c:
        sigkernel_gram(X, backend="pallas_fused")
    budget = B * (B + 1) // 2
    assert c.total <= budget, (c.total, budget)
    entries.append(_chk("smoke_symmetric_pair_solves",
                        f"solves={c.total}<=budget={budget}"))
    for b in dispatch.backends_for("sigkernel"):
        k = sigkernel(X, Y, backend=b)
        np.testing.assert_allclose(
            k, sigkernel(X, Y, backend="reference"), rtol=5e-4, atol=1e-5,
            err_msg=f"smoke: sigkernel {b} disagrees")
        entries.append(_chk(f"smoke_sigkernel_{b}", backend=b))
    return entries


# ---------------------------------------------------------------------------
# accuracy-vs-speed frontier — the approximate feature-map backends
# (rff / nystroem) swept over rank, each point measured for wall clock AND
# relative Frobenius error against the exact Gram, then persisted via
# autotune.tune_frontier so backend="auto" + error_budget= can legally pick
# the cheapest approximation that fits the caller's budget
# ---------------------------------------------------------------------------

#: (gram key shape, rank sweep) per mode — key shape as autotune.cache_key
#: documents it: (Bx, By, nx, ny, d)
_FRONTIER_CELLS = {
    "smoke": ((4, 4, 12, 12, 3), (8, 32)),
    "quick": ((8, 8, 32, 32, 4), (8, 32, 128)),
    "full": ((32, 32, 128, 128, 8), (32, 128, 512)),
}


def approx_frontier(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    """Frontier entries: one timed + one accuracy row per (method, rank).

    Timings are ``gate=False`` — approximation wall clock at bench shapes
    is dominated by fixed overheads and too noisy to gate — but the
    relative-error rows are gated: the estimators are deterministic (fixed
    feature keys), so an error regression is a real math regression.  The
    sweep also *persists* the frontier (``force=True`` re-measures every
    run), which is what arms :func:`repro.core.dispatch.resolve_approx`
    for this shape bucket on this machine.
    """
    shape, ranks = _FRONTIER_CELLS[_check_mode(mode)]
    entry = autotune.tune_frontier("gram", shape, ranks=ranks,
                                   repeats=repeats, force=True)
    bshape = autotune.key_shape("gram", shape)
    meta = dict(op="gram", shape=list(bshape))
    entries = [_t("approx_frontier_exact", entry["exact_seconds"],
                  f"backend={entry['exact_backend']}", gate=False, **meta)]
    for p in entry["frontier"]:
        tag = f"approx_frontier_{p['backend']}_r{p['rank']}"
        entries.append(_t(
            f"{tag}_time", p["seconds"],
            f"vs_exact={entry['exact_seconds'] / p['seconds']:.2f}x",
            gate=False, backend=p["backend"], rank=p["rank"], **meta))
        entries.append(_acc(
            f"{tag}_rel_err", p["rel_err"], f"rel_err={p['rel_err']:.2e}",
            backend=p["backend"], rank=p["rank"], **meta))
    # budget round-trip on the freshly-persisted frontier.  gate=False: at
    # tiny shapes no point may beat the exact engine's wall clock, and
    # "None (exact wins)" is then the *correct* answer, not a regression.
    found = autotune.lookup_budget("gram", shape, "float32", 0.5)
    entries.append(_chk("approx_frontier_budget_lookup",
                        f"budget=0.5->{found}", gate=False, **meta))
    return entries


# ---------------------------------------------------------------------------
# discretisation frontier — scheme order × grid coarseness × interior
# precision swept by autotune.tune_scheme_frontier: every point is the EXACT
# engine under a different GridConfig, measured for wall clock and relative
# Frobenius error against the order-1 fine-grid f32 baseline, then persisted
# so backend="auto" + error_budget= can legally trade discretisation for
# speed (dispatch.resolve_scheme)
# ---------------------------------------------------------------------------

#: gram key shape per mode, as autotune.cache_key documents it
_SCHEME_CELLS = {
    "smoke": (4, 4, 12, 12, 3),
    "quick": (8, 8, 32, 32, 4),
    "full": (16, 16, 128, 128, 8),
}

#: the PR acceptance budget: order-2 on the 2x-coarser grid must match the
#: order-1 fine-grid Gram within this relative Frobenius error
_SCHEME_COARSE_BUDGET = 0.05


def scheme_frontier(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    """Frontier entries: one timed + one accuracy row per discretisation.

    Timings are ``gate=False`` (fixed overheads dominate at bench shapes)
    but the relative-error rows are gated: every point is deterministic
    exact-engine arithmetic, so an error regression is a real math
    regression.  The order-2 coarse-grid point additionally carries a hard
    in-run budget assert — the scheme's selling point is matching order-1
    accuracy at a quarter of the cells, and this is where that claim is
    continuously measured.  The sweep persists the frontier (force=True),
    arming :func:`repro.core.dispatch.resolve_scheme` for this shape
    bucket on this machine.
    """
    shape = _SCHEME_CELLS[_check_mode(mode)]
    entry = autotune.tune_scheme_frontier("gram", shape, repeats=repeats,
                                          force=True)
    bshape = autotune.key_shape("gram", shape)
    meta = dict(op="gram", shape=list(bshape))
    entries = [_t("scheme_frontier_exact", entry["exact_seconds"],
                  f"backend={entry['exact_backend']}", gate=False, **meta)]
    coarse_o2 = None
    for p in entry["scheme_frontier"]:
        dt = "bf16" if p["interior_dtype"] == "bfloat16" else "f32"
        tag = f"scheme_frontier_{p['scheme']}_c{p['coarsen']}_{dt}"
        entries.append(_t(
            f"{tag}_time", p["seconds"],
            f"vs_exact={entry['exact_seconds'] / p['seconds']:.2f}x",
            gate=False, scheme=p["scheme"], coarsen=p["coarsen"],
            interior_dtype=p["interior_dtype"], **meta))
        entries.append(_acc(
            f"{tag}_rel_err", p["rel_err"], f"rel_err={p['rel_err']:.2e}",
            scheme=p["scheme"], coarsen=p["coarsen"],
            interior_dtype=p["interior_dtype"], **meta))
        if (p["scheme"], p["coarsen"], p["interior_dtype"]) == \
                ("order2", 1, "float32"):
            coarse_o2 = p
    assert coarse_o2 is not None, "order2/coarsen=1/f32 point did not run"
    assert coarse_o2["rel_err"] <= _SCHEME_COARSE_BUDGET, (
        f"order-2 on the 2x-coarser grid misses the order-1 fine baseline "
        f"by rel_err={coarse_o2['rel_err']:.2e} "
        f"(budget {_SCHEME_COARSE_BUDGET})")
    entries.append(_chk(
        "scheme_frontier_order2_coarse_budget",
        f"rel_err={coarse_o2['rel_err']:.2e}<={_SCHEME_COARSE_BUDGET}",
        **meta))
    # budget round-trip on the freshly-persisted frontier.  gate=False: at
    # tiny shapes no point may beat the baseline's wall clock, and "None
    # (order-1 fine wins)" is then the correct answer, not a regression.
    found = autotune.lookup_scheme_budget("gram", shape, "float32",
                                          _SCHEME_COARSE_BUDGET)
    entries.append(_chk("scheme_frontier_budget_lookup",
                        f"budget={_SCHEME_COARSE_BUDGET}->{found}",
                        gate=False, **meta))
    return entries


# ---------------------------------------------------------------------------
# autotune round-trip — tune the smoke shapes, then verify backend="auto"
# with a warm cache is never slower than the worst fixed backend
# ---------------------------------------------------------------------------

#: per-op key shapes the smoke suite tunes (see autotune.cache_key)
_AUTOTUNE_SMOKE_SHAPES: Dict[str, tuple] = {
    "sigkernel": (24, 24, 3),
    "gram": (4, 4, 12, 12, 3),
}


def autotune_auto(mode: str = "smoke", repeats: int = 2) -> List[dict]:
    del mode
    if not autotune.enabled():
        return [_chk("autotune_disabled",
                     "REPRO_DISABLE_AUTOTUNE set; skipped", gate=False)]
    entries = []
    for op, shape in _AUTOTUNE_SMOKE_SHAPES.items():
        winner = autotune.tune(op, shape, repeats=repeats, force=True)
        record = autotune.cache_entry(op, shape)
        times = record["timings"]
        bshape = autotune.key_shape(op, shape)
        for b, t in sorted(times.items()):
            entries.append(_t(f"autotune_{op}_{b}", t, op=op,
                              shape=list(bshape), backend=b))

        key = jax.random.PRNGKey(7)
        if op == "gram":
            Bx, By, nx, ny, d = bshape
            Xa = jax.random.normal(key, (Bx, nx + 1, d)) * 0.1
            Ya = jax.random.normal(jax.random.PRNGKey(8),
                                   (By, ny + 1, d)) * 0.1
            f = jax.jit(lambda x, y: sigkernel_gram(
                x, y, backend="auto", symmetric=False))
        else:
            nx, ny, d = bshape
            Xa = jax.random.normal(key, (8, nx + 1, d)) * 0.1
            Ya = jax.random.normal(jax.random.PRNGKey(8),
                                   (8, ny + 1, d)) * 0.1
            f = jax.jit(lambda x, y: sigkernel(x, y, backend="auto"))
        t_auto = timer.bench(f, Xa, Ya, repeats=repeats)
        worst = max(times.values())
        # the acceptance contract: warm-cache auto never loses to the worst
        # fixed backend (2x + 5ms of slack absorbs CI timer noise)
        assert t_auto <= worst * 2.0 + 5e-3, (
            f"auto ({t_auto * 1e6:.1f}us) slower than worst fixed backend "
            f"({worst * 1e6:.1f}us) for op={op} despite a warm cache")
        entries.append(_t(f"autotune_{op}_auto", t_auto,
                          f"winner={winner};worst_fixed={worst * 1e6:.1f}us",
                          op=op, shape=list(bshape)))
        entries.append(_chk(f"autotune_{op}_winner", f"winner={winner}",
                            op=op))
    return entries


# ---------------------------------------------------------------------------
# streaming Path engine — incremental append+query vs full recompute
# ---------------------------------------------------------------------------

_PATH_CELLS = {
    "smoke": [(64, 3, 3)],
    "quick": [(256, 4, 4)],
    "full": [(1024, 4, 5), (4096, 3, 4)],
}


def path_update(mode: str = "smoke", repeats: int = 3) -> List[dict]:
    """Streaming serving pattern: one-tick append + full-signature query.

    ``incremental`` is the ``repro.Path`` engine (O(chunk) scan + one Chen
    combine against the prefix store); ``full_recompute`` is what serving
    had to do before this subsystem existed — re-scan all L+1 points per
    tick.  The agreement entry pins the two to each other.  The timed
    appends run at a pre-grown capacity so they exercise the steady-state
    warm trace, never the (rare, bounded) growth retrace.
    """
    from repro.stream import Path

    entries = []
    for (L, d, N) in _PATH_CELLS[_check_mode(mode)]:
        pts = _paths(0, 1, L, d, 0.2)[0]
        tick = _paths(1, 1, 1, d, 0.2)[0]
        tag = f"path_update_L{L}_d{d}_N{N}"
        meta = dict(op="path_update", L=L, d=d, depth=N)

        base = Path.from_points(pts, N).update(tick)   # pre-grow + warm

        def append_query(p, t):
            return p.update(t).signature()

        t_inc = timer.bench(append_query, base, tick, repeats=repeats)
        entries.append(_t(f"{tag}_incremental", t_inc, **meta))

        full = jnp.concatenate([pts, tick, tick])
        f_full = jax.jit(lambda pp: signature(pp, N, backend="reference"))
        t_full = timer.bench(f_full, full, repeats=repeats)
        entries.append(_t(
            f"{tag}_full_recompute", t_full,
            f"speedup_incremental={t_full / t_inc:.2f}x",
            _fn=f_full, _args=(full,), **meta))

        got = append_query(base, tick)
        want = f_full(full)
        denom = max(float(jnp.abs(want).max()), 1e-30)
        rel = float(jnp.abs(got - want).max()) / denom
        entries.append(_acc(f"{tag}_agreement", rel,
                            "incremental vs full recompute", **meta))
        assert rel < 5e-5, f"Path incremental drifted from recompute: {rel}"
    return entries
