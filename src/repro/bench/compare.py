"""Diff two BENCH JSONs and exit nonzero on performance regression.

    PYTHONPATH=src python -m repro.bench.compare BASELINE NEW \
        [--tolerance 2.5] [--no-normalize] [--allow-missing]

Designed for the CI perf gate, where BASELINE is the committed
``BENCH_PR10.json`` (possibly produced on a different machine) and NEW is a
fresh run of the same mode.  Rules:

* Entries are matched by ``name``; a baseline entry missing from the new
  run is a coverage regression (``--allow-missing`` downgrades to a note).
* **Machine-speed normalisation** (default): the median new/old ratio over
  all shared timing entries is treated as the box-speed factor and divided
  out, so "the CI runner is uniformly 3x slower than the laptop that
  committed the baseline" never fails the gate — only entries that regress
  *relative to the rest of the suite* do.
* A timing entry regresses when its normalised ratio exceeds
  ``--tolerance`` (default 2.5x, generous for shared CPU runners) AND the
  absolute slowdown exceeds ``--abs-floor-us`` (default 250µs) — tiny
  entries are pure timer noise and never fail.
* Accuracy entries regress when the error grows past
  ``old * --accuracy-tolerance`` (default 4x) and an absolute floor of
  1e-5 (f32 rounding differs across BLAS builds).
* Entries with ``meta.gate == false`` (calibration probe, interpret-mode
  timings, the O(h) approx-backward baseline) are reported but never gate.
* **Roofline deltas are never gated**: when both sides of a timing entry
  carry ``"roofline"`` achieved-fraction fields (see
  :mod:`repro.bench.roofline`), the change in achieved fraction of peak
  FLOPs/bandwidth is reported as a ``ROOFLINE`` note — attribution for a
  launch-parameter tuning win or loss, informative only.
"""

from __future__ import annotations

import argparse
import sys
from statistics import median
from typing import Dict, List, Tuple

from . import suite

#: entries faster than this (baseline side) are excluded from the
#: machine-speed median — they are dominated by dispatch overhead
_NORMALIZE_MIN_SECONDS = 100e-6

#: accuracy regressions need to clear this absolute error floor
_ACCURACY_FLOOR = 1e-5

#: speed factors outside this range are implausible and get clamped
_FACTOR_CLAMP = 16.0


def _gated(entry: dict) -> bool:
    return bool(entry.get("meta", {}).get("gate", True))


def speed_factor(old_entries: Dict[str, dict],
                 new_entries: Dict[str, dict]) -> float:
    """Median new/old ratio over substantial shared timing entries."""
    ratios = []
    for name, old in old_entries.items():
        new = new_entries.get(name)
        if new is None or old["kind"] != "time" or new["kind"] != "time":
            continue
        if old["seconds"] >= _NORMALIZE_MIN_SECONDS and old["seconds"] > 0:
            ratios.append(new["seconds"] / old["seconds"])
    if len(ratios) < 3:
        return 1.0
    return min(max(median(ratios), 1.0 / _FACTOR_CLAMP), _FACTOR_CLAMP)


def compare_docs(old_doc: dict, new_doc: dict, *, tolerance: float = 2.5,
                 accuracy_tolerance: float = 4.0, abs_floor: float = 250e-6,
                 normalize: bool = True, allow_missing: bool = False,
                 ) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes); empty regressions == gate passes."""
    old_entries = {e["name"]: e for e in old_doc["entries"]}
    new_entries = {e["name"]: e for e in new_doc["entries"]}
    regressions: List[str] = []
    notes: List[str] = []

    if old_doc.get("mode") != new_doc.get("mode"):
        notes.append(f"NOTE mode mismatch: baseline {old_doc.get('mode')!r} "
                     f"vs new {new_doc.get('mode')!r} — entry sets may "
                     f"not align")
    factor = speed_factor(old_entries, new_entries) if normalize else 1.0
    if factor != 1.0:
        notes.append(f"machine-speed factor {factor:.2f}x "
                     f"(median over shared timing entries) divided out")

    for name, old in sorted(old_entries.items()):
        new = new_entries.get(name)
        if new is None:
            msg = f"MISSING {name}: present in baseline, absent from new run"
            (notes if allow_missing else regressions).append(msg)
            continue
        if old["kind"] != new["kind"]:
            regressions.append(
                f"KIND {name}: {old['kind']!r} -> {new['kind']!r}")
            continue
        if old["kind"] == "time" and old["seconds"] > 0:
            ratio = new["seconds"] / old["seconds"]
            eff = ratio / factor
            line = (f"{name}: {old['seconds'] * 1e6:.1f} -> "
                    f"{new['seconds'] * 1e6:.1f} us "
                    f"(x{ratio:.2f} raw, x{eff:.2f} normalized)")
            slow = new["seconds"] - old["seconds"] * factor
            if _gated(old) and _gated(new) and eff > tolerance \
                    and slow > abs_floor:
                regressions.append("SLOWER " + line)
            else:
                notes.append(line)
            ro = old.get("roofline") or {}
            rn = new.get("roofline") or {}
            if "frac_flops" in ro and "frac_flops" in rn:
                notes.append(
                    f"ROOFLINE {name}: frac-of-peak flops "
                    f"{ro['frac_flops']:.4f} -> {rn['frac_flops']:.4f}, "
                    f"bandwidth {ro.get('frac_bandwidth', 0.0):.4f} -> "
                    f"{rn.get('frac_bandwidth', 0.0):.4f} "
                    f"({rn.get('bound', '?')}-bound; non-gating)")
        elif old["kind"] == "accuracy":
            limit = max(old["value"] * accuracy_tolerance,
                        old["value"] + _ACCURACY_FLOOR)
            line = (f"{name}: err {old['value']:.2e} -> {new['value']:.2e}")
            if _gated(old) and _gated(new) and new["value"] > limit:
                regressions.append("LESS-ACCURATE " + line)
            else:
                notes.append(line)
        else:  # "check": presence is the contract; the run itself asserted
            notes.append(f"{name}: {new.get('derived', 'ok')}")
    extra = sorted(set(new_entries) - set(old_entries))
    if extra:
        notes.append(f"{len(extra)} new entries not in baseline: "
                     + ", ".join(extra))
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.compare",
        description="diff two BENCH JSONs; nonzero exit on regression")
    ap.add_argument("baseline", help="committed BENCH json (e.g. BENCH_PR10.json)")
    ap.add_argument("new", help="freshly produced BENCH json")
    ap.add_argument("--tolerance", type=float, default=2.5,
                    help="max normalized slowdown ratio (default 2.5)")
    ap.add_argument("--accuracy-tolerance", type=float, default=4.0,
                    help="max error growth factor (default 4.0)")
    ap.add_argument("--abs-floor-us", type=float, default=250.0,
                    help="ignore absolute slowdowns below this (default 250)")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare raw ratios (same-machine runs)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="missing baseline entries are notes, not failures")
    ap.add_argument("--quiet", action="store_true",
                    help="print regressions only")
    args = ap.parse_args(argv)

    old_doc = suite.load_json(args.baseline)
    new_doc = suite.load_json(args.new)
    regressions, notes = compare_docs(
        old_doc, new_doc, tolerance=args.tolerance,
        accuracy_tolerance=args.accuracy_tolerance,
        abs_floor=args.abs_floor_us * 1e-6,
        normalize=not args.no_normalize, allow_missing=args.allow_missing)
    if not args.quiet:
        for line in notes:
            print(line)
    for line in regressions:
        print("REGRESSION " + line)
    print(f"compared {len(old_doc['entries'])} baseline entries: "
          f"{len(regressions)} regressions")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
