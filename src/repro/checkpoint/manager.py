"""Sharded, atomic, async checkpointing with elastic restore.

Layout (multihost-aware; on one host there is one process file):

    <dir>/step_<N>.tmp/            — written first
        manifest.json              — tree structure, shapes, dtypes, step
        proc_<P>.npz               — this process's addressable shard data
    <dir>/step_<N>/                — atomic rename after fsync

Restore targets ANY mesh: leaves are loaded and device_put against the
requested shardings, so a checkpoint from a 16x16 run restores onto 2x16x16
(elastic rescale) or a single host (debugging) unchanged.  Saves run on a
background thread after a synchronous device_get snapshot, so the train loop
loses only the host-copy time.  A SIGTERM handler (see launch/train.py)
triggers a final synchronous save — preemption safety.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, List, Optional

import jax
import numpy as np


def _flatten(tree) -> List:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree) -> List[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, _leaf in flat:
        out.append("/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                            for k in path))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.process = jax.process_index()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, blocking: bool = False):
        """Snapshot to host, then write on a background thread."""
        self.wait()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        names = _paths(tree)
        meta = {
            "step": step,
            "names": names,
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "n_processes": jax.process_count(),
            "time": time.time(),
        }

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"proc_{self.process}.npz"),
                     **{str(i): a for i, a in enumerate(host_leaves)})
            if self.process == 0:
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(meta, f)
            # fsync directory then atomic rename
            fd = os.open(tmp, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any, shardings: Any = None):
        """Load a checkpoint into the structure of ``target_tree``; if
        ``shardings`` given, device_put each leaf (elastic re-sharding)."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(path, f"proc_{self.process}.npz"))
        leaves = [data[str(i)] for i in range(len(meta["names"]))]
        _, treedef = jax.tree_util.tree_flatten(target_tree)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, meta["step"]
