"""Signature-kernel training losses.

The workload pySigLib exists to accelerate: sig-kernel scores for training
generative models on time series (paper §1; refs [16, 21, 24]).  All losses
are differentiable through the exact one-pass backward of
``repro.core.sigkernel`` and route their Gram matrices through the unified
engine in ``repro.core.gram`` — the symmetric ``Kxx``/``Kyy`` terms solve
only the upper triangle (≈2× fewer PDE solves), and ``backend=`` selects the
solver via the registry in ``repro.core.dispatch``.

With ``streaming=`` on (auto-enabled whenever ``row_block=`` is set) the
losses never materialise their Gram matrices at all: every term routes
through :func:`repro.core.gram.sigkernel_gram_reduce`, which accumulates
per-row-block partial sums under ``jax.checkpoint`` in both the forward and
the VJP, and the shape guard
:func:`repro.core.gram.assert_streaming_reduction` abstractly traces the
reduction once per shape to prove no (B, B) intermediate exists.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import resolve_kernel_configs
from .dispatch import UNSET
from .gram import sigkernel_gram, sigkernel_gram_reduce


def _use_streaming(streaming: Optional[bool], row_block: Optional[int],
                   approx: bool = False) -> bool:
    """``streaming=None`` means auto: stream iff the caller bounded memory
    with ``row_block=`` (the only reason to pay the reduction's extra
    trace) — or an approximation is active (``features=`` /
    ``error_budget=``), whose whole point is O(B·rank) memory: the
    feature-space reduction never forms a B×B Gram, so streaming is the
    natural default.  Explicit True/False always wins."""
    if streaming is None:
        return row_block is not None or approx
    return bool(streaming)


def mmd2(X: jax.Array, Y: jax.Array, *, transforms=None, grid=None,
         static_kernel=None, unbiased: bool = True, backend: str = "auto",
         row_block: Optional[int] = None, streaming: Optional[bool] = None,
         lengths=None, lengths_y=None, features=None, error_budget=None,
         lam1=UNSET, lam2=UNSET, time_aug=UNSET, lead_lag=UNSET,
         use_pallas=UNSET) -> jax.Array:
    """Squared MMD between two path distributions under the signature kernel.

    X: (Bx, L, d) samples from P;  Y: (By, L', d) samples from Q.

    ``transforms=`` (:class:`repro.TransformPipeline`), ``grid=``
    (:class:`repro.GridConfig`) and ``static_kernel=`` (:class:`repro.Linear`
    / :class:`repro.RBF`) configure the kernel; the legacy
    ``lam1/lam2/time_aug/lead_lag/use_pallas`` kwargs are deprecated
    aliases (DeprecationWarning once per call-site).

    ``lengths``/``lengths_y`` — optional (Bx,)/(By,) int arrays of per-path
    true point counts — make both batches ragged: each Gram term masks its
    padding exactly (see :func:`repro.core.gram.sigkernel_gram`), so the two
    sides may be padded to *different* L and still compare correctly.

    ``streaming`` — ``True`` accumulates all three Gram terms as per-block
    partial sums (forward and gradient) via
    :func:`repro.core.gram.sigkernel_gram_reduce`, so the full (B, B) Grams
    never exist; peak memory is set by ``row_block`` instead of the batch.
    ``None`` (default) auto-enables streaming when ``row_block=`` is set;
    ``False`` forces the dense Grams.  Values and gradients match the dense
    path to summation-order tolerance, and an intermediate-shape assertion
    (abstract trace, no FLOPs, once per shape) guards against the streaming
    path silently densifying.

    ``features=`` (a :class:`repro.FeatureConfig`) or ``error_budget=``
    activate the approximate feature-map backends exactly as in
    :func:`repro.core.gram.sigkernel_gram`; all three Gram terms then
    reduce in feature space — O(B·rank) memory end-to-end, streaming by
    default (see docs/api/public.md § Approximate kernels).

    The unbiased estimator divides by ``b·(b−1)`` and therefore needs at
    least two samples on each side — a single-sample batch raises instead of
    silently returning NaN; use ``unbiased=False`` for ``b = 1``.
    """
    bx, by = X.shape[0], Y.shape[0]
    if unbiased and min(bx, by) < 2:
        raise ValueError(
            f"unbiased MMD needs >= 2 samples per side (got Bx={bx}, "
            f"By={by}); the 1/(b·(b-1)) normaliser is NaN at b=1 — "
            "pass unbiased=False")
    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    approx = features is not None or error_budget is not None
    kw = dict(transforms=cfg, grid=g, static_kernel=kernel,
              backend=backend, row_block=row_block, use_pallas=use_pallas,
              features=features, error_budget=error_budget)
    if _use_streaming(streaming, row_block, approx):
        rkw = dict(kw, check_streaming=True)
        sxx_sum = sigkernel_gram_reduce(X, lengths=lengths,
                                        include_diag=not unbiased, **rkw)
        syy_sum = sigkernel_gram_reduce(Y, lengths=lengths_y,
                                        include_diag=not unbiased, **rkw)
        sxy_sum = sigkernel_gram_reduce(X, Y, lengths=lengths,
                                        lengths_y=lengths_y, **rkw)
        if unbiased:
            sxx = sxx_sum / (bx * (bx - 1))
            syy = syy_sum / (by * (by - 1))
        else:
            sxx = sxx_sum / (bx * bx)
            syy = syy_sum / (by * by)
        return sxx + syy - 2.0 * sxy_sum / (bx * by)
    Kxx = sigkernel_gram(X, lengths=lengths, **kw)   # upper triangle only
    Kyy = sigkernel_gram(Y, lengths=lengths_y, **kw)
    Kxy = sigkernel_gram(X, Y, lengths=lengths, lengths_y=lengths_y, **kw)
    if unbiased:
        sxx = (Kxx.sum() - jnp.trace(Kxx)) / (bx * (bx - 1))
        syy = (Kyy.sum() - jnp.trace(Kyy)) / (by * (by - 1))
    else:
        sxx = Kxx.mean()
        syy = Kyy.mean()
    return sxx + syy - 2.0 * Kxy.mean()


def scoring_rule(X: jax.Array, y: jax.Array, *, transforms=None, grid=None,
                 static_kernel=None, backend: str = "auto",
                 row_block: Optional[int] = None,
                 streaming: Optional[bool] = None,
                 lengths=None, length_y=None,
                 features=None, error_budget=None,
                 lam1=UNSET, lam2=UNSET, time_aug=UNSET, lead_lag=UNSET,
                 use_pallas=UNSET) -> jax.Array:
    """Sig-kernel score  E[k(X,X')]/2 − E[k(X,y)]  for one observation y (L, d).

    A strictly proper scoring rule for path-valued prediction [24].
    ``E[k(X,X')]`` averages over distinct pairs (divides by ``b·(b−1)``), so
    the ensemble needs at least two members.  Configured like :func:`mmd2`;
    ``lengths`` (B,) makes the ensemble ragged, ``length_y`` (a scalar int)
    gives the observation's true point count.  ``streaming=`` streams both
    terms as per-block partial sums exactly as in :func:`mmd2` (auto-on when
    ``row_block=`` is set) — the (B, B) ensemble Gram never exists.
    ``features=`` / ``error_budget=`` activate the approximate feature-map
    backends (streaming by default), as in :func:`mmd2`.
    """
    b = X.shape[0]
    if b < 2:
        raise ValueError(
            f"scoring_rule needs an ensemble of >= 2 paths (got B={b}); "
            "the 1/(b·(b-1)) normaliser is NaN at b=1")
    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    approx = features is not None or error_budget is not None
    kw = dict(transforms=cfg, grid=g, static_kernel=kernel,
              backend=backend, row_block=row_block, use_pallas=use_pallas,
              features=features, error_budget=error_budget)
    ly = None if length_y is None else jnp.reshape(length_y, (1,))
    if _use_streaming(streaming, row_block, approx):
        rkw = dict(kw, check_streaming=True)
        exx_sum = sigkernel_gram_reduce(X, lengths=lengths,
                                        include_diag=False, **rkw)
        exy_sum = sigkernel_gram_reduce(X, y[None], lengths=lengths,
                                        lengths_y=ly, **rkw)
        return 0.5 * exx_sum / (b * (b - 1)) - exy_sum / b
    Kxx = sigkernel_gram(X, lengths=lengths, **kw)
    exx = (Kxx.sum() - jnp.trace(Kxx)) / (b * (b - 1))
    Kxy = sigkernel_gram(X, y[None], lengths=lengths, lengths_y=ly, **kw)
    return 0.5 * exx - Kxy.mean()


def sig_aux_loss(hidden: jax.Array, target: jax.Array, *, proj: jax.Array,
                 transforms=None, grid=None, static_kernel=None,
                 backend: str = "auto", row_block: Optional[int] = None,
                 streaming: Optional[bool] = None,
                 lengths=None, lengths_target=None,
                 features=None, error_budget=None,
                 lam1=UNSET, lam2=UNSET, time_aug=UNSET, lead_lag=UNSET,
                 use_pallas=UNSET) -> jax.Array:
    """Auxiliary sig-kernel loss between a model's hidden trajectory and a
    target path distribution (the glue attaching the paper's technique to any
    sequence architecture — DESIGN.md §5).

    hidden: (B, L, H) hidden states; proj: (H, d) fixed/learned projection into
    a low-dim path space; target: (B, L, d) reference paths.  ``lengths`` /
    ``lengths_target`` (each (B,)) make the corresponding side ragged — e.g.
    packed batches of variable-length sequences.  The legacy
    ``time_aug=``/``lead_lag=`` bools are accepted as the same deprecated
    aliases its siblings :func:`mmd2`/:func:`scoring_rule` take (one
    DeprecationWarning per call-site, identical results).  ``streaming=``,
    ``features=`` and ``error_budget=`` pass through to :func:`mmd2` — an
    active approximation makes the auxiliary loss O(B·rank), which is what
    lets it ride along every training step of a large model.
    """
    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    path = hidden @ proj                      # (B, L, d)
    # normalise scale so the PDE stays well-conditioned for wide layers
    path = path / jnp.sqrt(jnp.asarray(proj.shape[0], path.dtype))
    return mmd2(path, target, transforms=cfg, grid=g, static_kernel=kernel,
                unbiased=False, backend=backend, row_block=row_block,
                streaming=streaming, lengths=lengths,
                lengths_y=lengths_target, features=features,
                error_budget=error_budget, use_pallas=use_pallas)
