"""Signature-kernel training losses.

The workload pySigLib exists to accelerate: sig-kernel scores for training
generative models on time series (paper §1; refs [16, 21, 24]).  All losses
are differentiable through the exact one-pass backward of
``repro.core.sigkernel``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .sigkernel import sigkernel_gram


def mmd2(X: jax.Array, Y: jax.Array, *, lam1: int = 0, lam2: int = 0,
         time_aug: bool = False, lead_lag: bool = False,
         unbiased: bool = True, use_pallas: bool = False) -> jax.Array:
    """Squared MMD between two path distributions under the signature kernel.

    X: (Bx, L, d) samples from P;  Y: (By, L', d) samples from Q.
    """
    kw = dict(lam1=lam1, lam2=lam2, time_aug=time_aug, lead_lag=lead_lag,
              use_pallas=use_pallas)
    Kxx = sigkernel_gram(X, X, **kw)
    Kyy = sigkernel_gram(Y, Y, **kw)
    Kxy = sigkernel_gram(X, Y, **kw)
    bx, by = X.shape[0], Y.shape[0]
    if unbiased:
        sxx = (Kxx.sum() - jnp.trace(Kxx)) / (bx * (bx - 1))
        syy = (Kyy.sum() - jnp.trace(Kyy)) / (by * (by - 1))
    else:
        sxx = Kxx.mean()
        syy = Kyy.mean()
    return sxx + syy - 2.0 * Kxy.mean()


def scoring_rule(X: jax.Array, y: jax.Array, *, lam1: int = 0, lam2: int = 0,
                 time_aug: bool = False, lead_lag: bool = False,
                 use_pallas: bool = False) -> jax.Array:
    """Sig-kernel score  E[k(X,X')]/2 − E[k(X,y)]  for one observation y (L, d).

    A strictly proper scoring rule for path-valued prediction [24].
    """
    kw = dict(lam1=lam1, lam2=lam2, time_aug=time_aug, lead_lag=lead_lag,
              use_pallas=use_pallas)
    Kxx = sigkernel_gram(X, X, **kw)
    b = X.shape[0]
    exx = (Kxx.sum() - jnp.trace(Kxx)) / (b * (b - 1))
    Kxy = sigkernel_gram(X, y[None], **kw)
    return 0.5 * exx - Kxy.mean()


def sig_aux_loss(hidden: jax.Array, target: jax.Array, *, proj: jax.Array,
                 lam1: int = 0, lam2: int = 0,
                 use_pallas: bool = False) -> jax.Array:
    """Auxiliary sig-kernel loss between a model's hidden trajectory and a
    target path distribution (the glue attaching the paper's technique to any
    sequence architecture — DESIGN.md §5).

    hidden: (B, L, H) hidden states; proj: (H, d) fixed/learned projection into
    a low-dim path space; target: (B, L, d) reference paths.
    """
    path = hidden @ proj                      # (B, L, d)
    # normalise scale so the PDE stays well-conditioned for wide layers
    path = path / jnp.sqrt(jnp.asarray(proj.shape[0], path.dtype))
    return mmd2(path, target, lam1=lam1, lam2=lam2, unbiased=False,
                use_pallas=use_pallas)
