"""Core signature computations — the paper's contribution as composable JAX ops."""

from . import config
from . import dispatch
from . import lyndon
from . import tensoralg
from .config import (GridConfig, LaunchConfig, Linear, RBF, StaticKernel,
                     TransformPipeline, delta_from_gram)
from .features import FeatureConfig
from . import features
from .signature import (signature, signature_direct, signature_combine,
                        path_increments, transformed_dim)
from .logsignature import (logsignature, logsignature_combine,
                           logsignature_dim)
from .sigkernel import (sigkernel, solve_goursat,
                        solve_goursat_grad, delta_matrix)
from .gram import (sigkernel_gram, sigkernel_gram_reduce,
                   sigkernel_gram_sharded)
from .sigkernel import sigkernel_gram_blocked
from .transforms import (time_augment, lead_lag, basepoint,
                         transform_increments, transform_path,
                         pad_ragged, bucket_length)
from . import gram
from . import losses

__all__ = [
    "config", "dispatch", "features", "gram", "lyndon", "tensoralg",
    "TransformPipeline", "GridConfig", "LaunchConfig", "FeatureConfig",
    "StaticKernel", "Linear", "RBF",
    "delta_from_gram",
    "signature", "signature_direct",
    "signature_combine", "path_increments", "transformed_dim",
    "logsignature", "logsignature_combine", "logsignature_dim",
    "sigkernel", "sigkernel_gram", "sigkernel_gram_blocked",
    "sigkernel_gram_reduce", "sigkernel_gram_sharded",
    "solve_goursat", "solve_goursat_grad", "delta_matrix", "time_augment",
    "lead_lag", "basepoint", "transform_increments", "transform_path",
    "pad_ragged", "bucket_length",
    "losses",
]
