"""Unified solver-backend registry and dispatch (the single switchboard).

Every compute-heavy entry point (``signature``, ``logsignature``,
``sigkernel``, the Gram engine in :mod:`repro.core.gram` and the losses on
top of it) selects its execution path through this registry instead of
ad-hoc ``use_pallas`` bools / ``solver=`` strings.  A backend is a *named*
implementation with capability flags; ``"auto"`` resolves per op from the
active JAX platform and the problem shape.

Registered backends:

``"reference"``
    Pure-JAX row-major scans (oracle-grade, serial).  Works everywhere,
    exact one-pass backward for the sig-kernel ops.
``"antidiag"``
    Vectorised anti-diagonal wavefront (SIMD on CPU/GPU).  Sig-kernel ops
    only; the exact backward recomputes the reference grid.
``"pallas"``
    Pallas TPU kernels (compiled on TPU, interpret mode elsewhere).
    Checkpointed exact backward for the PDE; Horner kernel for signatures.
``"pallas_fused"``
    Fused-Δ Pallas PDE kernels: Δ is built in VMEM from the increments and
    never exists in HBM.  Gram-capable; differentiable via the checkpointed
    exact backward (which re-materialises Δ for the reverse sweep only).
``"rff"`` / ``"nystroem"``
    Approximate feature-map Gram backends (:mod:`repro.core.features`):
    random Fourier signature features and Nyström landmark low-rank.
    Flagged ``approximate=True`` — never resolved for an exact request;
    ``"auto"`` may pick them only when the caller passes an
    ``error_budget=`` and the autotune cache holds a measured frontier
    entry meeting it (:func:`resolve_approx`).
``"auto"``
    Measured winner from the on-disk autotune cache when one exists for the
    (op, shape, dtype, platform) key (:mod:`repro.bench.autotune`);
    shape/platform heuristics when the cache is cold or autotuning is
    disabled (``REPRO_DISABLE_AUTOTUNE=1``).

The legacy ``use_pallas=``/``solver=`` kwargs survive as thin deprecation
shims: :func:`canonicalize` maps them onto backend names with a
``DeprecationWarning`` (once per call-site).
"""

from __future__ import annotations

import dataclasses
import functools
import os
import sys
import threading
import warnings
from typing import Dict, FrozenSet, Optional, Tuple

import jax

#: ops a backend can serve
OPS = ("signature", "logsignature", "sigkernel", "gram")

#: sentinel distinguishing "kwarg not passed" from an explicit value
UNSET = object()

#: below this many refined PDE cells the serial reference scan wins on
#: CPU/GPU (the anti-diagonal skew/gather overhead dominates tiny grids)
_ANTIDIAG_MIN_CELLS = 4096


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability card for one named backend."""

    name: str
    ops: FrozenSet[str]
    #: backward is the paper's exact one-pass scheme (§2.4 / §3.4 Alg 4),
    #: not plain autodiff through the forward
    grad_exact: bool
    #: can produce a whole Gram matrix without materialising every pairwise
    #: Δ in HBM up front
    gram_capable: bool
    #: compiled only on TPU; elsewhere it runs in (slow) interpret mode
    needs_tpu: bool
    #: consumes path increments directly — Δ never exists in HBM
    fused: bool = False
    #: result is an *approximation* (feature-map inner products, not the
    #: exact PDE kernel) — refused unless the caller opted in with
    #: ``features=`` / ``error_budget=``; never an ``"auto"`` winner for
    #: an exact request
    approximate: bool = False
    #: Goursat cell-update stencils this backend implements
    #: (:data:`repro.core.config.GRID_SCHEMES`).  A backend that does not
    #: implement the requested ``GridConfig.scheme`` is *refused* with an
    #: error — never silently downgraded to another stencil.
    schemes: FrozenSet[str] = frozenset({"order1", "order2"})


_REGISTRY: Dict[str, BackendSpec] = {}


def register(spec: BackendSpec) -> BackendSpec:
    """Add (or replace) a backend in the registry."""
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> BackendSpec:
    """Look up a backend by name; raise with the known names otherwise."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)} "
            f"(plus 'auto')") from None


def backends_for(op: str) -> Tuple[str, ...]:
    """Names of all registered backends that serve ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; known: {OPS}")
    return tuple(sorted(n for n, s in _REGISTRY.items() if op in s.ops))


register(BackendSpec("reference", frozenset(OPS), grad_exact=True,
                     gram_capable=False, needs_tpu=False))
register(BackendSpec("antidiag", frozenset({"sigkernel", "gram"}),
                     grad_exact=True, gram_capable=False, needs_tpu=False))
register(BackendSpec("pallas", frozenset(OPS), grad_exact=True,
                     gram_capable=False, needs_tpu=True))
register(BackendSpec("pallas_fused", frozenset({"sigkernel", "gram"}),
                     grad_exact=True, gram_capable=True, needs_tpu=True,
                     fused=True))
# feature-map approximations: differentiable (plain JAX autodiff through
# the feature maps — not the paper's one-pass exact-Gram backward, hence
# grad_exact=False), Gram-capable by construction (phi is (B, F); no B×B
# intermediate ever forms), platform-agnostic
register(BackendSpec("rff", frozenset({"gram"}), grad_exact=False,
                     gram_capable=True, needs_tpu=False, approximate=True,
                     schemes=frozenset({"order1"})))
register(BackendSpec("nystroem", frozenset({"gram"}), grad_exact=False,
                     gram_capable=True, needs_tpu=False, approximate=True,
                     schemes=frozenset({"order1"})))


# ---------------------------------------------------------------------------
# legacy-kwarg shims
# ---------------------------------------------------------------------------

#: user call-sites that already got their DeprecationWarning this process
_warned_sites: set = set()

#: hard cap on the dedup set: a pathological caller minting fresh call-sites
#: forever (exec'd snippets, generated code) must not grow memory without
#: bound — past the cap new sites still warn, they just stop deduplicating
_MAX_WARNED_SITES = 4096

#: this library's own package directory — frames under it are shim-internal
_PKG_DIR = os.path.dirname(os.path.dirname(os.path.realpath(__file__))) \
    + os.sep


@functools.lru_cache(maxsize=1024)
def _is_own_frame_file(filename: str) -> bool:
    """Whether a frame's co_filename lives under this library's install dir.

    Cached per filename: the frame walk runs on *every* deprecated call
    (even already-deduplicated ones), and realpath stats the filesystem.
    """
    return os.path.realpath(filename).startswith(_PKG_DIR)


def reset_warned_sites() -> None:
    """Forget which call-sites have warned (tests)."""
    _warned_sites.clear()


def _warn_deprecated(message: str) -> None:
    """Emit ``DeprecationWarning`` once per *user call-site*.

    The warning is attributed to the first stack frame whose file lives
    outside this library's own install directory (so internal shims —
    ``sigkernel.sigkernel_gram``, ``sigkernel_gram_blocked``, the losses —
    never absorb it, while a *user* script or package that merely happens
    to be named ``repro`` is correctly treated as the call-site) and
    deduplicated on that frame's (filename, lineno): a training loop
    passing ``use_pallas=`` every step warns once, not once per call,
    while distinct call-sites each get their own warning.  The dedup key
    deliberately excludes the message, so one call mixing several
    deprecated kwarg families (``lam1=`` + ``use_pallas=``) still emits
    exactly one warning per call-site.
    """
    depth = 1  # sys._getframe index; 0 is this helper
    frame = sys._getframe(1)
    while frame is not None and _is_own_frame_file(
            frame.f_code.co_filename):
        frame = frame.f_back
        depth += 1
    if frame is not None:
        site = (frame.f_code.co_filename, frame.f_lineno)
        if site in _warned_sites:
            return
        if len(_warned_sites) < _MAX_WARNED_SITES:
            _warned_sites.add(site)
    # warnings stacklevel n attributes to sys._getframe(n - 1) from here
    warnings.warn(message, DeprecationWarning, stacklevel=depth + 1)


def _validate(backend: str, op: str) -> str:
    """Check a concrete backend name exists and implements ``op``."""
    spec = get(backend)
    if op not in spec.ops:
        raise ValueError(
            f"backend {backend!r} does not implement op {op!r}; "
            f"options: {backends_for(op)}")
    return backend


def check_scheme(backend: str, scheme: str, *, op: str) -> str:
    """Refuse a backend that does not implement the requested stencil.

    The scheme capability contract (ISSUE: no silent downgrades): a backend
    whose :attr:`BackendSpec.schemes` does not contain
    ``GridConfig.scheme`` raises, naming the knob, the backend's supported
    schemes, and the backends that *do* implement the request — it is never
    quietly served with a different discretisation.
    """
    spec = get(backend)
    if scheme not in spec.schemes:
        capable = tuple(n for n in backends_for(op)
                        if scheme in get(n).schemes)
        raise ValueError(
            f"backend {backend!r} does not implement "
            f"GridConfig.scheme={scheme!r} (it supports "
            f"{tuple(sorted(spec.schemes))}); schemes are never silently "
            f"downgraded — pick a capable backend for op {op!r}: {capable}, "
            f"or a supported scheme (docs/solver_guide.md, 'Choosing a "
            f"scheme order')")
    return backend


def canonicalize(backend: str, *, op: str, use_pallas=UNSET,
                 solver=UNSET) -> str:
    """Map legacy ``use_pallas``/``solver`` kwargs onto a backend name.

    ``backend`` wins when it is not ``"auto"`` (validated against ``op``;
    contradictory legacy kwargs are ignored with a warning).
    ``use_pallas=True`` overrides ``solver=`` — the historical precedence of
    ``sigkernel_gram_blocked``.  ``use_pallas=None`` is the historical
    documented "auto" and stays silent; explicit bools and ``solver=``
    strings emit a ``DeprecationWarning`` once per call-site.  Returns a
    backend name (possibly still ``"auto"`` — resolve it with
    :func:`resolve`).
    """
    legacy_given = ((use_pallas is not UNSET and use_pallas is not None)
                    or (solver is not UNSET and solver is not None))
    if backend != "auto":
        if legacy_given:
            _warn_deprecated(
                f"deprecated use_pallas=/solver= ignored because "
                f"backend={backend!r} was passed explicitly")
        return _validate(backend, op)
    if use_pallas is not UNSET and use_pallas is not None:
        _warn_deprecated(
            "use_pallas= is deprecated; pass backend='pallas' / "
            "backend='reference' instead (docs/solver_guide.md)")
        if use_pallas:  # historically overrode solver=
            return "pallas"
        if solver is UNSET or solver is None:
            return "reference"
    if solver is not UNSET and solver is not None:
        _warn_deprecated(
            "solver= is deprecated; pass backend='antidiag' / "
            "backend='reference' instead (docs/solver_guide.md)")
        return "antidiag" if solver == "antidiag" else "reference"
    return "auto"


# ---------------------------------------------------------------------------
# auto-selection
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _autotuned(op: str, shape, dtype, ragged: bool = False) -> Optional[str]:
    """Winning backend from the on-disk autotune cache, or None.

    None (→ static heuristics) whenever the cache is cold, autotuning is
    disabled (``REPRO_DISABLE_AUTOTUNE=1``), the cache file is unreadable,
    or the cached name no longer denotes a live backend serving ``op``.
    Lookups never run a measurement — tuning happens only through
    :func:`repro.bench.autotune.tune` (the bench suite does this).
    ``ragged`` keys variable-length workloads separately: the same padded
    shape does very different work when most of it is masked.
    """
    if shape is None:
        return None
    try:
        from repro.bench import autotune
    except ImportError:
        return None
    if not autotune.enabled():
        return None
    try:
        name = autotune.lookup(op, shape, dtype or "float32", ragged=ragged)
    except (ValueError, TypeError):
        return None
    spec = _REGISTRY.get(name)
    if spec is None or op not in spec.ops:
        return None  # stale entry: backend renamed/removed since tuning
    if spec.needs_tpu and not on_tpu():
        return None  # never let a stale entry force interpret mode
    if spec.approximate:
        # exact-winner cache keys must never return an approximation; the
        # budgeted path goes through resolve_approx → lookup_budget
        return None
    return name


def _autotuned_launch(op: str, shape, dtype, ragged: bool = False):
    """Tuned :class:`repro.core.config.LaunchConfig` for this key, or None.

    Same fail-open discipline as :func:`_autotuned`: any problem — cold
    cache, disabled autotune, unreadable file, a launch dict with invalid
    values, a fingerprint from another machine — yields None and the
    library defaults.  Lookups never measure anything.
    """
    if shape is None:
        return None
    try:
        from repro.bench import autotune
    except ImportError:
        return None
    if not autotune.enabled():
        return None
    try:
        return autotune.lookup_launch(op, shape, dtype or "float32",
                                      ragged=ragged)
    except (ValueError, TypeError):
        return None


def resolve_launch(launch=None, *, op: str, shape=None, dtype=None,
                   ragged: bool = False):
    """Concrete :class:`LaunchConfig`: explicit > autotuned > defaults.

    The companion of :func:`resolve` for kernel *launch parameters*: an
    explicit ``launch=`` from the caller always wins; otherwise the
    autotune cache may hold a swept winner for the same
    ``(op, shape-bucket, dtype, platform, ragged)`` key that stores the
    backend winner; otherwise every knob stays at the library default
    (bitwise-identical to the pre-tuning constants).
    """
    from .config import LaunchConfig, resolve_launch as _check
    if launch is not None:
        return _check(launch)
    tuned = _autotuned_launch(op, shape, dtype, ragged)
    return tuned if tuned is not None else LaunchConfig()


def resolve(backend: str, *, op: str, grid_cells: Optional[int] = None,
            shape=None, dtype=None, allow_fused: bool = True,
            ragged: bool = False, allow_approximate: bool = False,
            scheme: str = "order1") -> str:
    """Resolve ``"auto"`` to a concrete backend name for ``op``.

    When ``shape`` is given (the per-op cache-key shape documented in
    :func:`repro.bench.autotune.cache_key`) and the autotune cache holds a
    measured winner for it, that wins.  Otherwise the static heuristics
    apply: ``grid_cells`` is the refined PDE cell count ``nx·ny``
    (sig-kernel ops only); small grids stay on the serial reference scan
    where the wavefront's skew overhead is not worth paying.

    ``allow_fused=False`` keeps ``"auto"`` off fused-Δ backends — used when
    Δ is not a plain increment matmul (non-linear static-kernel lifts),
    which a fused kernel cannot build in VMEM.  ``ragged=True`` marks a
    variable-length (``lengths=``) workload: its autotune cache key is kept
    separate from the dense key of the same padded shape.

    ``allow_approximate=False`` (the default) means the caller wants the
    exact kernel: backends flagged ``approximate=True`` are *refused* even
    when named explicitly — opting in requires ``features=`` or
    ``error_budget=`` on the Gram/loss entry points, which resolve with
    ``allow_approximate=True``.  ``"auto"`` never returns an approximate
    backend from this function either way (the budgeted route is
    :func:`resolve_approx`).

    ``scheme`` is the requested :class:`repro.GridConfig` stencil: a
    concrete backend (explicit *or* auto/autotuned winner) that does not
    list it in :attr:`BackendSpec.schemes` is refused via
    :func:`check_scheme` — the discretisation is never silently swapped.
    """
    if backend != "auto":
        name = _validate(backend, op)
        if get(name).approximate and not allow_approximate:
            raise ValueError(
                f"backend {name!r} is flagged approximate=True (feature-map "
                f"inner products, not the exact PDE kernel) and an exact "
                f"result was requested; pass features=FeatureConfig(...) or "
                f"error_budget= to opt in (docs/api/public.md, 'Approximate "
                f"kernels'), or pick an exact backend: "
                f"{tuple(n for n in backends_for(op) if not get(n).approximate)}")
        return check_scheme(name, scheme, op=op)
    tuned = _autotuned(op, shape, dtype, ragged)
    if tuned is not None and (allow_fused or not get(tuned).fused) \
            and scheme in get(tuned).schemes:
        return tuned
    if op in ("signature", "logsignature"):
        return "pallas" if on_tpu() else "reference"
    if on_tpu():
        name = "pallas_fused" if op == "gram" and allow_fused else "pallas"
    elif grid_cells is not None and grid_cells >= _ANTIDIAG_MIN_CELLS:
        name = "antidiag"
    else:
        name = "reference"
    return check_scheme(name, scheme, op=op)


def resolve_approx(op: str, shape=None, dtype=None, *,
                   error_budget: float, ragged: bool = False
                   ) -> Optional[Tuple[str, int]]:
    """Cheapest approximate backend meeting ``error_budget``, or None.

    The only road by which ``"auto"`` may legally land on an approximate
    backend: the caller supplied an explicit relative-error budget, and the
    autotune cache holds a *measured* accuracy-vs-speed frontier for this
    ``(op, shape-bucket, dtype, platform)`` key
    (:func:`repro.bench.autotune.tune_frontier`, run by the bench suite's
    ``approx_frontier`` workload) with an entry whose measured relative
    error fits the budget *and* that beat the exact engine's wall clock.
    Returns ``(backend_name, rank)`` or None — same fail-open discipline as
    :func:`_autotuned`: cold cache, disabled autotune, unreadable file,
    foreign machine stamp, or no qualifying point all mean None (→ the
    exact engine).
    """
    if shape is None or error_budget is None:
        return None
    try:
        from repro.bench import autotune
    except ImportError:
        return None
    if not autotune.enabled():
        return None
    try:
        found = autotune.lookup_budget(op, shape, dtype or "float32",
                                       error_budget, ragged=ragged)
    except (ValueError, TypeError):
        return None
    if found is None:
        return None
    name, rank = found
    spec = _REGISTRY.get(name)
    if spec is None or op not in spec.ops or not spec.approximate:
        return None  # stale frontier entry
    return name, int(rank)


def resolve_scheme(op: str, shape=None, dtype=None, *,
                   error_budget: float, ragged: bool = False
                   ) -> Optional[Tuple[str, int, str]]:
    """Cheapest measured *discretisation* meeting ``error_budget``, or None.

    The exact-engine sibling of :func:`resolve_approx`: instead of
    swapping the PDE solve for feature maps, the scheme frontier trades
    stencil order, grid coarseness and interior precision — the autotune
    cache (:func:`repro.bench.autotune.tune_scheme_frontier`, recorded by
    the bench suite's ``scheme_frontier`` workload) holds measured
    ``(scheme, coarsen, interior_dtype)`` points with their relative error
    against the order-1 fine-grid f32 baseline.  Returns the cheapest
    point that fits the budget *and* beat the baseline's wall clock, or
    None under the same fail-open discipline as :func:`resolve_approx`
    (cold cache, autotune disabled, foreign machine, no qualifying
    point).  Only consulted when the caller left ``GridConfig.scheme`` /
    ``interior_dtype`` at their defaults — an explicit choice is never
    overridden.
    """
    if shape is None or error_budget is None:
        return None
    try:
        from repro.bench import autotune
    except ImportError:
        return None
    if not autotune.enabled():
        return None
    try:
        found = autotune.lookup_scheme_budget(op, shape, dtype or "float32",
                                              error_budget, ragged=ragged)
    except (ValueError, TypeError):
        return None
    if found is None:
        return None
    scheme, coarsen, idt = found
    from repro.kernels.sigkernel_pde import stencil
    if scheme not in stencil.SCHEMES or idt not in stencil.INTERIOR_DTYPES:
        return None  # stale frontier entry
    return scheme, int(coarsen), idt


# ---------------------------------------------------------------------------
# op accounting (used by tests / the benchmark smoke job to verify the
# symmetric-Gram fast path really does ~half the PDE solves, and by the
# streaming Path engine to prove interval queries never re-scan a path)
# ---------------------------------------------------------------------------

_count_state = threading.local()


class _op_counter:
    """Context manager counting one op kind issued at *trace* time.

    Counts are per-thread and only reflect traces executed inside the
    context (jit cache hits recompute nothing and therefore count nothing —
    call on fresh shapes).
    """

    _slot: str = ""

    def __init__(self):
        self.total = 0

    def __enter__(self):
        self._prev = getattr(_count_state, self._slot, None)
        setattr(_count_state, self._slot, self)
        return self

    def __exit__(self, *exc):
        setattr(_count_state, self._slot, self._prev)
        return False


def _record(slot: str, n: int) -> None:
    active = getattr(_count_state, slot, None)
    if active is not None:
        active.total += int(n)


class count_pair_solves(_op_counter):
    """Counts PDE pair-solves: the engine reports the batch size it hands to
    each solver call (including any padding), so ``with count_pair_solves()
    as c: ...; c.total`` is the number of Goursat problems solved."""

    _slot = "pair"


class count_scan_steps(_op_counter):
    """Counts signature Horner-scan steps (one per increment folded).

    ``repro.core.signature`` reports the increment-stream length of every
    scan it traces, so ``c.total`` is how many path increments were
    re-processed — the quantity the streaming ``repro.Path`` engine drives
    to zero for interval queries and to O(chunk) for ``update()``.
    """

    _slot = "scan"


class count_combines(_op_counter):
    """Counts Chen combines issued by the streaming ``repro.Path`` engine
    (one per interval query; O(chunk) per ``update``)."""

    _slot = "combine"


def record_pair_solves(n: int) -> None:
    """Report ``n`` PDE pair-solves to the active counter (no-op otherwise)."""
    _record("pair", n)


def record_scan_steps(n: int) -> None:
    """Report ``n`` Horner-scan steps to the active counter."""
    _record("scan", n)


def record_combines(n: int) -> None:
    """Report ``n`` Chen combines to the active counter."""
    _record("combine", n)
