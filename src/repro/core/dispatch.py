"""Unified solver-backend registry and dispatch (the single switchboard).

Every compute-heavy entry point (``signature``, ``logsignature``,
``sigkernel``, the Gram engine in :mod:`repro.core.gram` and the losses on
top of it) selects its execution path through this registry instead of
ad-hoc ``use_pallas`` bools / ``solver=`` strings.  A backend is a *named*
implementation with capability flags; ``"auto"`` resolves per op from the
active JAX platform and the problem shape.

Registered backends:

``"reference"``
    Pure-JAX row-major scans (oracle-grade, serial).  Works everywhere,
    exact one-pass backward for the sig-kernel ops.
``"antidiag"``
    Vectorised anti-diagonal wavefront (SIMD on CPU/GPU).  Sig-kernel ops
    only; the exact backward recomputes the reference grid.
``"pallas"``
    Pallas TPU kernels (compiled on TPU, interpret mode elsewhere).
    Checkpointed exact backward for the PDE; Horner kernel for signatures.
``"pallas_fused"``
    Fused-Δ Pallas PDE kernels: Δ is built in VMEM from the increments and
    never exists in HBM.  Gram-capable; differentiable via the checkpointed
    exact backward (which re-materialises Δ for the reverse sweep only).
``"auto"``
    Shape/platform-aware choice of the above.

The legacy ``use_pallas=``/``solver=`` kwargs survive as thin deprecation
shims: :func:`canonicalize` maps them onto backend names with a
``DeprecationWarning``.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Dict, FrozenSet, Optional, Tuple

import jax

#: ops a backend can serve
OPS = ("signature", "logsignature", "sigkernel", "gram")

#: sentinel distinguishing "kwarg not passed" from an explicit value
UNSET = object()

#: below this many refined PDE cells the serial reference scan wins on
#: CPU/GPU (the anti-diagonal skew/gather overhead dominates tiny grids)
_ANTIDIAG_MIN_CELLS = 4096


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability card for one named backend."""

    name: str
    ops: FrozenSet[str]
    #: backward is the paper's exact one-pass scheme (§2.4 / §3.4 Alg 4),
    #: not plain autodiff through the forward
    grad_exact: bool
    #: can produce a whole Gram matrix without materialising every pairwise
    #: Δ in HBM up front
    gram_capable: bool
    #: compiled only on TPU; elsewhere it runs in (slow) interpret mode
    needs_tpu: bool
    #: consumes path increments directly — Δ never exists in HBM
    fused: bool = False


_REGISTRY: Dict[str, BackendSpec] = {}


def register(spec: BackendSpec) -> BackendSpec:
    """Add (or replace) a backend in the registry."""
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> BackendSpec:
    """Look up a backend by name; raise with the known names otherwise."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)} "
            f"(plus 'auto')") from None


def backends_for(op: str) -> Tuple[str, ...]:
    """Names of all registered backends that serve ``op``."""
    if op not in OPS:
        raise ValueError(f"unknown op {op!r}; known: {OPS}")
    return tuple(sorted(n for n, s in _REGISTRY.items() if op in s.ops))


register(BackendSpec("reference", frozenset(OPS), grad_exact=True,
                     gram_capable=False, needs_tpu=False))
register(BackendSpec("antidiag", frozenset({"sigkernel", "gram"}),
                     grad_exact=True, gram_capable=False, needs_tpu=False))
register(BackendSpec("pallas", frozenset(OPS), grad_exact=True,
                     gram_capable=False, needs_tpu=True))
register(BackendSpec("pallas_fused", frozenset({"sigkernel", "gram"}),
                     grad_exact=True, gram_capable=True, needs_tpu=True,
                     fused=True))


# ---------------------------------------------------------------------------
# legacy-kwarg shims
# ---------------------------------------------------------------------------

def _validate(backend: str, op: str) -> str:
    """Check a concrete backend name exists and implements ``op``."""
    spec = get(backend)
    if op not in spec.ops:
        raise ValueError(
            f"backend {backend!r} does not implement op {op!r}; "
            f"options: {backends_for(op)}")
    return backend


def canonicalize(backend: str, *, op: str, use_pallas=UNSET,
                 solver=UNSET) -> str:
    """Map legacy ``use_pallas``/``solver`` kwargs onto a backend name.

    ``backend`` wins when it is not ``"auto"`` (validated against ``op``;
    contradictory legacy kwargs are ignored with a warning).
    ``use_pallas=True`` overrides ``solver=`` — the historical precedence of
    ``sigkernel_gram_blocked``.  ``use_pallas=None`` is the historical
    documented "auto" and stays silent; explicit bools and ``solver=``
    strings emit a ``DeprecationWarning``.  Returns a backend name
    (possibly still ``"auto"`` — resolve it with :func:`resolve`).
    """
    legacy_given = ((use_pallas is not UNSET and use_pallas is not None)
                    or (solver is not UNSET and solver is not None))
    if backend != "auto":
        if legacy_given:
            warnings.warn(
                f"deprecated use_pallas=/solver= ignored because "
                f"backend={backend!r} was passed explicitly",
                DeprecationWarning, stacklevel=3)
        return _validate(backend, op)
    if use_pallas is not UNSET and use_pallas is not None:
        warnings.warn(
            "use_pallas= is deprecated; pass backend='pallas' / "
            "backend='reference' instead (docs/solver_guide.md)",
            DeprecationWarning, stacklevel=3)
        if use_pallas:  # historically overrode solver=
            return "pallas"
        if solver is UNSET or solver is None:
            return "reference"
    if solver is not UNSET and solver is not None:
        warnings.warn(
            "solver= is deprecated; pass backend='antidiag' / "
            "backend='reference' instead (docs/solver_guide.md)",
            DeprecationWarning, stacklevel=3)
        return "antidiag" if solver == "antidiag" else "reference"
    return "auto"


# ---------------------------------------------------------------------------
# auto-selection
# ---------------------------------------------------------------------------

def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(backend: str, *, op: str,
            grid_cells: Optional[int] = None) -> str:
    """Resolve ``"auto"`` to a concrete backend name for ``op``.

    ``grid_cells`` is the refined PDE cell count ``nx·ny`` (sig-kernel ops
    only); small grids stay on the serial reference scan where the
    wavefront's skew overhead is not worth paying.
    """
    if backend != "auto":
        return _validate(backend, op)
    if op in ("signature", "logsignature"):
        return "pallas" if on_tpu() else "reference"
    if on_tpu():
        return "pallas_fused" if op == "gram" else "pallas"
    if grid_cells is not None and grid_cells >= _ANTIDIAG_MIN_CELLS:
        return "antidiag"
    return "reference"


# ---------------------------------------------------------------------------
# pair-solve accounting (used by tests / the benchmark smoke job to verify
# the symmetric-Gram fast path really does ~half the PDE solves)
# ---------------------------------------------------------------------------

_count_state = threading.local()


class count_pair_solves:
    """Context manager counting PDE pair-solves issued at *trace* time.

    The engine reports the batch size it hands to each solver call (including
    any padding), so ``with count_pair_solves() as c: ...; c.total`` is the
    number of Goursat problems solved.  Counts are per-thread and only
    reflect traces executed inside the context (jit cache hits recompute
    nothing and therefore count nothing — call on fresh shapes).
    """

    def __init__(self):
        self.total = 0

    def __enter__(self):
        self._prev = getattr(_count_state, "active", None)
        _count_state.active = self
        return self

    def __exit__(self, *exc):
        _count_state.active = self._prev
        return False


def record_pair_solves(n: int) -> None:
    """Report ``n`` PDE pair-solves to the active counter (no-op otherwise)."""
    active = getattr(_count_state, "active", None)
    if active is not None:
        active.total += int(n)
