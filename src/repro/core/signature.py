"""Truncated path signatures (pySigLib §2) in pure JAX.

Implements both algorithms from the paper:

* Algorithm 1 — the *direct* update (à la ``iisignature``), used as an
  independently-written cross-check oracle.
* Algorithm 2 — *Horner's scheme* (à la ``signatory``), the production path.

Both follow the paper's memory discipline conceptually (flat contiguous level
layout, reverse-order level updates); the literal in-place buffer reuse is
realised in the Pallas kernels (``repro.kernels.signature``), while here the
same arithmetic is expressed functionally for XLA.

Backpropagation (§2.4) uses the time-reversed-path deconstruction of
Reizenstein [42, §4.9]: the backward pass never stores intermediate signatures;
it *reconstructs* S(x_{1:ℓ}) from S(x_{1:ℓ+1}) by Chen-multiplying with
exp(-z_ℓ) (the signature of the reversed segment), so backward memory is O(1)
in path length.  Implemented as a ``jax.custom_vjp``.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp

from . import tensoralg as ta
from .dispatch import UNSET


# ---------------------------------------------------------------------------
# increments (with optional on-the-fly transforms, §4)
# ---------------------------------------------------------------------------

def path_increments(path: jax.Array) -> jax.Array:
    """z_ℓ = x_{ℓ+1} - x_ℓ along the second-to-last axis."""
    return path[..., 1:, :] - path[..., :-1, :]


def _effective_increments(path: jax.Array, pipeline,
                          lengths=None) -> jax.Array:
    """Increment stream with a §4 :class:`TransformPipeline` applied on-the-fly.

    Never materialises the transformed path; only its increments, which is all
    the signature algorithms consume.  Delegates to
    :func:`repro.core.transforms.pipeline_increments`.  With ``lengths=``
    (ragged batches) padded increments are zeroed in place — exact no-ops
    for the Horner recursion — so the valid prefix stays first.
    """
    from . import transforms as tf
    return tf.pipeline_increments(path, pipeline, lengths, align="start")


def transformed_dim(d: int, time_aug: bool, lead_lag: bool) -> int:
    """Channel dimension after on-the-fly transforms.

    Prefer :meth:`repro.TransformPipeline.transformed_dim`; this helper is
    kept for the bool-flag call sites.
    """
    if lead_lag:
        d = 2 * d
    if time_aug:
        d = d + 1
    return d


# ---------------------------------------------------------------------------
# Algorithm 1 — direct
# ---------------------------------------------------------------------------

def _direct_step(levels: List[jax.Array], z: jax.Array, depth: int) -> List[jax.Array]:
    """A_k <- Σ_{i=0}^{k} A_i ⊗ z^{⊗(k-i)}/(k-i)!  (reverse level order)."""
    ez = ta.tensor_exp_levels(z, depth)
    new = list(levels)
    for k in range(depth, 0, -1):           # reverse order: reads only A_i, i<k
        acc = levels[k - 1] + ez[k - 1]     # i=k term (A_k) + i=0 term (z^{⊗k}/k!)
        for i in range(1, k):
            acc = acc + ta.outer(levels[i - 1], ez[k - i - 1])
        new[k - 1] = acc
    return new


# ---------------------------------------------------------------------------
# Algorithm 2 — Horner
# ---------------------------------------------------------------------------

def _horner_step(levels: List[jax.Array], z: jax.Array, depth: int) -> List[jax.Array]:
    """One path-step of Horner's scheme (Alg 2):

        A_k = (B_k + A_{k-1}) ⊗ z + A_k,
        B_k = ((...((z/k + A_1) ⊗ z/(k-1) + A_2) ⊗ z/(k-2) + ...) ⊗ z/2)
    """
    new = list(levels)
    for k in range(depth, 1, -1):
        b = z / k
        for i in range(1, k - 1):
            b = ta.outer(b + levels[i - 1], z / (k - i))
        b = b + levels[k - 2]               # + A_{k-1}
        new[k - 1] = ta.outer(b, z) + levels[k - 1]
    new[0] = levels[0] + z
    return new


# ---------------------------------------------------------------------------
# full signatures
# ---------------------------------------------------------------------------

def _signature_scan(z: jax.Array, d: int, depth: int, step_fn) -> jax.Array:
    """Scan a per-step update over the increment stream z (..., L-1, d)."""
    from .dispatch import record_scan_steps
    record_scan_steps(z.shape[-2])
    batch_shape = z.shape[:-2]
    init = [jnp.zeros((*batch_shape, s), dtype=z.dtype) for s in ta.level_sizes(d, depth)]
    zs = jnp.moveaxis(z, -2, 0)             # (L-1, ..., d) for scan

    def body(carry, zt):
        return step_fn(carry, zt, depth), None

    levels, _ = jax.lax.scan(body, init, zs)
    return ta.join_levels(levels)


def signature_direct(path: jax.Array, depth: int, *, transforms=None,
                     time_aug=UNSET, lead_lag=UNSET) -> jax.Array:
    """Truncated signature via Algorithm 1 (direct).  Cross-check oracle."""
    from .config import resolve_transforms
    cfg = resolve_transforms(transforms, time_aug, lead_lag)
    z = _effective_increments(path, cfg)
    return _signature_scan(z, z.shape[-1], depth, _direct_step)


def _signature_horner_from_increments(z: jax.Array, depth: int) -> jax.Array:
    return _signature_scan(z, z.shape[-1], depth, _horner_step)


# -- custom VJP: time-reversed deconstruction backward (§2.4) ---------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _signature_core(z: jax.Array, depth: int) -> jax.Array:
    return _signature_horner_from_increments(z, depth)


def _signature_core_fwd(z, depth):
    sig = _signature_horner_from_increments(z, depth)
    return sig, (z, sig)


def _signature_core_bwd(depth, res, g):
    z, sig = res
    d = z.shape[-1]

    def step(s_prev_flat, zt):
        """Local forward step as a flat->flat function for per-step VJP."""
        return ta.chen(s_prev_flat, ta.tensor_exp(zt, depth), d, depth)

    def body(carry, zt):
        s_after, g_after = carry
        # deconstruct: S_before = S_after ⊗ exp(-z)   (time-reversed segment)
        s_before = ta.chen(s_after, ta.tensor_exp(-zt, depth), d, depth)
        _, vjp = jax.vjp(step, s_before, zt)
        g_before, g_z = vjp(g_after)
        return (s_before, g_before), g_z

    zs = jnp.moveaxis(z, -2, 0)
    (_, _), g_z = jax.lax.scan(body, (sig, g), zs, reverse=True)
    return (jnp.moveaxis(g_z, 0, -2),)


_signature_core.defvjp(_signature_core_fwd, _signature_core_bwd)


def signature(path: jax.Array, depth: int, *, transforms=None,
              backend: str = "auto", stream: bool = False, lengths=None,
              launch=None,
              time_aug=UNSET, lead_lag=UNSET, use_pallas=None) -> jax.Array:
    """Truncated signature of a batch of piecewise-linear paths.

    Args:
      path: (..., L, d) discrete stream; linearly interpolated.
      depth: truncation level N.
      transforms: a :class:`repro.TransformPipeline` — §4 transforms
        (basepoint / lead-lag / time-aug over [t0, t1]), applied on-the-fly
        to increments.  Default: no transforms.
      backend: ``"reference"`` (pure-JAX Horner scan), ``"pallas"`` (the TPU
        kernel; interpret mode — slow — elsewhere), or ``"auto"`` (default):
        the registry in :mod:`repro.core.dispatch` picks "pallas" on TPU and
        "reference" on CPU/GPU.  With ``stream=True`` only ``"auto"`` /
        ``"reference"`` are valid (the streamed scan is pure JAX);
        explicitly requesting ``"pallas"`` raises instead of silently
        degrading.
      stream: if True return signatures of all prefixes (..., L-1, sig_dim).
      lengths: optional (...,) int array of per-path true point counts for
        ragged (variable-length) batches.  Each path is treated as if
        truncated to its own length — padding content is ignored, and the
        ``time_aug`` grid ends at ``t1`` at the *true* last point.  The
        length axis is padded up to a power-of-two bucket
        (:func:`repro.core.transforms.pad_ragged`) so nearby max-lengths
        share one jit trace.  With ``stream=True``, prefix entries at or
        past a path's true end repeat its final signature.
      launch: an optional :class:`repro.LaunchConfig`; its ``sig_bt`` /
        ``sig_lb`` knobs set the Pallas kernel's batch-tile and
        length-block shapes (``None`` fields fall back to the autotuned
        winner for this shape bucket, then to the library defaults).
        Tile geometry never changes the arithmetic — results are
        bitwise-identical across launch configs.  Ignored by the pure-JAX
        reference backend and the streamed scan.
      time_aug / lead_lag: deprecated bool aliases for ``transforms=``
        (DeprecationWarning once per call-site; bitwise-identical results).
      use_pallas: deprecated alias — ``True`` -> ``backend="pallas"``,
        ``False`` -> ``backend="reference"`` (with a DeprecationWarning);
        ``None`` keeps the historical meaning of auto.

    Returns:
      (..., sig_dim(d', depth)) flat signature (levels 1..depth), where d' is
      the transformed channel count (``transforms.transformed_dim(d)``).
    """
    from . import dispatch
    from . import transforms as tf
    from .config import resolve_transforms
    cfg = resolve_transforms(transforms, time_aug, lead_lag)
    if lengths is not None:
        path, lengths = tf.pad_ragged(path, lengths)
    z = _effective_increments(path, cfg, lengths)
    backend = dispatch.canonicalize(backend, op="signature",
                                    use_pallas=use_pallas)
    if stream:
        if backend != "auto" and backend != "reference":
            raise ValueError(
                f"signature(stream=True) has no {backend!r} implementation "
                "— the streamed prefix scan is pure JAX; pass "
                "backend='auto' or backend='reference'")
        return _signature_stream_from_increments(z, depth)
    key_shape = (z.shape[-2], z.shape[-1], depth)
    backend = dispatch.resolve(
        backend, op="signature", shape=key_shape,
        dtype=z.dtype, ragged=lengths is not None)
    if backend == "pallas":
        from repro.kernels.signature import ops as sig_ops
        launch = dispatch.resolve_launch(launch, op="signature",
                                         shape=key_shape, dtype=z.dtype,
                                         ragged=lengths is not None)
        return sig_ops.signature_from_increments(z, depth, launch)
    return _signature_core(z, depth)


def _signature_stream_from_increments(z: jax.Array, depth: int) -> jax.Array:
    """All prefix signatures: (..., L-1, sig_dim). Differentiable via scan."""
    from .dispatch import record_scan_steps
    record_scan_steps(z.shape[-2])
    d = z.shape[-1]
    batch_shape = z.shape[:-2]
    init = [jnp.zeros((*batch_shape, s), dtype=z.dtype) for s in ta.level_sizes(d, depth)]
    zs = jnp.moveaxis(z, -2, 0)

    def body(carry, zt):
        new = _horner_step(carry, zt, depth)
        return new, ta.join_levels(new)

    _, flats = jax.lax.scan(body, init, zs)
    return jnp.moveaxis(flats, 0, -2)


def signature_combine(sig_a: jax.Array, sig_b: jax.Array, d: int, depth: int) -> jax.Array:
    """Chen-combine signatures of consecutive path segments."""
    return ta.chen(sig_a, sig_b, d, depth)
