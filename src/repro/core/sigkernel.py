"""Signature kernels via the Goursat PDE (pySigLib §3) in pure JAX.

Forward (§3.1–§3.3): the 2nd-order discretisation (paper eq. (1))

    k̂_{i+1,j+1} = (k̂_{i+1,j} + k̂_{i,j+1})·A(Δ_{ij}) − k̂_{i,j}·B(Δ_{ij}),
    A(p) = 1 + p/2 + p²/12,   B(p) = 1 − p²/12,

over a dyadically refined grid of independent orders (λ1, λ2) — paper design
choice (1).  Δ is precomputed with ONE batched matmul (choice (2); on TPU this
is the MXU-bound part for large d) and the dyadic refinement is applied
ON-THE-FLY by index arithmetic (choice (3)); the refined path and refined Δ
are never materialised.

Backward (§3.4, Alg 4): pySigLib's novel *exact* gradient — differentiate the
solver itself.  One reverse wavefront pass computes

    ∂F/∂k̂_{i,j} = ∂F/∂k̂_{i+1,j}·A(Δ_{i,j−1}) + ∂F/∂k̂_{i,j+1}·A(Δ_{i−1,j})
                  − ∂F/∂k̂_{i+1,j+1}·B(Δ_{i,j})
    ∂F/∂Δ_{i,j} = ∂F/∂k̂_{i+1,j+1}·[(k̂_{i+1,j}+k̂_{i,j+1})·A'(Δ_{i,j})
                  − k̂_{i,j}·B'(Δ_{i,j})]

with A'(p) = 1/2 + p/6, B'(p) = −p/6, accumulated over refined cells onto the
unrefined Δ, then pulled back through the Δ-matmul to the paths.  This is
wired as ``jax.custom_vjp`` so ``jax.grad`` of any loss through
``sigkernel`` uses the exact one-pass scheme.

The reference solver here is a row-major double scan (oracle-grade, O(Lx·Ly)
serial).  The production wavefront solver lives in
``repro.kernels.sigkernel_pde`` (Pallas, anti-diagonal vectorisation with a
rotating 3-buffer in VMEM).

Schemes and mixed precision: the cell-update stencil is pluggable
(``GridConfig.scheme``) — the shared coefficient sets and the per-scheme
adjoint derivations live in ``repro.kernels.sigkernel_pde.stencil``.  The
``"order2"`` stencil adds an anti-diagonal curvature correction

    k̂_{i+1,j+1} = (k̂_{i+1,j} + k̂_{i,j+1})·A(p) − k̂_{i,j}·B₂(p)
                  − C(p)·(k̂_{i+1,j−1} + k̂_{i−1,j+1}),
    B₂(p) = 1 − p/6 + p²/12,   C(p) = p/12,

with out-of-grid skew reads := 1 (the boundary of ones extends), and its
exact one-pass adjoint gains the mirrored −C terms

    g[a,b] += … − g[a,b+2]·C(Δ[a−1,b+1]) − g[a+2,b]·C(Δ[a+1,b−1]),
    dΔ += g[i+1,j+1]·[… − (k̂_{i+1,j−1}+k̂_{i−1,j+1})·C'(p)],  C'(p) = 1/12.

``GridConfig.interior_dtype = "bfloat16"`` rounds every interior cell
through bf16 after its update (identical points on all backends) while the
boundary and readout stay f32; the custom VJP is the exact straight-through
adjoint of the rounded forward.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import dispatch
from .config import (_maybe_scale as _config_scale, delta_from_gram,
                     resolve_kernel_configs, resolve_static_kernel,
                     resolve_transforms)
from .dispatch import UNSET
from . import transforms as tf


# ---------------------------------------------------------------------------
# Δ precomputation (one batched matmul — paper design choice (2)), now with
# static-kernel lifts: non-linear κ go through the Δ-from-Gram path
# ---------------------------------------------------------------------------

def delta_matrix(x: jax.Array, y: jax.Array, *, transforms=None,
                 static_kernel=None, lengths_x=None, lengths_y=None,
                 time_aug=UNSET, lead_lag=UNSET) -> jax.Array:
    """Δ for the Goursat solver: (..., Lx, d) × (..., Ly, d) -> (..., Lx-1, Ly-1).

    For the (default) linear lift this is the paper's one batched matmul
    over transformed *increments*, Δ[i,j] = ⟨dx̃_i, dỹ_j⟩ — lead-lag /
    time-aug / basepoint never materialise the transformed path.  For a
    non-linear lift κ (e.g. :class:`repro.RBF`) the transformed paths are
    materialised once and Δ is the double increment of the pointwise Gram,

        Δ[i,j] = κ(x̃_{i+1}, ỹ_{j+1}) − κ(x̃_{i+1}, ỹ_j)
                 − κ(x̃_i, ỹ_{j+1}) + κ(x̃_i, ỹ_j),

    which feeds the *same* solver; gradients flow through the Gram by
    (exact) autodiff and through the solver by the one-pass §3.4 backward.

    ``lengths_x``/``lengths_y`` (ragged batches) produce *end-aligned*
    streams: the valid Δ block sits at the bottom-right and the padding
    contributes exactly-zero leading rows/columns (zero increments for the
    linear lift; repeated points, hence a vanishing double difference, for
    Δ-from-Gram).  Leading zero Δ leaves the Goursat boundary of ones
    bitwise intact — ``A(0) = B(0) = 1`` and ``(1+1)·1 − 1·1 = 1`` — so the
    solvers' far-corner readout *is* the true ``(len_x, len_y)``-corner
    value on every backend, and no solver needs a masked readout.

    ``time_aug=``/``lead_lag=`` are deprecated aliases for ``transforms=``.
    """
    cfg = resolve_transforms(transforms, time_aug, lead_lag)
    kernel = resolve_static_kernel(static_kernel)
    if kernel.lifts_increments:
        dx = tf.pipeline_increments(x, cfg, lengths_x, align="end")
        dy = tf.pipeline_increments(y, cfg, lengths_y, align="end")
        # the hot matmul — MXU on TPU, one bmm as in the paper
        return kernel.delta_from_increments(dx, dy)
    xt = tf.transform_path(x, cfg, lengths_x, align="end")
    yt = tf.transform_path(y, cfg, lengths_y, align="end")
    return delta_from_gram(kernel.gram(xt, yt))


# ---------------------------------------------------------------------------
# scheme coefficients — shared with every kernel backend via the pluggable
# stencil module (identical expressions, so the aliases are bitwise-neutral)
# ---------------------------------------------------------------------------

from repro.kernels.sigkernel_pde import stencil  # noqa: E402

_A = stencil.coeff_A
_B = stencil.coeff_B1
_dA = stencil.coeff_dA
_dB = stencil.coeff_dB1


# ---------------------------------------------------------------------------
# forward solver (row-major reference; full-grid + final-value variants)
# ---------------------------------------------------------------------------

def _solve_rows(delta: jax.Array, lam1: int, lam2: int,
                return_grid: bool, scheme: str = "order1",
                interior_dtype: str = "float32") -> jax.Array:
    """Solve the Goursat scheme for one Δ matrix (Lx, Ly) -> scalar or grid.

    Dyadic refinement on-the-fly: refined cell (s,t) reads
    p = Δ[s >> λ1, t >> λ2] · 2^{−(λ1+λ2)}.  ``scheme``/``interior_dtype``
    pick the cell-update stencil and interior rounding (stencil.py); the
    defaults are bitwise the historical order-1 f32 scan.
    """
    stencil.check_scheme(scheme)
    stencil.check_interior_dtype(interior_dtype)
    Lx, Ly = delta.shape
    nx, ny = Lx << lam1, Ly << lam2
    scale = 2.0 ** (-(lam1 + lam2))
    # refined row of Δ indices along t is static per row: repeat each col 2^λ2
    def row_delta(s):
        return jnp.repeat(delta[s >> lam1] * scale, 1 << lam2, axis=0)  # (ny,)

    init_row = jnp.ones((ny + 1,), dtype=delta.dtype)

    if scheme == "order2":
        # carries: (k̂[s, ·], k̂[s−1, ·]) across rows — the second row feeds
        # the k̂_{i−1,j+1} skew read; (left, down-left) within a row.  Both
        # carries start at ones: the boundary of ones extends out of grid.
        # order-1 fallback on data gridlines (stencil.py): cell (s, t) with
        # s % 2^λ1 == 0 or t % 2^λ2 == 0
        t_edge = jnp.arange(ny) % (1 << lam2) == 0

        def row_body(carry, s):
            prev_row, prev2_row = carry
            p_row = row_delta(s)
            a_row = _A(p_row)
            edge = (s % (1 << lam1) == 0) | t_edge
            b_row = stencil.coeff_B2_at(p_row, edge)
            c_row = stencil.coeff_C2_at(p_row, edge)
            ul_row = prev2_row[1:]                       # k̂[s−1, t+1]

            def col_body(cc, inputs):
                left, dl = cc                            # k̂[s+1,t], k̂[s+1,t−1]
                up, upleft, ul, a, b, c = inputs
                new = (left + up) * a - upleft * b - (dl + ul) * c
                new = stencil.round_interior(new, interior_dtype)
                return (new, left), new

            one = jnp.asarray(1.0, delta.dtype)
            _, rest = jax.lax.scan(
                col_body, (one, one),
                (prev_row[1:], prev_row[:-1], ul_row, a_row, b_row, c_row))
            new_row = jnp.concatenate([jnp.ones((1,), delta.dtype), rest])
            return (new_row, prev_row), new_row if return_grid else None

        (last_row, _), rows = jax.lax.scan(
            row_body, (init_row, init_row), jnp.arange(nx))
    else:
        def row_body(prev_row, s):
            p_row = row_delta(s)                              # (ny,)
            a_row, b_row = _A(p_row), _B(p_row)

            def col_body(left, inputs):
                up, upleft, a, b = inputs
                new = (left + up) * a - upleft * b
                new = stencil.round_interior(new, interior_dtype)
                return new, new

            _, rest = jax.lax.scan(
                col_body, jnp.asarray(1.0, delta.dtype),
                (prev_row[1:], prev_row[:-1], a_row, b_row))
            new_row = jnp.concatenate([jnp.ones((1,), delta.dtype), rest])
            return new_row, new_row if return_grid else None

        last_row, rows = jax.lax.scan(row_body, init_row, jnp.arange(nx))
    if return_grid:
        grid = jnp.concatenate([init_row[None], rows], axis=0)  # (nx+1, ny+1)
        return grid
    return last_row[-1]


def solve_goursat(delta: jax.Array, lam1: int = 0, lam2: int = 0,
                  return_grid: bool = False, scheme: str = "order1",
                  interior_dtype: str = "float32") -> jax.Array:
    """Batched Goursat solve.  delta: (..., Lx, Ly) -> (...,) or (..., nx+1, ny+1)."""
    fn = functools.partial(_solve_rows, lam1=lam1, lam2=lam2,
                           return_grid=return_grid, scheme=scheme,
                           interior_dtype=interior_dtype)
    for _ in range(delta.ndim - 2):
        fn = jax.vmap(fn)
    return fn(delta)


def _solve_antidiag_one(delta: jax.Array, lam1: int, lam2: int,
                        scheme: str = "order1",
                        interior_dtype: str = "float32") -> jax.Array:
    """Vectorised anti-diagonal solver for one Δ (Lx, Ly) — the fast CPU path.

    SIMD analogue of the paper's GPU wavefront: all cells of an anti-diagonal
    are updated as one vector op; three rotating diagonal buffers.  Materialises
    a skewed refined Δ (the Pallas kernel avoids even that).

    The order-2 skew neighbours (cell = lane i, diagonal t, column c = t−i)
    both live on the t−2 buffer: k̂_{i+1,c−1} is ``prev2`` at lane i
    unshifted (:= 1 when c ≤ 1, i.e. lane ≥ t−1) and k̂_{i−1,c+1} is
    ``prev2`` shifted down two lanes (:= 1 for lanes ≤ 1).  The correction
    is symmetric in the pair, so the nx > ny lane transpose stays exact.
    """
    stencil.check_scheme(scheme)
    stencil.check_interior_dtype(interior_dtype)
    Lx, Ly = delta.shape
    nx, ny = Lx << lam1, Ly << lam2
    scale = 2.0 ** (-(lam1 + lam2))
    M = jnp.repeat(jnp.repeat(delta, 1 << lam1, axis=0), 1 << lam2, axis=1) * scale
    mlane, mcol = 1 << lam1, 1 << lam2   # data-gridline periods (stencil.py)
    if nx > ny:                      # keep the vector lane = shorter axis
        M = M.T
        nx, ny = ny, nx
        mlane, mcol = mcol, mlane
    # skew: Msk[i, t] = M[i, t - i]  (gather once)
    t_idx = jnp.arange(nx + ny - 1)[None, :] - jnp.arange(nx)[:, None]
    Msk = jnp.take_along_axis(M, jnp.clip(t_idx, 0, ny - 1), axis=1)
    Msk = jnp.where((t_idx >= 0) & (t_idx < ny), Msk, 0.0)

    lanes = jnp.arange(nx)

    def body(carry, pdiag):
        prev, prev2, t = carry
        a = _A(pdiag)
        up = jnp.concatenate([jnp.ones((1,), delta.dtype), prev[:-1]])
        upleft = jnp.concatenate([jnp.ones((1,), delta.dtype), prev2[:-1]])
        left = jnp.where(lanes == t, 1.0, prev)
        upleft = jnp.where(lanes == t, 1.0, upleft)
        if scheme == "order2":
            # cell = (lane i, col c = t − i): order-1 fallback on data
            # gridlines, i % mlane == 0 or c % mcol == 0 (the periods
            # swap with the lane transpose above)
            edge = (lanes % mlane == 0) | ((t - lanes) % mcol == 0)
            b = stencil.coeff_B2_at(pdiag, edge)
            c = stencil.coeff_C2_at(pdiag, edge)
            k_dl = jnp.where(lanes >= t - 1, 1.0, prev2)
            k_ul = jnp.where(lanes <= 1, 1.0, jnp.roll(prev2, 2))
            cur = (left + up) * a - upleft * b - (k_dl + k_ul) * c
        else:
            cur = (left + up) * a - upleft * _B(pdiag)
        cur = stencil.round_interior(cur, interior_dtype)
        active = (lanes <= t) & (lanes > t - ny)
        cur = jnp.where(active, cur, 0.0)
        return (cur, prev, t + 1), None

    init = (jnp.zeros((nx,), delta.dtype), jnp.zeros((nx,), delta.dtype),
            jnp.asarray(0, jnp.int32))
    (last, _, _), _ = jax.lax.scan(body, init, Msk.T)
    return last[nx - 1]


def solve_goursat_antidiag(delta: jax.Array, lam1: int = 0, lam2: int = 0,
                           band_chunk: Optional[int] = None,
                           scheme: str = "order1",
                           interior_dtype: str = "float32") -> jax.Array:
    """Batched vectorised wavefront solve: (..., Lx, Ly) -> (...,).

    ``band_chunk`` (a :class:`LaunchConfig` knob) caps how many Goursat
    band solves are vectorised per sweep: the flattened pair batch is
    processed ``band_chunk`` problems at a time under ``lax.map``, bounding
    the live diagonal-buffer memory for huge batches.  Each pair's scan
    arithmetic is untouched, so results are bitwise-identical to the
    unchunked default (``None`` — the whole batch in one sweep); padding
    pairs are all-zero Δ (solution ≡ 1) and dropped.
    """
    fn1 = functools.partial(_solve_antidiag_one, lam1=lam1, lam2=lam2,
                            scheme=scheme, interior_dtype=interior_dtype)
    batch_shape = delta.shape[:-2]
    if band_chunk is None or not batch_shape:
        fn = fn1
        for _ in range(delta.ndim - 2):
            fn = jax.vmap(fn)
        return fn(delta)
    flat = delta.reshape((-1,) + delta.shape[-2:])
    B = flat.shape[0]
    pad = (-B) % band_chunk
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((pad,) + flat.shape[1:], flat.dtype)])
    chunks = flat.reshape((-1, band_chunk) + flat.shape[1:])
    out = jax.lax.map(jax.vmap(fn1), chunks)
    return out.reshape(-1)[:B].reshape(batch_shape)


# ---------------------------------------------------------------------------
# exact backward (Alg 4) — reference implementation
# ---------------------------------------------------------------------------

def _backward_rows(delta: jax.Array, grid: jax.Array, gbar: jax.Array,
                   lam1: int, lam2: int, scheme: str = "order1",
                   interior_dtype: str = "float32") -> jax.Array:
    """Alg 4 for one pair: returns ∂F/∂Δ (Lx, Ly) given the forward grid.

    Traverses the refined grid bottom-up, carrying one row of ∂F/∂k̂
    (two rows for ``scheme="order2"``, whose stencil reaches two skew steps
    — the per-scheme adjoint derivations live in
    ``repro.kernels.sigkernel_pde.stencil``).  The adjoint recursion itself
    is scheme-dependent but precision-independent: ``interior_dtype`` only
    selects the (rounded) forward ``grid`` the dΔ terms read, so the
    backward is the exact straight-through adjoint of the rounded forward.
    """
    stencil.check_scheme(scheme)
    Lx, Ly = delta.shape
    nx, ny = Lx << lam1, Ly << lam2
    scale = 2.0 ** (-(lam1 + lam2))
    dtype = delta.dtype
    order2 = scheme == "order2"

    def row_delta(s):
        # p for refined row s (cells (s, t), t = 0..ny-1)
        return jnp.repeat(delta[s >> lam1] * scale, 1 << lam2, axis=0)

    # g_row[j] = ∂F/∂k̂[s, j] for the row currently being consumed (length ny+1).
    # Seed row s = nx: g[nx, ny] = ḡ and gradients flow leftward along the row,
    #   g[nx, t] = g[nx, t+1] · A(Δ[nx-1, t])  [− g[nx, t+2] · C(Δ[nx-1, t+1])]
    # (cell (nx-1, t) writes k̂[nx, t+1] reading k̂[nx, t] with coefficient A;
    # for order2, cell (nx-1, t+1) also reads k̂[nx, t] as its k_dl, −C).
    p_lastrow = row_delta(nx - 1)
    m1, m2 = 1 << lam1, 1 << lam2        # data-gridline periods (stencil.py)

    if order2 and lam1 > 0:
        # p[nx-1, t+1] aligned at t (0 pad at t = ny-1: C(0) = 0 and the
        # g[nx, ny+1] factor is out of grid anyway).  The C writers are
        # cells (nx-1, t+1): row nx-1 is off-gridline iff λ1 > 0 (else the
        # order-1 seed applies), columns mask per t below.
        p_last_sh = jnp.concatenate([p_lastrow[1:], jnp.zeros((1,), dtype)])
        cq_seed = stencil.coeff_C2_at(
            p_last_sh, (jnp.arange(ny) + 1) % m2 == 0)

        def seed_body(carry, inputs):
            right, right2 = carry            # g[nx, t+1], g[nx, t+2]
            p, cq = inputs
            g = right * _A(p) - right2 * cq
            return (g, right), g

        _, seed_rest = jax.lax.scan(
            seed_body, (jnp.asarray(gbar, dtype), jnp.zeros((), dtype)),
            (p_lastrow, cq_seed), reverse=True)
    else:
        def seed_body(right, p):
            g = right * _A(p)
            return g, g

        _, seed_rest = jax.lax.scan(seed_body, jnp.asarray(gbar, dtype),
                                    p_lastrow, reverse=True)
    seed = jnp.concatenate([seed_rest, jnp.asarray(gbar, dtype)[None]])

    def row_body(carry, s):
        g_below, g_below2 = carry        # ∂F/∂k̂[s+1, ·], ∂F/∂k̂[s+2, ·]
        p_row = row_delta(s)             # Δ for cells (s, t)
        # within-row reverse scan: g[s, t] depends on g[s, t+1] (right), and
        # g[s+1, t] / g[s+1, t+1] (below row), all known.
        #   g[s,t] = g[s+1,t]·A(p[s,t-1]) + g[s,t+1]·A(p[s-1,t]) − g[s+1,t+1]·B(p[s,t])
        # order2 adds (stencil.py):  − g[s,t+2]·C(p[s-1,t+1]) − g[s+2,t]·C(p[s+1,t-1])
        # NOTE the A coefficients use Δ of *neighbouring* cells (paper eq.).
        p_left = jnp.concatenate([jnp.zeros((1,), dtype), p_row[:-1]])  # p[s, t-1]
        p_above = row_delta(jnp.maximum(s - 1, 0))                      # p[s-1, t]
        p_above = jnp.where(s >= 1, p_above, jnp.zeros_like(p_above))

        # t = ny entry first: g[s, ny] = g[s+1, ny]·A(p[s, ny-1]) (nothing right of it)
        g_last = g_below[ny] * _A(p_row[ny - 1])

        if order2:
            t_idx = jnp.arange(ny)
            # p[s-1, t+1] aligned at t (invalid cells -> p = 0 -> C = 0)
            p_above_sh = jnp.concatenate([p_above[1:],
                                          jnp.zeros((1,), dtype)])
            # p[s+1, t-1] aligned at t (clamped row read is masked by
            # g_below2 = 0 on the last row; t = 0 pad -> C(0) = 0)
            p_belowrow = row_delta(jnp.minimum(s + 1, nx - 1))
            p_below_sh = jnp.concatenate([jnp.zeros((1,), dtype),
                                          p_belowrow[:-1]])
            # per-WRITER gridline fallback (stencil.py, edge(i, j) =
            # i % m1 == 0 | j % m2 == 0): the -B writer is cell (s, t);
            # the g[s, t+2] C writer is cell (s-1, t+1); the g[s+2, t]
            # C writer is cell (s+1, t-1)
            bq = stencil.coeff_B2_at(
                p_row, (s % m1 == 0) | (t_idx % m2 == 0))
            cq_above = stencil.coeff_C2_at(
                p_above_sh,
                ((s - 1) % m1 == 0) | ((t_idx + 1) % m2 == 0))
            cq_below = stencil.coeff_C2_at(
                p_below_sh,
                ((s + 1) % m1 == 0) | ((t_idx - 1) % m2 == 0))
            # cell (s+1, ny-1) reads k̂[s, ny] as its k_ul (−C), so the
            # last-column entry gains the g[s+2, ny] term too — unless
            # that writer sits on a gridline (col ny-1 always does when
            # λ2 == 0)
            g_last = g_below[ny] * _A(p_row[ny - 1])
            if lam2 > 0:
                g_last = g_last - g_below2[ny] * stencil.coeff_C2_at(
                    p_belowrow[ny - 1], (s + 1) % m1 == 0)

            def col_body(cc, inputs):
                right, right2 = cc
                below, belowright, below2, pl, pa, bc, ca, cb = inputs
                g = (below * _A(pl) + right * _A(pa)
                     - belowright * bc
                     - right2 * ca
                     - below2 * cb)
                return (g, right), g

            _, rest = jax.lax.scan(
                col_body, (g_last, jnp.zeros((), dtype)),
                (g_below[:-1], g_below[1:], g_below2[:-1],
                 p_left, p_above, bq, cq_above, cq_below),
                reverse=True)
        else:
            def col_body(right, inputs):
                below, belowright, pl, pa, pc = inputs
                g = below * _A(pl) + right * _A(pa) - belowright * _B(pc)
                return g, g

            _, rest = jax.lax.scan(
                col_body, g_last,
                (g_below[:-1], g_below[1:], p_left, p_above, p_row),
                reverse=True)
        g_row = jnp.concatenate([rest, g_last[None]])
        # seed lands at (nx, ny): when s == nx-1, the "below" row is the seed row
        # handled by initialising carry with the seed.
        # ∂F/∂Δ contributions of row s: cells (s,t) use g[s+1,t+1]
        k_up = grid[s]                    # k̂[s, ·]
        k_below = grid[s + 1]             # k̂[s+1, ·]
        if order2:
            cell_edge = (s % m1 == 0) | (jnp.arange(ny) % m2 == 0)
            contrib = g_below[1:] * (
                (k_below[:-1] + k_up[1:]) * _dA(p_row)
                - k_up[:-1] * stencil.coeff_dB2_at(p_row, cell_edge)
                - (_skew_dl(k_below) + _skew_ul(grid, s, ny, dtype))
                * stencil.coeff_dC2_at(p_row, cell_edge))
        else:
            contrib = g_below[1:] * ((k_below[:-1] + k_up[1:]) * _dA(p_row)
                                     - k_up[:-1] * _dB(p_row))     # (ny,)
        # fold refined t-cells back onto unrefined columns
        contrib = contrib.reshape(Ly, 1 << lam2).sum(axis=1) * scale
        return (g_row, g_below), (contrib, s >> lam1)

    _, (contribs, row_ids) = jax.lax.scan(
        row_body, (seed, jnp.zeros_like(seed)), jnp.arange(nx - 1, -1, -1))
    # contribs: (nx, Ly) rows emitted for refined rows nx-1..0; fold onto Lx rows
    ddelta = jnp.zeros((Lx, Ly), dtype).at[row_ids].add(contribs)
    return ddelta


def _skew_dl(k_below: jax.Array) -> jax.Array:
    """k̂[s+1, t-1] for t = 0..ny-1 (t = 0 reads the := 1 extension)."""
    return jnp.concatenate([jnp.ones((1,), k_below.dtype), k_below[:-2]])


def _skew_ul(grid: jax.Array, s, ny: int, dtype) -> jax.Array:
    """k̂[s-1, t+1] for t = 0..ny-1 (s = 0 reads the := 1 extension)."""
    k_up2 = grid[jnp.maximum(s - 1, 0)][1:]
    return jnp.where(s >= 1, k_up2, jnp.ones((ny,), dtype))


def solve_goursat_grad(delta: jax.Array, grid: jax.Array, gbar: jax.Array,
                       lam1: int = 0, lam2: int = 0, scheme: str = "order1",
                       interior_dtype: str = "float32") -> jax.Array:
    """Batched exact backward: (..., Lx, Ly), (..., nx+1, ny+1), (...,) -> (..., Lx, Ly)."""
    fn = functools.partial(_backward_rows, lam1=lam1, lam2=lam2,
                           scheme=scheme, interior_dtype=interior_dtype)
    for _ in range(delta.ndim - 2):
        fn = jax.vmap(fn)
    return fn(delta, grid, gbar)


# ---------------------------------------------------------------------------
# the PDE-approximation backward of [30] (baseline for the accuracy benchmark)
# ---------------------------------------------------------------------------

def solve_goursat_grad_pde_approx(delta: jax.Array, grid: jax.Array,
                                  gbar: jax.Array, lam1: int = 0,
                                  lam2: int = 0) -> jax.Array:
    """Approximate ∂F/∂Δ via the continuous adjoint (second Goursat PDE).

    The continuum adjoint g(s,t) solves the same PDE from the far corner, i.e.
    g = k̂ of the time-reversed pair.  Discretely this is only O(h)-accurate —
    exactly the inexactness pySigLib §3.4 criticises in existing libraries.
    """
    rev = delta[..., ::-1, ::-1]
    g_grid = solve_goursat(rev, lam1, lam2, return_grid=True)[..., ::-1, ::-1]
    scale = 2.0 ** (-(lam1 + lam2))
    # cell (s,t) refined values of k̂ and adjoint
    Lx, Ly = delta.shape[-2:]

    def per_pair(dmat, kgrid, ggrid, gb):
        nx, ny = Lx << lam1, Ly << lam2
        pref = jnp.repeat(jnp.repeat(dmat * scale, 1 << lam1, axis=0),
                          1 << lam2, axis=1)               # (nx, ny)
        contrib = ggrid[1:, 1:] * gb * ((kgrid[1:, :-1] + kgrid[:-1, 1:]) * _dA(pref)
                                        - kgrid[:-1, :-1] * _dB(pref))
        contrib = contrib.reshape(Lx, 1 << lam1, Ly, 1 << lam2).sum((1, 3))
        return contrib * scale

    fn = per_pair
    for _ in range(delta.ndim - 2):
        fn = jax.vmap(fn)
    return fn(delta, grid, g_grid, gbar)


# ---------------------------------------------------------------------------
# public API with custom VJP (exact gradients, §3.4)
# ---------------------------------------------------------------------------

def _normalize_backend(backend) -> str:
    """Accept the historical bool (True = Pallas) alongside backend names."""
    if backend is True:
        return "pallas"
    if backend is False:
        return "reference"
    return backend


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _sigkernel_from_delta(delta: jax.Array, lam1: int, lam2: int,
                          backend="reference", launch=None,
                          scheme: str = "order1",
                          interior_dtype: str = "float32") -> jax.Array:
    """Solve batched Goursat problems with the named (concrete) backend.

    ``backend`` is a resolved name from :mod:`repro.core.dispatch`
    ("reference" | "antidiag" | "pallas"; bools are accepted for
    backwards compatibility).  The custom VJP is the exact one-pass
    backward (Alg 4) for every backend *and every scheme*: the backward
    recomputes/reads the forward grid with the SAME stencil and interior
    rounding, so it is the exact adjoint of the discrete forward map
    (per-scheme derivations in ``repro.kernels.sigkernel_pde.stencil``).
    ``launch`` is an optional :class:`repro.core.config.LaunchConfig`
    (static, like the backend name): ``pde_strip`` shapes the Pallas
    strips, ``band_chunk`` chunks the antidiag pair batch; the reference
    scan is launch-free.  ``scheme`` / ``interior_dtype`` are the
    :class:`repro.GridConfig` stencil/precision knobs, static like the
    grid orders.
    """
    backend = _normalize_backend(backend)
    if backend == "pallas":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        return pde_ops.solve(delta, lam1, lam2, launch, scheme=scheme,
                             interior_dtype=interior_dtype)
    if backend == "antidiag":
        return solve_goursat_antidiag(delta, lam1, lam2,
                                      getattr(launch, "band_chunk", None),
                                      scheme=scheme,
                                      interior_dtype=interior_dtype)
    if backend == "reference":
        return solve_goursat(delta, lam1, lam2, scheme=scheme,
                             interior_dtype=interior_dtype)
    raise ValueError(f"no Δ-solver implementation for backend {backend!r}")


def _sk_fwd(delta, lam1, lam2, backend, launch=None, scheme="order1",
            interior_dtype="float32"):
    backend = _normalize_backend(backend)
    if backend == "pallas":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        k, grid = pde_ops.solve_with_grid(delta, lam1, lam2, launch,
                                          scheme=scheme,
                                          interior_dtype=interior_dtype)
    elif backend == "antidiag":
        # rematerialisation trade-off: save Δ only (Lx·Ly floats) and rebuild
        # the refined grid serially in the backward, instead of holding the
        # (nx+1)·(ny+1) grid — 4^λ larger — as residual like "reference" does.
        # Gradient-dominated small-grid workloads that prefer time over
        # memory should pass backend="reference" (docs/solver_guide.md).
        k, grid = solve_goursat_antidiag(
            delta, lam1, lam2, getattr(launch, "band_chunk", None),
            scheme=scheme, interior_dtype=interior_dtype), None
    elif backend == "reference":
        grid = solve_goursat(delta, lam1, lam2, return_grid=True,
                             scheme=scheme, interior_dtype=interior_dtype)
        k = grid[..., -1, -1]
    else:
        raise ValueError(f"no Δ-solver implementation for backend {backend!r}")
    return k, (delta, grid)


def _sk_bwd(lam1, lam2, backend, launch, scheme, interior_dtype, res, gbar):
    backend = _normalize_backend(backend)
    delta, grid = res
    if backend == "pallas":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        ddelta = pde_ops.solve_grad(delta, grid, gbar, lam1, lam2, launch,
                                    scheme=scheme,
                                    interior_dtype=interior_dtype)
    else:
        if grid is None:  # antidiag saves Δ only; rebuild the grid exactly
            grid = solve_goursat(delta, lam1, lam2, return_grid=True,
                                 scheme=scheme,
                                 interior_dtype=interior_dtype)
        ddelta = solve_goursat_grad(delta, grid, gbar, lam1, lam2,
                                    scheme=scheme,
                                    interior_dtype=interior_dtype)
    return (ddelta,)


_sigkernel_from_delta.defvjp(_sk_fwd, _sk_bwd)


def sigkernel(x: jax.Array, y: jax.Array, *, transforms=None, grid=None,
              static_kernel=None, backend: str = "auto", launch=None,
              lengths_x=None, lengths_y=None,
              lam1=UNSET, lam2=UNSET, time_aug=UNSET, lead_lag=UNSET,
              use_pallas=UNSET) -> jax.Array:
    """Signature kernel k(x, y) = ⟨S(x̃), S(ỹ)⟩ for batches of paths.

    x: (..., Lx, d), y: (..., Ly, d)  ->  (...,).

    Differentiable w.r.t. x and y with pySigLib's exact one-pass backward.

    Args:
      transforms: a :class:`repro.TransformPipeline` — §4 transforms
        (basepoint / lead-lag / time-aug over [t0, t1]) applied on-the-fly.
      grid: a :class:`repro.GridConfig` — the independent dyadic refinement
        orders (λ1, λ2) of the PDE grid.
      static_kernel: the static-kernel lift — :class:`repro.Linear` (the
        default; the paper's kernel) or :class:`repro.RBF`.  Non-linear
        lifts route Δ through the pointwise-Gram double increment
        (:func:`repro.core.config.delta_from_gram`) into the same solver.
      backend: a name from :mod:`repro.core.dispatch` ("reference" |
        "antidiag" | "pallas" | "pallas_fused") or ``"auto"`` (default:
        per-platform/size).  ``"pallas_fused"`` builds Δ from increments in
        VMEM and therefore requires the linear lift.
      lengths_x / lengths_y: optional (...,) int arrays of per-path true
        point counts for ragged batches.  ``k(x, y)`` is read at the true
        ``(len_x, len_y)`` grid corner on every backend — exactly, via
        end-aligned streams whose padding contributes zero Δ rows/columns
        that leave the Goursat boundary bitwise intact (see
        :func:`delta_matrix`).  Length axes are padded to power-of-two
        buckets so nearby sizes share one jit trace.
      launch: an optional :class:`repro.LaunchConfig` — explicit kernel
        launch parameters (Pallas strip height, antidiag band chunking).
        Default ``None`` consults the autotune cache for a swept winner and
        otherwise keeps the library defaults.  Results are independent of
        the launch parameters (they only shape tiles/strips).
      lam1 / lam2 / time_aug / lead_lag / use_pallas: deprecated aliases
        for ``grid=`` / ``transforms=`` / ``backend=`` (DeprecationWarning
        once per call-site; bitwise-identical results).
    """
    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    lam1, lam2 = g.lam1, g.lam2
    if lengths_x is not None:
        x, lengths_x = tf.pad_ragged(x, lengths_x)
    if lengths_y is not None:
        y, lengths_y = tf.pad_ragged(y, lengths_y)
    ragged = lengths_x is not None or lengths_y is not None
    backend = dispatch.canonicalize(backend, op="sigkernel",
                                    use_pallas=use_pallas)
    if backend == "pallas_fused" and not kernel.lifts_increments:
        raise ValueError(
            "backend='pallas_fused' builds Δ from increments in VMEM and "
            f"only supports the linear lift, got "
            f"static_kernel={type(kernel).__name__}; pass backend='auto'")
    Lx = cfg.transformed_steps(x.shape[-2])
    Ly = cfg.transformed_steps(y.shape[-2])
    key_shape = (Lx << lam1, Ly << lam2, cfg.transformed_dim(x.shape[-1]))
    launch = dispatch.resolve_launch(launch, op="sigkernel",
                                     shape=key_shape, dtype=x.dtype,
                                     ragged=ragged)
    if backend in ("auto", "pallas_fused"):
        was_auto = backend == "auto"
        cells = (Lx << lam1) * (Ly << lam2)
        backend = dispatch.resolve(
            backend, op="sigkernel", grid_cells=cells,
            shape=key_shape,
            dtype=x.dtype, allow_fused=kernel.lifts_increments,
            ragged=ragged, scheme=g.scheme)
        if was_auto and backend == "pallas_fused" \
                and x.shape[:-2] != y.shape[:-2]:
            # the autotune key carries no batch info, so a tuned winner can
            # be fused even for broadcastable batches it cannot serve;
            # auto must degrade to the static heuristic, not raise below
            backend = dispatch.resolve("auto", op="sigkernel",
                                       grid_cells=cells, allow_fused=False,
                                       scheme=g.scheme)
    else:
        dispatch.check_scheme(backend, g.scheme, op="sigkernel")
    if backend == "pallas_fused":
        if x.shape[:-2] != y.shape[:-2]:
            raise ValueError("backend='pallas_fused' needs matching batch "
                             f"shapes, got {x.shape[:-2]} vs {y.shape[:-2]}")
        from repro.kernels.sigkernel_pde import ops as pde_ops
        dx = tf.pipeline_increments(x, cfg, lengths_x, align="end")
        dy = tf.pipeline_increments(y, cfg, lengths_y, align="end")
        # fold a non-unit linear scale into one increment side:
        # scale·⟨dx, dy⟩ = ⟨scale·dx, dy⟩ exactly
        dx = _config_scale(dx, kernel.scale)
        batch_shape = dx.shape[:-2]
        dispatch.record_pair_solves(
            functools.reduce(lambda a, b: a * b, batch_shape, 1))
        k = pde_ops.solve_fused(dx.reshape((-1,) + dx.shape[-2:]),
                                dy.reshape((-1,) + dy.shape[-2:]),
                                lam1, lam2, launch, g.scheme,
                                g.interior_dtype)
        return k.reshape(batch_shape)
    delta = delta_matrix(x, y, transforms=cfg, static_kernel=kernel,
                         lengths_x=lengths_x, lengths_y=lengths_y)
    dispatch.record_pair_solves(
        functools.reduce(lambda a, b: a * b, delta.shape[:-2], 1))
    return _sigkernel_from_delta(delta, lam1, lam2, backend, launch,
                                 g.scheme, g.interior_dtype)


def sigkernel_gram(X: jax.Array, Y: Optional[jax.Array] = None, **kw) -> jax.Array:
    """Gram matrix K[a, b] = k(X_a, Y_b) — delegates to the unified engine
    :func:`repro.core.gram.sigkernel_gram` (dense / blocked / fused variants,
    symmetric fast path when ``Y`` is omitted).  Kept here so existing
    ``from repro.core.sigkernel import sigkernel_gram`` call sites keep
    working; see docs/solver_guide.md.
    """
    from . import gram as gram_engine
    return gram_engine.sigkernel_gram(X, Y, **kw)


def sigkernel_gram_blocked(X: jax.Array, Y: Optional[jax.Array] = None, *,
                           row_block: int = 8, **kw) -> jax.Array:
    """Deprecated alias for the engine with ``row_block`` set.

    ``Bx`` no longer needs to divide by ``row_block`` — the engine zero-pads
    the row batch (padded rows are dropped; Δ = 0 padding is exact).
    """
    from . import gram as gram_engine
    return gram_engine.sigkernel_gram(X, Y, row_block=row_block, **kw)
