"""Log-signatures of paths (the compressed path feature of Signatory).

The log-signature logS(x) = log(S(x)) is the truncated-tensor-algebra log of
the signature.  It carries the same information as S(x) up to the chosen
depth but lives in the free Lie algebra, whose dimension (the number of
Lyndon words, Witt's formula) is much smaller than the full tensor algebra —
e.g. d=5, N=5: 829 vs 3905 coordinates.

Pipeline:  increments --Horner--> S(x) --tensor_log--> flat Lie element
--Lyndon projection--> compressed coordinates.  The Horner recursion is the
*same* hot path as ``repro.core.signature`` (and routes through the same
Pallas kernel when ``use_pallas``); log + projection are a cheap epilogue.

Backpropagation reuses the time-reversed deconstruction backward of
``core.signature`` (§2.4, O(1) memory in path length): the custom VJP pulls
the cotangent back through ``tensor_log`` analytically via ``jax.vjp`` and
hands the signature cotangent to ``_signature_core_bwd``.

Modes (see ``repro.core.lyndon``):

* ``"lyndon"``   — Lyndon-word coefficients (default; a static gather).
* ``"brackets"`` — coefficients in the Lyndon bracket basis (triangular solve,
  precomputed).
* ``"expand"``   — the full flat tensor layout of log(S(x)) (sig_dim wide).
"""

from __future__ import annotations

import functools
import jax

from . import dispatch as dispatch_mod
from . import lyndon
from . import tensoralg as ta
from .signature import (_effective_increments, _signature_core_bwd,
                        _signature_horner_from_increments,
                        _signature_stream_from_increments)

MODES = ("lyndon", "brackets", "expand")


def logsignature_dim(d: int, depth: int, mode: str = "lyndon") -> int:
    """Output width of :func:`logsignature` for a (transformed) channel count d."""
    if mode == "expand":
        return ta.sig_dim(d, depth)
    return lyndon.logsig_dim(d, depth)


def _project(flat_log: jax.Array, d: int, depth: int, mode: str) -> jax.Array:
    if mode == "expand":
        return flat_log
    return lyndon.compress(flat_log, d, depth, mode)


# ---------------------------------------------------------------------------
# core: increments -> flat log-signature, with the reused exact backward
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _logsignature_core(z: jax.Array, depth: int) -> jax.Array:
    """Flat (mode="expand") log-signature of an increment stream z (..., L-1, d)."""
    d = z.shape[-1]
    return ta.tensor_log(_signature_horner_from_increments(z, depth), d, depth)


def _logsig_core_fwd(z, depth):
    sig = _signature_horner_from_increments(z, depth)
    d = z.shape[-1]
    return ta.tensor_log(sig, d, depth), (z, sig)


def _logsig_core_bwd(depth, res, g):
    z, sig = res
    d = z.shape[-1]
    # pull the cotangent back through the (pointwise-polynomial) log ...
    _, log_vjp = jax.vjp(lambda s: ta.tensor_log(s, d, depth), sig)
    (g_sig,) = log_vjp(g)
    # ... then reuse the O(1)-memory time-reversed deconstruction of §2.4.
    return _signature_core_bwd(depth, (z, sig), g_sig)


_logsignature_core.defvjp(_logsig_core_fwd, _logsig_core_bwd)


def logsignature_from_increments(z: jax.Array, depth: int,
                                 mode: str = "lyndon") -> jax.Array:
    """Log-signature of increment streams z (..., L-1, d), pure-JAX path."""
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    d = z.shape[-1]
    return _project(_logsignature_core(z, depth), d, depth, mode)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def logsignature(path: jax.Array, depth: int, *, mode: str = "lyndon",
                 transforms=None, backend: str = "auto",
                 stream: bool = False, lengths=None, launch=None,
                 time_aug=dispatch_mod.UNSET,
                 lead_lag=dispatch_mod.UNSET, use_pallas=None) -> jax.Array:
    """Truncated log-signature of a batch of piecewise-linear paths.

    Args:
      path: (..., L, d) discrete stream; linearly interpolated.
      depth: truncation level N.
      mode: "lyndon" (default) | "brackets" | "expand" — see module docstring.
      transforms: a :class:`repro.TransformPipeline` — §4 transforms
        (basepoint / lead-lag / time-aug over [t0, t1]), applied on-the-fly
        to increments.  Default: no transforms.
      backend: ``"reference"`` (pure-JAX Horner scan) | ``"pallas"`` (the TPU
        kernel) | ``"auto"`` (default; the registry in
        :mod:`repro.core.dispatch` picks "pallas" on TPU, "reference"
        elsewhere).  The Lyndon projection is a final gather either way.
        With ``stream=True`` explicitly requesting ``"pallas"`` raises (the
        streamed scan is pure JAX); ``"auto"`` degrades silently.
      stream: if True return log-signatures of all prefixes
        (..., L-1, logsig_dim).
      lengths: optional (...,) int array of per-path true point counts for
        ragged batches — same semantics as :func:`repro.core.signature`
        (padding masked, per-path time grid, power-of-two length buckets;
        streamed prefixes repeat the final value past the true end).
      launch: an optional :class:`repro.LaunchConfig` — same semantics as
        :func:`repro.core.signature.signature` (``sig_bt`` / ``sig_lb``
        tile the Pallas Horner kernel; bitwise-identical results across
        launch configs; ignored off the pallas backend).
      time_aug / lead_lag: deprecated bool aliases for ``transforms=``
        (DeprecationWarning once per call-site; bitwise-identical results).
      use_pallas: deprecated alias — explicit bools warn and map to
        ``backend="pallas"`` / ``"reference"``; ``None`` keeps the
        historical meaning of auto.

    Returns:
      (..., logsignature_dim(d', depth, mode)) where d' is the transformed
      channel count (``transforms.transformed_dim(d)``).
    """
    from . import dispatch
    from . import transforms as tf
    from .config import resolve_transforms
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    cfg = resolve_transforms(transforms, time_aug, lead_lag)
    if lengths is not None:
        path, lengths = tf.pad_ragged(path, lengths)
    z = _effective_increments(path, cfg, lengths)
    d = z.shape[-1]
    backend = dispatch.canonicalize(backend, op="logsignature",
                                    use_pallas=use_pallas)
    if stream:
        if backend not in ("auto", "reference"):
            raise ValueError(
                f"logsignature(stream=True) has no {backend!r} "
                "implementation — the streamed prefix scan is pure JAX; "
                "pass backend='auto' or backend='reference'")
        sig_stream = _signature_stream_from_increments(z, depth)
        flat_log = ta.tensor_log(sig_stream, d, depth)
        return _project(flat_log, d, depth, mode)
    key_shape = (z.shape[-2], z.shape[-1], depth)
    backend = dispatch.resolve(
        backend, op="logsignature", shape=key_shape, dtype=z.dtype,
        ragged=lengths is not None)
    if backend == "pallas":
        from repro.kernels.signature import ops as sig_ops
        launch = dispatch.resolve_launch(launch, op="logsignature",
                                         shape=key_shape, dtype=z.dtype,
                                         ragged=lengths is not None)
        return sig_ops.logsignature_from_increments(z, depth, mode, launch)
    return logsignature_from_increments(z, depth, mode)


def logsignature_combine(lsa: jax.Array, lsb: jax.Array, d: int, depth: int,
                         mode: str = "lyndon") -> jax.Array:
    """Log-signature of a concatenation from the pieces' log-signatures.

    Chen's identity holds for signatures, so combine via exp -> ⊗ -> log:
    logS(x * y) = log(exp(logS(x)) ⊗ exp(logS(y))).  ``d`` is the
    (transformed) channel count the inputs were computed with.
    """
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    if mode != "expand":
        lsa = lyndon.expand(lsa, d, depth, mode)
        lsb = lyndon.expand(lsb, d, depth, mode)
    sa = ta.tensor_exp_full(lsa, d, depth)
    sb = ta.tensor_exp_full(lsb, d, depth)
    combined = ta.tensor_log(ta.chen(sa, sb, d, depth), d, depth)
    return _project(combined, d, depth, mode)
