"""Composable kernel API v1 — pytree-registered config dataclasses.

The public surface of :mod:`repro` used to be a kwarg soup: ``time_aug=`` /
``lead_lag=`` bools, ``lam1``/``lam2`` ints and a deprecated ``use_pallas``
sprinkled over every entry point.  This module replaces them with three
small frozen dataclasses, all registered as JAX pytrees so they pass
cleanly through ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` boundaries:

``TransformPipeline(time_aug, lead_lag, basepoint, t0, t1)``
    The §4 path transforms, applied on-the-fly to increments in the
    canonical order **basepoint → lead-lag → time-aug** (matching the
    materialised ``time_augment(lead_lag(basepoint(x)), t0, t1)``).
    ``t0``/``t1`` are *data* leaves (traceable floats); the booleans are
    static metadata because they change output shapes.

``GridConfig(lam1, lam2)``
    The independent dyadic refinement orders of the Goursat PDE grid.
    Both static (they enter shapes via bit-shifts).

``LaunchConfig(pde_strip, sig_bt, sig_lb, gram_row_block, band_chunk)``
    Kernel *launch parameters* — the tile/block/strip shapes that used to
    be module constants (``_MAX_T``, ``_MAX_BT``/``_LB``, the Gram
    ``row_block`` heuristic).  All static; all default to ``None`` ("use
    the library default", bitwise-identical to the pre-tuning constants).
    The autotune subsystem (:mod:`repro.bench.autotune`) sweeps a bounded
    space of these per shape-bucket and persists the winner.

``StaticKernel`` — ``Linear(scale)`` / ``RBF(sigma)``
    The static-kernel *lift* under the signature kernel (KSig-style).
    ``Linear`` keeps the paper's one-matmul Δ from increments; ``RBF``
    feeds the same Goursat solver through the Δ-from-Gram path
    (:func:`delta_from_gram`), so ``jax.grad`` still uses the exact
    one-pass §3.4 backward through ``_sigkernel_from_delta`` and plain
    autodiff (exact) through the RBF Gram itself.  ``scale``/``sigma``
    are data leaves — differentiable kernel hyper-parameters.

The legacy kwargs survive as shims: :func:`resolve_transforms` and
:func:`resolve_grid` map them onto config objects with a
``DeprecationWarning`` once per call-site (via
:func:`repro.core.dispatch._warn_deprecated`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .dispatch import UNSET, _warn_deprecated


def _pytree_dataclass(cls, data_fields, meta_fields):
    """Register a frozen dataclass as a pytree with static metadata."""
    jax.tree_util.register_dataclass(cls, data_fields=list(data_fields),
                                     meta_fields=list(meta_fields))
    return cls


# ---------------------------------------------------------------------------
# TransformPipeline — §4 path transforms as one value
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformPipeline:
    """On-the-fly §4 path transforms, in order basepoint → lead-lag → time-aug.

    Attributes:
      time_aug: append a uniform time channel over ``[t0, t1]``.
      lead_lag: interleave lead/lag copies (2d channels, 2L-1 points).
      basepoint: prepend the origin, making translations visible to S(x).
      t0 / t1: endpoints of the time-augmentation grid (data leaves —
        traceable under ``jit``; only used when ``time_aug=True``).
    """

    time_aug: bool = False
    lead_lag: bool = False
    basepoint: bool = False
    t0: float = 0.0
    t1: float = 1.0

    @property
    def identity(self) -> bool:
        """True when the pipeline changes nothing (fast-path check)."""
        return not (self.time_aug or self.lead_lag or self.basepoint)

    def transformed_dim(self, d: int) -> int:
        """Channel count after the pipeline (basepoint adds points, not
        channels)."""
        if self.lead_lag:
            d = 2 * d
        if self.time_aug:
            d = d + 1
        return d

    def transformed_steps(self, L: int) -> int:
        """Increment count after the pipeline for an L-point path."""
        n = L - 1
        if self.basepoint:
            n += 1
        if self.lead_lag:
            n *= 2
        return n


_pytree_dataclass(TransformPipeline, data_fields=("t0", "t1"),
                  meta_fields=("time_aug", "lead_lag", "basepoint"))


# ---------------------------------------------------------------------------
# GridConfig — dyadic refinement of the PDE grid
# ---------------------------------------------------------------------------

#: Goursat cell-update stencils the PDE backends implement
#: (kernels/sigkernel_pde/stencil.py holds the shared coefficient sets).
GRID_SCHEMES = ("order1", "order2")

#: interior-cell storage precisions. "bfloat16" rounds every interior cell
#: through bf16 after its update while the boundary of ones, carried
#: boundary rows and the readout stay f32 (the mixed-precision contract —
#: see docs/solver_guide.md, "Choosing a scheme order").
GRID_INTERIOR_DTYPES = ("float32", "bfloat16")


@dataclasses.dataclass(frozen=True)
class GridConfig:
    """Static Goursat-solver discretisation: grid refinement, stencil, dtype.

    ``lam1``/``lam2`` are independent dyadic refinement orders (λ1, λ2): a
    refined grid has ``(Lx << lam1) · (Ly << lam2)`` cells, so these enter
    shapes and must be Python ints.

    ``scheme`` selects the cell-update stencil: ``"order1"`` (the default —
    bitwise-identical to the historical solvers) or ``"order2"``, which adds
    an anti-diagonal curvature correction and typically reaches order-1
    accuracy on a ~2× coarser grid (docs/solver_guide.md).

    ``interior_dtype`` selects interior-cell storage precision:
    ``"float32"`` (default) or ``"bfloat16"`` (interior cells rounded
    through bf16 after each update; boundary and readout stay f32).

    All four fields are **static** metadata (compile-time choices baked
    into the kernels), hence pytree aux data.
    """

    lam1: int = 0
    lam2: int = 0
    scheme: str = "order1"
    interior_dtype: str = "float32"

    def __post_init__(self):
        for name in ("lam1", "lam2"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                raise ValueError(
                    f"GridConfig.{name} must be a non-negative Python int "
                    f"(it sets static grid shapes), got {v!r}")
        if self.scheme not in GRID_SCHEMES:
            raise ValueError(
                f"GridConfig.scheme must be one of {GRID_SCHEMES} (the "
                f"Goursat cell-update stencil, a static compile-time "
                f"choice), got {self.scheme!r}")
        if self.interior_dtype not in GRID_INTERIOR_DTYPES:
            raise ValueError(
                f"GridConfig.interior_dtype must be one of "
                f"{GRID_INTERIOR_DTYPES} (interior-cell storage precision; "
                f"boundary/readout always stay float32), "
                f"got {self.interior_dtype!r}")

    @property
    def cells_scale(self) -> int:
        return 1 << (self.lam1 + self.lam2)


_pytree_dataclass(GridConfig, data_fields=(),
                  meta_fields=("lam1", "lam2", "scheme", "interior_dtype"))


# ---------------------------------------------------------------------------
# LaunchConfig — kernel launch parameters (the autotune search space)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LaunchConfig:
    """Kernel launch parameters: the tile/block/strip shapes of the hot paths.

    Every field is **static** metadata (they set kernel block shapes and
    jit-trace structure) and every field defaults to ``None`` — "use the
    library default", which reproduces the pre-tuning constants bitwise.
    Non-default values come from three places, in precedence order: an
    explicit ``launch=`` kwarg on an entry point, the autotune cache
    (:mod:`repro.bench.autotune` sweeps a small bounded space per
    shape-bucket and persists the winner), and the defaults.

    Attributes:
      pde_strip: refined-row strip height per Goursat Pallas program
        (cap on ``T``; default 128 = ``kernels.sigkernel_pde.ops._MAX_T``).
        Must be a power of two; still shrunk to fit the VMEM budget and
        clamped to at least one unrefined row (``1 << lam1``).
      sig_bt: batch-tile (lane) cap of the signature Horner kernel
        (default 128 = ``kernels.signature.ops._MAX_BT``). Power of two;
        still shrunk to fit the VMEM budget.
      sig_lb: length-block of the signature Horner kernel's grid
        (default 256 = ``kernels.signature.ops._LB``). Power of two.
      gram_row_block: Gram-engine row blocking (``row_block=``) applied
        when the caller didn't pass one. Default ``None`` keeps today's
        behaviour (dense, or the symmetric path's gather-budget heuristic).
      band_chunk: antidiagonal-wavefront solver batching — at most this
        many Goursat pair problems are vectorised per sweep
        (``lax.map`` over chunks). Default: the whole flattened batch in
        one sweep. Caps the live band memory for huge pair batches.
    """

    pde_strip: Optional[int] = None
    sig_bt: Optional[int] = None
    sig_lb: Optional[int] = None
    gram_row_block: Optional[int] = None
    band_chunk: Optional[int] = None

    _POW2_FIELDS = ("pde_strip", "sig_bt", "sig_lb")

    def __post_init__(self):
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is None:
                continue
            if not isinstance(v, int) or isinstance(v, bool) or v < 1:
                raise ValueError(
                    f"LaunchConfig.{f.name} must be None or a positive "
                    f"Python int (it sets static kernel block shapes), "
                    f"got {v!r}")
            if f.name in self._POW2_FIELDS and v & (v - 1):
                raise ValueError(
                    f"LaunchConfig.{f.name} must be a power of two "
                    f"(kernel tiling constraint), got {v}")

    @property
    def is_default(self) -> bool:
        """True when every knob is at the library default."""
        return all(getattr(self, f.name) is None
                   for f in dataclasses.fields(self))

    def to_dict(self) -> dict:
        """JSON-friendly dict of the non-default knobs (autotune cache)."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "LaunchConfig":
        """Rebuild from :meth:`to_dict` output.

        Unknown keys are dropped (fail-open: a cache written by a newer
        version must not break an older library); known keys with invalid
        values raise — callers treat that as a stale cache entry.
        """
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in dict(d).items() if k in known})


_pytree_dataclass(LaunchConfig, data_fields=(),
                  meta_fields=("pde_strip", "sig_bt", "sig_lb",
                               "gram_row_block", "band_chunk"))


def resolve_launch(launch: Optional[LaunchConfig]) -> LaunchConfig:
    """Default + type-check the ``launch=`` kwarg of the entry points."""
    if launch is None:
        return LaunchConfig()
    if not isinstance(launch, LaunchConfig):
        raise TypeError(
            f"launch= expects a LaunchConfig, got {type(launch).__name__} "
            f"(see docs/benchmarks.md, 'Launch-parameter tuning')")
    return launch


# ---------------------------------------------------------------------------
# static-kernel lifts (KSig-style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StaticKernel:
    """Base class for static-kernel lifts κ under the signature kernel.

    Subclasses implement :meth:`gram` — pointwise κ between path *points*.
    ``lifts_increments = True`` (only :class:`Linear`) means Δ can be built
    directly from increment streams with one matmul (the paper's design
    choice (2), and what the fused Pallas kernels consume); every other
    kernel goes through the Δ-from-Gram path (:func:`delta_from_gram`).
    """

    #: Δ is a plain increment matmul — usable by the fused Pallas kernels
    lifts_increments = False

    def gram(self, x: jax.Array, y: jax.Array) -> jax.Array:
        """κ(x_i, y_j) pointwise: (..., Lx, d) × (..., Ly, d) -> (..., Lx, Ly).

        Leading batch dims broadcast, so ``gram(x[:, None], y[None])`` gives
        all pairwise point-Grams of two path batches.
        """
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Linear(StaticKernel):
    """κ(x, y) = scale · ⟨x, y⟩ — the paper's plain signature kernel."""

    scale: float = 1.0
    lifts_increments = True

    def gram(self, x, y):
        return _maybe_scale(jnp.einsum("...id,...jd->...ij", x, y),
                            self.scale)

    def delta_from_increments(self, dx: jax.Array, dy: jax.Array) -> jax.Array:
        """Δ[i,j] = scale · ⟨dx_i, dy_j⟩ — one batched matmul."""
        return _maybe_scale(jnp.einsum("...id,...jd->...ij", dx, dy),
                            self.scale)


@dataclasses.dataclass(frozen=True)
class RBF(StaticKernel):
    """κ(x, y) = exp(−‖x−y‖² / (2σ²)) — the Gaussian static-kernel lift."""

    sigma: float = 1.0

    def gram(self, x, y):
        sq = (jnp.sum(x * x, axis=-1)[..., :, None]
              + jnp.sum(y * y, axis=-1)[..., None, :]
              - 2.0 * jnp.einsum("...id,...jd->...ij", x, y))
        sq = jnp.maximum(sq, 0.0)        # clamp catastrophic cancellation
        return jnp.exp(-sq / (2.0 * jnp.asarray(self.sigma) ** 2))


for _cls, _data in ((StaticKernel, ()), (Linear, ("scale",)),
                    (RBF, ("sigma",))):
    _pytree_dataclass(_cls, data_fields=_data, meta_fields=())


def _maybe_scale(v: jax.Array, scale) -> jax.Array:
    """Multiply by ``scale`` unless it is concretely 1 (bitwise no-op)."""
    if isinstance(scale, (int, float)) and scale == 1.0:
        return v
    return v * scale


def delta_from_gram(G: jax.Array) -> jax.Array:
    """Δ from a pointwise static-kernel Gram: the double increment

        Δ[i,j] = G[i+1,j+1] − G[i+1,j] − G[i,j+1] + G[i,j]

    (..., Lx, Ly) -> (..., Lx-1, Ly-1).  For κ = ⟨·,·⟩ this reduces exactly
    to the increment matmul; for any other κ it is the discrete mixed
    second derivative the Goursat scheme integrates.
    """
    return (G[..., 1:, 1:] - G[..., 1:, :-1]
            - G[..., :-1, 1:] + G[..., :-1, :-1])


# ---------------------------------------------------------------------------
# legacy-kwarg resolution (deprecation shims shared by every entry point)
# ---------------------------------------------------------------------------

def _legacy_names(**kwargs) -> list:
    return [name for name, v in kwargs.items() if v is not UNSET]


def resolve_transforms(transforms: Optional[TransformPipeline],
                       time_aug=UNSET, lead_lag=UNSET,
                       _warn: bool = True) -> TransformPipeline:
    """Merge the legacy ``time_aug=``/``lead_lag=`` bools into a config.

    An explicit ``transforms=`` wins (contradictory legacy kwargs are
    ignored with a warning, matching :func:`dispatch.canonicalize`).
    Explicitly-passed legacy bools — even ``False`` — warn once per
    call-site and build the equivalent :class:`TransformPipeline`, which
    runs through the *same* code path (bitwise-identical results).
    """
    used = _legacy_names(time_aug=time_aug, lead_lag=lead_lag)
    if transforms is not None:
        if not isinstance(transforms, TransformPipeline):
            raise TypeError(
                f"transforms= expects a TransformPipeline, got "
                f"{type(transforms).__name__} (see docs/migration.md)")
        if used and _warn:
            _warn_deprecated(
                f"deprecated {'/'.join(n + '=' for n in used)} ignored "
                "because transforms= was passed explicitly "
                "(docs/migration.md)")
        return transforms
    if used:
        if _warn:
            _warn_deprecated(
                f"{'/'.join(n + '=' for n in used)} deprecated; pass "
                "transforms=repro.TransformPipeline(...) "
                "(docs/migration.md)")
        return TransformPipeline(
            time_aug=bool(time_aug) if time_aug is not UNSET else False,
            lead_lag=bool(lead_lag) if lead_lag is not UNSET else False)
    return TransformPipeline()


def resolve_grid(grid: Optional[GridConfig], lam1=UNSET, lam2=UNSET,
                 _warn: bool = True) -> GridConfig:
    """Merge the legacy ``lam1=``/``lam2=`` ints into a :class:`GridConfig`."""
    used = _legacy_names(lam1=lam1, lam2=lam2)
    if grid is not None:
        if not isinstance(grid, GridConfig):
            raise TypeError(
                f"grid= expects a GridConfig, got {type(grid).__name__} "
                f"(see docs/migration.md)")
        if used and _warn:
            _warn_deprecated(
                f"deprecated {'/'.join(n + '=' for n in used)} ignored "
                "because grid= was passed explicitly (docs/migration.md)")
        return grid
    if used:
        if _warn:
            _warn_deprecated(
                f"{'/'.join(n + '=' for n in used)} deprecated; pass "
                "grid=repro.GridConfig(lam1=..., lam2=...) "
                "(docs/migration.md)")
        return GridConfig(lam1=int(lam1) if lam1 is not UNSET else 0,
                          lam2=int(lam2) if lam2 is not UNSET else 0)
    return GridConfig()


def resolve_kernel_configs(transforms, grid, static_kernel, *,
                           time_aug=UNSET, lead_lag=UNSET,
                           lam1=UNSET, lam2=UNSET):
    """One-stop legacy resolution for the sig-kernel entry points.

    Emits at most **one** ``DeprecationWarning`` per call-site even when a
    call mixes transform and grid legacy kwargs (``sigkernel(x, y, lam1=1,
    time_aug=True)`` warns once, naming both).
    """
    used = _legacy_names(time_aug=time_aug, lead_lag=lead_lag,
                         lam1=lam1, lam2=lam2)
    if used:
        ignored = []
        if transforms is not None:
            ignored += _legacy_names(time_aug=time_aug, lead_lag=lead_lag)
        if grid is not None:
            ignored += _legacy_names(lam1=lam1, lam2=lam2)
        taken = [n for n in used if n not in ignored]
        parts = []
        if taken:
            parts.append(f"{'/'.join(n + '=' for n in taken)} deprecated; "
                         "pass transforms=repro.TransformPipeline(...) / "
                         "grid=repro.GridConfig(...)")
        if ignored:
            parts.append(f"deprecated {'/'.join(n + '=' for n in ignored)} "
                         "ignored because the config object was passed "
                         "explicitly")
        _warn_deprecated("; ".join(parts) + " (docs/migration.md)")
    return (resolve_transforms(transforms, time_aug, lead_lag, _warn=False),
            resolve_grid(grid, lam1, lam2, _warn=False),
            resolve_static_kernel(static_kernel))


def resolve_static_kernel(static_kernel: Optional[StaticKernel]
                          ) -> StaticKernel:
    """Default the lift to the paper's linear kernel; validate the type."""
    if static_kernel is None:
        return Linear()
    if not isinstance(static_kernel, StaticKernel):
        raise TypeError(
            f"static_kernel= expects a StaticKernel (repro.Linear / "
            f"repro.RBF), got {type(static_kernel).__name__}")
    return static_kernel
