"""Lyndon-word machinery for log-signatures (free Lie algebra bases).

The log-signature of a path lives in the free Lie algebra L^N(R^d), a linear
subspace of the truncated tensor algebra T^N(R^d) of dimension equal to the
number of Lyndon words of length <= N over a d-letter alphabet (Witt's
formula).  Two coordinate systems on that subspace are supported, mirroring
``signatory``:

* ``"lyndon"`` — the coefficient of each Lyndon *word* read directly off the
  flat tensor expansion.  Because the expansion of a bracketed Lyndon word is
  the word itself plus lexicographically-greater words of the same length,
  this extraction is a change of basis (a gather — the cheapest projection,
  and the one the fused Pallas path uses).
* ``"brackets"`` — coefficients with respect to the Lyndon (Chen-Fox-Lyndon)
  *bracket* basis itself, recovered from the word coefficients by solving the
  unitriangular change-of-basis system.

Everything data-independent (word enumeration, bracketing, the expansion
matrix, the triangular solve) is computed ONCE per (d, depth) in NumPy at
trace time and cached, so the jnp-facing ``compress``/``expand`` maps are a
static gather / matmul — fully jit- and vmap-compatible.

Ordering convention: words are grouped by length, lexicographic within a
length — matching the flat level layout of ``repro.core.tensoralg``.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .tensoralg import level_offsets, sig_dim

Word = Tuple[int, ...]


# ---------------------------------------------------------------------------
# enumeration (Duval's algorithm) and Witt's formula
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def lyndon_words(d: int, depth: int) -> Tuple[Word, ...]:
    """All Lyndon words over {0..d-1} of length 1..depth, (length, lex)-ordered."""
    by_len: List[List[Word]] = [[] for _ in range(depth + 1)]
    w = [-1]
    while w:
        w[-1] += 1
        m = len(w)
        by_len[m].append(tuple(w))
        while len(w) < depth:
            w.append(w[len(w) - m])
        while w and w[-1] == d - 1:
            w.pop()
    # Duval emits in global lex order; regroup as (length, lex-within-length).
    return tuple(wd for length in range(1, depth + 1)
                 for wd in sorted(by_len[length]))


def _mobius(n: int) -> int:
    if n == 1:
        return 1
    mu, m = 1, n
    p = 2
    while p * p <= m:
        if m % p == 0:
            m //= p
            if m % p == 0:
                return 0
            mu = -mu
        p += 1
    if m > 1:
        mu = -mu
    return mu


def witt_dims(d: int, depth: int) -> List[int]:
    """Number of Lyndon words of each length 1..depth (Witt's formula)."""
    out = []
    for n in range(1, depth + 1):
        total = sum(_mobius(m) * d ** (n // m) for m in range(1, n + 1)
                    if n % m == 0)
        out.append(total // n)
    return out


def logsig_dim(d: int, depth: int) -> int:
    """Dimension of the depth-truncated free Lie algebra over R^d."""
    return sum(witt_dims(d, depth))


# ---------------------------------------------------------------------------
# standard bracketing and its tensor expansion
# ---------------------------------------------------------------------------

def _is_lyndon(w: Word) -> bool:
    return all(w < w[i:] + w[:i] for i in range(1, len(w)))


@functools.lru_cache(maxsize=None)
def standard_bracketing(w: Word):
    """Chen-Fox-Lyndon bracketing: w = uv with v the longest proper Lyndon
    suffix; returns a nested tuple of letters."""
    if len(w) == 1:
        return w[0]
    if not _is_lyndon(w):
        raise ValueError(f"not a Lyndon word: {w}")
    for i in range(1, len(w)):
        if _is_lyndon(w[i:]):
            return (standard_bracketing(w[:i]), standard_bracketing(w[i:]))
    raise AssertionError("unreachable: every Lyndon word factorises")


def bracket_string(w: Word) -> str:
    """Human-readable standard bracketing, e.g. ``[0, [0, 1]]``."""
    def fmt(b):
        if isinstance(b, int):
            return str(b)
        return f"[{fmt(b[0])}, {fmt(b[1])}]"
    return fmt(standard_bracketing(w))


def _expand_bracket(b) -> Dict[Word, float]:
    """Tensor-word coefficients of a nested commutator ``[u, v] = uv - vu``."""
    if isinstance(b, int):
        return {(b,): 1.0}
    u, v = _expand_bracket(b[0]), _expand_bracket(b[1])
    out: Dict[Word, float] = {}
    for wu, cu in u.items():
        for wv, cv in v.items():
            out[wu + wv] = out.get(wu + wv, 0.0) + cu * cv
            out[wv + wu] = out.get(wv + wu, 0.0) - cu * cv
    return {w: c for w, c in out.items() if c != 0.0}


def word_to_flat_index(w: Word, d: int, depth: int) -> int:
    """Position of tensor word w inside the flat level-1..depth layout."""
    k = len(w)
    within = 0
    for a in w:
        within = within * d + a
    return level_offsets(d, depth)[k - 1] + within


# ---------------------------------------------------------------------------
# cached static tables
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def lyndon_flat_indices(d: int, depth: int) -> np.ndarray:
    """Flat-layout index of every Lyndon word — the "final gather" table."""
    return np.asarray([word_to_flat_index(w, d, depth)
                       for w in lyndon_words(d, depth)], dtype=np.int32)


@functools.lru_cache(maxsize=None)
def expand_matrix(d: int, depth: int) -> np.ndarray:
    """E (n_lyndon, sig_dim): row i is the tensor expansion of bracket i."""
    words = lyndon_words(d, depth)
    E = np.zeros((len(words), sig_dim(d, depth)), dtype=np.float64)
    for i, w in enumerate(words):
        for tw, c in _expand_bracket(standard_bracketing(w)).items():
            E[i, word_to_flat_index(tw, d, depth)] = c
    return E


@functools.lru_cache(maxsize=None)
def _basis_change(d: int, depth: int) -> Tuple[np.ndarray, np.ndarray]:
    """(M, M^{-1}) with M[i, j] = coeff of Lyndon word i in bracket j.

    With (length, lex) ordering M is block-diagonal by length and
    lower-unitriangular within each block, hence exactly invertible.
    """
    M = expand_matrix(d, depth)[:, lyndon_flat_indices(d, depth)].T
    assert np.allclose(np.diag(M), 1.0) and np.allclose(np.triu(M, 1), 0.0)
    return M, np.linalg.inv(M)


# ---------------------------------------------------------------------------
# jit-compatible compress / expand maps
# ---------------------------------------------------------------------------

def compress(logsig_flat: jax.Array, d: int, depth: int,
             mode: str = "lyndon") -> jax.Array:
    """Project a flat log-signature (..., sig_dim) onto Lie coordinates
    (..., logsig_dim).

    ``mode="lyndon"``: gather the Lyndon-word coefficients (a static take).
    ``mode="brackets"``: additionally apply the precomputed inverse of the
    unitriangular word->bracket change of basis.
    """
    idx = jnp.asarray(lyndon_flat_indices(d, depth))
    words = jnp.take(logsig_flat, idx, axis=-1)
    if mode == "lyndon":
        return words
    if mode == "brackets":
        _, Minv = _basis_change(d, depth)
        return words @ jnp.asarray(Minv, dtype=logsig_flat.dtype).T
    raise ValueError(f"unknown compress mode: {mode!r}")


def expand(coeffs: jax.Array, d: int, depth: int,
           mode: str = "lyndon") -> jax.Array:
    """Inverse of :func:`compress`: Lie coordinates (..., logsig_dim) back to
    the flat tensor layout (..., sig_dim)."""
    E = jnp.asarray(expand_matrix(d, depth), dtype=coeffs.dtype)
    if mode == "lyndon":
        _, Minv = _basis_change(d, depth)
        coeffs = coeffs @ jnp.asarray(Minv, dtype=coeffs.dtype).T
    elif mode != "brackets":
        raise ValueError(f"unknown expand mode: {mode!r}")
    return coeffs @ E
