"""Sub-quadratic signature-kernel approximations as feature maps.

Every loss in the library costs O(B²) Goursat PDE solves through the exact
Gram engine.  This module provides the two classic low-rank escapes (KSig
user's guide, arxiv 2501.07145) as *feature maps* ``phi(X) ∈ R^{B×F}``
whose inner products approximate the exact signature-kernel Gram,

    K[a, b] = k(X_a, Y_b)  ≈  ⟨phi(X)_a, phi(Y)_b⟩,

so MMD-style losses become O(B·F) end-to-end — the full (B, B) Gram (and
the (B, B, Lx, Ly) pairwise Δ stack) never exists, in the value *or* the
gradient (the streaming guard of :mod:`repro.core.gram` proves it).

``"rff"`` — Random Fourier signature features
    The static-kernel lift is replaced by its random-Fourier feature map
    (exact for :class:`repro.Linear`; the classic Bochner ``cos(Wx + b)``
    features for :class:`repro.RBF`), and the lifted path's *truncated
    signature* is sketched by tensor random projections: one projection
    draw ``u_1, …, u_n`` turns the level-n signature tensor into the
    scalar iterated sum ``Σ_{i_1<…<i_n} Π_k ⟨u_k, dz_{i_k}⟩`` — an
    O(L·depth) scan per draw, unbiased because
    ``E[⟨u_1⊗…⊗u_n, S⟩·⟨u_1⊗…⊗u_n, T⟩] = ⟨S, T⟩`` for independent
    isotropic ``u_k``.  ``rank`` independent draws are averaged, so the
    feature dimension is ``1 + rank·depth`` and the variance shrinks as
    1/rank.  No PDE solves at all.

``"nystroem"`` — landmark (pivoted-Cholesky) low-rank approximation
    ``rank`` landmark paths are greedily selected from a ``pool``-sized
    candidate subset by pivoted Cholesky on the *exact* landmark Gram
    (the classic trace-norm-greedy rule: each pivot is the largest
    residual diagonal), and ``phi(A) = K(A, Z)·L_w^{-T}`` with
    ``L_w = chol(K(Z, Z) + jitter·I)``, so
    ``phi(A)·phi(B)^T = K(A,Z)·K(Z,Z)^{-1}·K(Z,B)`` — the Nyström
    approximation, exact when ``rank`` reaches the Gram's numerical rank.
    Costs O(pool²) + O(B·rank) exact PDE solves — linear in B.

Both maps are plain differentiable JAX (the Nyström pivot *selection* is
detached via ``stop_gradient``; everything it gathers stays on the tape),
compose with every :class:`repro.TransformPipeline` / static-kernel lift /
``lengths=`` ragged batch, and are deterministic given the ``key`` leaf of
:class:`FeatureConfig`.

The entry points live in :mod:`repro.core.gram`: pass
``features=FeatureConfig(...)`` (or a caller error budget, see
``docs/api/public.md`` § Approximate kernels) to ``sigkernel_gram``,
``sigkernel_gram_reduce``, ``mmd2``, ``scoring_rule`` or
``sig_aux_loss``; the dispatch registry routes the ``"rff"`` /
``"nystroem"`` backend names here.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .config import Linear, RBF, _pytree_dataclass
from . import transforms as tf

#: methods a FeatureConfig may name (also the dispatch backend names)
METHODS = ("rff", "nystroem")

#: floor added to pivoted-Cholesky residuals before the sqrt — keeps the
#: selection loop finite when the residual underflows at full rank
_PIVOT_TINY = 1e-12


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    """Configuration of one approximate sig-kernel feature map.

    A frozen pytree: ``method``/``rank``/``depth``/``lift_dim``/``pool``
    are static metadata (they set feature shapes and trace structure);
    ``key`` and ``jitter`` are data leaves, so the same trace serves any
    seed and the Cholesky jitter stays tunable under ``jit``.

    Attributes:
      method: ``"rff"`` (random Fourier signature features, no PDE solves)
        or ``"nystroem"`` (landmark low-rank, O(B·rank) exact solves).
      rank: approximation rank R — the number of independent projection
        draws (rff; feature dim ``1 + rank·depth``) or landmarks
        (nystroem; feature dim ``rank``, silently clamped to the available
        pool for small batches).  Accuracy rises and speedup falls with R:
        the bench frontier workload maps the trade-off.
      key: PRNG key leaf making the map deterministic and reproducible;
        ``None`` means ``jax.random.PRNGKey(0)``.  Two configs differing
        only in ``key`` share one jit trace.
      depth: rff only — signature truncation depth of the sketch.  The
        exact kernel's level-n term decays like ``‖path‖^{2n}/(n!)²``, so
        small depths already capture paper-scale paths.
      lift_dim: rff only — random-Fourier dimension m of the static-kernel
        lift (ignored for :class:`repro.Linear`, which lifts exactly).
      pool: nystroem only — candidate-subset size the pivoted-Cholesky
        selection sees.  0 (default) means ``min(B, 4·rank)``.
      jitter: nystroem only — diagonal regulariser of the landmark Gram
        Cholesky.
    """

    method: str = "rff"
    rank: int = 32
    key: Optional[jax.Array] = None
    depth: int = 4
    lift_dim: int = 64
    pool: int = 0
    jitter: float = 1e-6

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(
                f"FeatureConfig.method must be one of {METHODS}, got "
                f"{self.method!r}")
        for name, lo in (("rank", 1), ("depth", 1), ("lift_dim", 1),
                         ("pool", 0)):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v < lo:
                raise ValueError(
                    f"FeatureConfig.{name} must be a Python int >= {lo} "
                    f"(it sets static feature shapes), got {v!r}")

    def resolved_key(self) -> jax.Array:
        return jax.random.PRNGKey(0) if self.key is None else self.key

    def feature_dim(self, batch: int) -> int:
        """Static feature dimension F of ``phi`` for a batch of ``batch``."""
        if self.method == "rff":
            return 1 + self.rank * self.depth
        return min(self.rank, self.pool_size(batch))

    def pool_size(self, batch: int) -> int:
        """Concrete nystroem candidate-pool size for a batch of ``batch``."""
        pool = self.pool if self.pool else 4 * self.rank
        return max(1, min(int(pool), int(batch)))


_pytree_dataclass(FeatureConfig, data_fields=("key", "jitter"),
                  meta_fields=("method", "rank", "depth", "lift_dim",
                               "pool"))


def resolve_features(features) -> Optional[FeatureConfig]:
    """Type-check the ``features=`` kwarg of the Gram entry points."""
    if features is None or isinstance(features, FeatureConfig):
        return features
    raise TypeError(
        f"features= expects a FeatureConfig, got "
        f"{type(features).__name__} (see docs/api/public.md, "
        f"'Approximate kernels')")


# ---------------------------------------------------------------------------
# random Fourier signature features
# ---------------------------------------------------------------------------

def _rff_lift(points: jax.Array, kernel, key: jax.Array,
              m: int) -> jax.Array:
    """Pointwise feature map of the static-kernel lift: (..., L, d) -> (..., L, m').

    ``Linear(scale)`` lifts exactly (``√scale·x``, no randomness, m' = d);
    ``RBF(sigma)`` uses Bochner features ``√(2/m)·cos(x·W + b)`` with
    ``W ~ N(0, I/σ²)``, ``b ~ U[0, 2π)`` — ``E⟨z(x), z(y)⟩ = κ(x, y)``.
    ``sigma`` stays on the tape (W is the standard-normal draw divided by
    it), so kernel hyper-parameter gradients survive the approximation.
    """
    d = points.shape[-1]
    if isinstance(kernel, Linear):
        scale = jnp.asarray(kernel.scale, points.dtype)
        return points * jnp.sqrt(scale)
    if isinstance(kernel, RBF):
        kw, kb = jax.random.split(key)
        w = jax.random.normal(kw, (d, m), points.dtype) \
            / jnp.asarray(kernel.sigma, points.dtype)
        b = jax.random.uniform(kb, (m,), points.dtype, 0.0, 2.0 * jnp.pi)
        return jnp.sqrt(2.0 / m) * jnp.cos(points @ w + b)
    raise ValueError(
        f"rff features support Linear/RBF static kernels, got "
        f"{type(kernel).__name__}")


def _sig_projection_scan(inc: jax.Array, proj: jax.Array) -> jax.Array:
    """Tensor-random-projected signature levels of an increment stream.

    inc: (B, L, m) increments; proj: (rank, depth, m) projection draws.
    Returns (B, rank, depth): entry ``[b, r, n-1]`` is the level-n
    *continuous* (piecewise-linear) signature ``S_n`` contracted with
    ``u_1 ⊗ … ⊗ u_n``.  By Chen's identity the path signature is the
    ordered product of per-segment exponentials ``exp⊗(dz_l)``, so each
    scan step folds a whole segment in exactly:

        new_P[k] = Σ_{j≤k} P[j] · ⟨u_{j+1}, dz_l⟩ … ⟨u_k, dz_l⟩ / (k−j)!

    The ``1/(k−j)!`` within-segment powers are what distinguish this from
    the strict iterated *sum* (the discrete-time signature) — dropping
    them leaves an O(‖dz‖²) bias against the Goursat PDE solution, which
    integrates the continuous kernel.  O(depth²) work per step, with
    depth ≤ ~6 — negligible next to the einsum.  Trailing zero increments
    (ragged padding) are exact no-ops.
    """
    B = inc.shape[0]
    rank, depth, _ = proj.shape
    # s[l, b, r, k] = ⟨proj[r, k], dz_l⟩
    s = jnp.einsum("blm,rkm->lbrk", inc, proj)
    p0 = jnp.concatenate(
        [jnp.ones((B, rank, 1), inc.dtype),
         jnp.zeros((B, rank, depth), inc.dtype)], axis=-1)

    def step(p, s_l):
        new = [p[..., 0]]                       # level 0 stays 1
        for k in range(1, depth + 1):
            acc = p[..., k]
            prod = None
            fact = 1.0
            for j in range(k - 1, -1, -1):      # prod = s_{j+1} ⋯ s_k
                prod = s_l[..., j] if prod is None else prod * s_l[..., j]
                fact *= (k - j)
                acc = acc + p[..., j] * prod * (1.0 / fact)
            new.append(acc)
        return jnp.stack(new, axis=-1), None

    p, _ = jax.lax.scan(step, p0, s)
    return p[..., 1:]


def rff_features(paths: jax.Array, feats: FeatureConfig, pipeline,
                 kernel, lengths=None) -> jax.Array:
    """Random Fourier signature features phi(paths) ∈ (B, 1 + rank·depth).

    ``⟨phi(x), phi(y)⟩`` is an unbiased estimate (over the projection
    draws; and the Bochner draw for RBF lifts) of the depth-truncated
    signature kernel of the transformed, lifted paths — the quantity the
    Goursat PDE computes untruncated.  Ragged ``lengths=`` reuse the
    transform layer's clamped-padding semantics, so padded rows contribute
    exactly-zero increments and padding content (even NaN) never reaches
    the features.
    """
    if paths.ndim != 3:
        raise ValueError(
            f"rff_features expects (B, L, d) paths, got {paths.shape}")
    key = feats.resolved_key()
    k_lift, k_proj = jax.random.split(key)
    # transform first (start-aligned: trailing zero increments are no-ops
    # for the iterated-sum scan, mirroring the signature Horner kernels)
    points = tf.transform_path(paths, pipeline, lengths, align="start")
    z = _rff_lift(points, kernel, k_lift, feats.lift_dim)
    inc = z[:, 1:] - z[:, :-1]
    proj = jax.random.normal(k_proj,
                             (feats.rank, feats.depth, z.shape[-1]),
                             inc.dtype)
    levels = _sig_projection_scan(inc, proj)             # (B, rank, depth)
    flat = levels.reshape(inc.shape[0], feats.rank * feats.depth)
    flat = flat / jnp.sqrt(jnp.asarray(feats.rank, flat.dtype))
    one = jnp.ones((inc.shape[0], 1), flat.dtype)        # level-0 term
    return jnp.concatenate([one, flat], axis=-1)


# ---------------------------------------------------------------------------
# Nyström landmark selection (pivoted Cholesky) + feature solve
# ---------------------------------------------------------------------------

def pivoted_cholesky(G: jax.Array, rank: int):
    """Greedy rank-``rank`` pivoted Cholesky of a PSD Gram ``G`` (n, n).

    Returns ``(piv, resid)``: the selected pivot indices (rank,) int32 in
    selection order, and the residual diagonal trace after each step
    (rank,) — ``resid[-1]`` bounds ``‖G − L·L^T‖_tr``, the classic
    certificate that ``rank`` was enough.  Selection runs on
    ``stop_gradient(G)``: which landmarks win is a discrete choice with no
    useful derivative, while everything the caller *gathers at* those
    indices stays differentiable.
    """
    n = G.shape[0]
    if not 1 <= rank <= n:
        raise ValueError(
            f"pivoted_cholesky rank must be in [1, {n}], got {rank}")
    Gs = jax.lax.stop_gradient(G)

    def step(carry, _):
        d, L, j = carry
        p = jnp.argmax(d)
        dp = jnp.maximum(d[p], _PIVOT_TINY)
        # residual column at the pivot: G[:, p] − L·L[p]
        col = Gs[:, p] - L @ L[p]
        lj = col / jnp.sqrt(dp)
        L = jax.lax.dynamic_update_index_in_dim(
            L.T, lj, j, axis=0).T                         # L[:, j] = lj
        d = jnp.maximum(d - lj * lj, 0.0)
        d = d.at[p].set(0.0)                              # never re-picked
        return (d, L, j + 1), (p.astype(jnp.int32), d.sum())

    d0 = jnp.diagonal(Gs)
    L0 = jnp.zeros((n, rank), Gs.dtype)
    (_, _, _), (piv, resid) = jax.lax.scan(
        step, (d0, L0, 0), None, length=rank)
    return piv, resid


def nystroem_factor(G_landmarks: jax.Array, jitter) -> jax.Array:
    """Lower Cholesky factor of the landmark Gram ``W + jitter·I``."""
    W = G_landmarks
    eye = jnp.eye(W.shape[0], dtype=W.dtype)
    return jnp.linalg.cholesky(W + jnp.asarray(jitter, W.dtype) * eye)


def nystroem_phi(K_cross: jax.Array, Lw: jax.Array) -> jax.Array:
    """Nyström features from an exact cross-Gram: ``K(A, Z)·L_w^{-T}``.

    ``phi(A)·phi(B)^T = K(A,Z)·(L_w·L_w^T)^{-1}·K(Z,B)`` — the Nyström
    approximation of ``K(A, B)``.
    """
    return jax.scipy.linalg.solve_triangular(
        Lw, K_cross.T, lower=True).T
