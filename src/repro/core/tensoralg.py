"""Flattened truncated tensor algebra over R^d.

The truncated tensor algebra T^N(R^d) = ⊕_{k=0..N} (R^d)^{⊗k} is the carrier
of signature computations.  Following pySigLib design choice (1), elements with
scalar part 1 (group-like elements such as signatures) are stored as a SINGLE
flattened contiguous array holding levels 1..N back-to-back::

    flat = [ A_1 (d floats) | A_2 (d^2 floats) | ... | A_N (d^N floats) ]

The scalar level A_0 == 1 is implicit.  All functions below are pure and
jit-compatible; ``d`` and ``depth`` are static Python ints.
"""

from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# layout helpers
# ---------------------------------------------------------------------------

def level_sizes(d: int, depth: int) -> List[int]:
    """Sizes of levels 1..depth: [d, d^2, ..., d^depth]."""
    return [d ** k for k in range(1, depth + 1)]


def sig_dim(d: int, depth: int) -> int:
    """Total flattened length of levels 1..depth."""
    return sum(level_sizes(d, depth))


def level_offsets(d: int, depth: int) -> List[int]:
    """Start offset of each level 1..depth inside the flat array."""
    offs, acc = [], 0
    for s in level_sizes(d, depth):
        offs.append(acc)
        acc += s
    return offs


def split_levels(flat: jax.Array, d: int, depth: int) -> List[jax.Array]:
    """Split a flat signature (..., sig_dim) into per-level arrays (..., d^k)."""
    out, off = [], 0
    for s in level_sizes(d, depth):
        out.append(flat[..., off:off + s])
        off += s
    return out


def join_levels(levels: Sequence[jax.Array]) -> jax.Array:
    """Concatenate per-level arrays back into a flat signature."""
    return jnp.concatenate(list(levels), axis=-1)


# ---------------------------------------------------------------------------
# primitive tensor operations (flat level representation)
# ---------------------------------------------------------------------------

def outer(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tensor (outer) product of flat level tensors.

    a: (..., m) flat level-i, b: (..., n) flat level-j  ->  (..., m*n) level-(i+j).
    """
    return (a[..., :, None] * b[..., None, :]).reshape(*a.shape[:-1], -1)


def tensor_exp_levels(z: jax.Array, depth: int) -> List[jax.Array]:
    """Levels 1..depth of exp(z) = sum_k z^{⊗k}/k! for an increment z (..., d)."""
    levels = [z]
    for k in range(2, depth + 1):
        levels.append(outer(levels[-1], z / k))
    return levels


def tensor_exp(z: jax.Array, depth: int) -> jax.Array:
    """Flat signature of a linear segment with increment z (Proposition 2.1)."""
    return join_levels(tensor_exp_levels(z, depth))


def chen_levels(a: List[jax.Array], b: List[jax.Array], depth: int) -> List[jax.Array]:
    """Chen product on per-level lists: c_k = a_k + b_k + Σ_{i=1}^{k-1} a_i ⊗ b_{k-i}."""
    out = []
    for k in range(1, depth + 1):
        c = a[k - 1] + b[k - 1]
        for i in range(1, k):
            c = c + outer(a[i - 1], b[k - i - 1])
        out.append(c)
    return out


def chen(a: jax.Array, b: jax.Array, d: int, depth: int) -> jax.Array:
    """Chen's identity (Prop 2.2): signature of a concatenation, flat in / flat out."""
    return join_levels(
        chen_levels(split_levels(a, d, depth), split_levels(b, d, depth), depth)
    )


def _levels_mul(a: List, b: List, depth: int) -> List:
    """Truncated product of two scalar-free elements given as level lists.

    Entries may be ``None`` (zero level); levels above ``depth`` are dropped.
    The result's level ``tot`` is Σ_{i} a_i ⊗ b_{tot-i}.
    """
    out: List = [None] * depth
    for tot in range(2, depth + 1):
        acc = None
        for i in range(1, tot):
            if a[i - 1] is None or b[tot - i - 1] is None:
                continue
            term = outer(a[i - 1], b[tot - i - 1])
            acc = term if acc is None else acc + term
        out[tot - 1] = acc
    return out


def _power_series(al: List[jax.Array], depth: int, coeff) -> List[jax.Array]:
    """Σ_{k>=1} coeff(k) · u^{⊗k} truncated at ``depth``, u given as levels."""
    out = [coeff(1) * x for x in al]
    power: List = list(al)
    for k in range(2, depth + 1):
        power = _levels_mul(power, al, depth)   # u^{⊗k}; levels < k are None
        c = coeff(k)
        for lvl in range(k, depth + 1):
            if power[lvl - 1] is not None:
                out[lvl - 1] = out[lvl - 1] + c * power[lvl - 1]
    return out


def sig_inverse(a: jax.Array, d: int, depth: int) -> jax.Array:
    """Group inverse of a signature: S(x)^{-1} = S(time-reversed x).

    Computed as the truncated tensor-algebra inverse of (1, a_1, a_2, ...):
    b = Σ_{k>=0} (-1)^k (a - 1)^{⊗k}, truncated at ``depth``.
    """
    al = split_levels(a, d, depth)
    return join_levels(_power_series(al, depth, lambda k: (-1.0) ** k))


def tensor_log(a: jax.Array, d: int, depth: int) -> jax.Array:
    """Truncated log of a group-like element (the dual of :func:`tensor_exp`).

    ``a`` is a flat signature (scalar part 1 implicit); returns the flat
    Lie element log(1 + u) = Σ_{k>=1} (-1)^{k+1} u^{⊗k} / k with u = a.
    The result lives in the free Lie algebra — its Lyndon-coordinate
    projection is ``repro.core.lyndon.compress``.
    """
    al = split_levels(a, d, depth)
    return join_levels(
        _power_series(al, depth, lambda k: (-1.0) ** (k + 1) / k))


def tensor_exp_full(a: jax.Array, d: int, depth: int) -> jax.Array:
    """Truncated exp of an arbitrary scalar-free element (flat in / flat out).

    Generalises :func:`tensor_exp` (which only handles level-1 increments):
    exp(u) = Σ_{k>=0} u^{⊗k}/k!, scalar part implicit.  Inverse of
    :func:`tensor_log` on the image of log.
    """
    al = split_levels(a, d, depth)
    fact = [1.0]
    for k in range(1, depth + 1):
        fact.append(fact[-1] * k)
    return join_levels(_power_series(al, depth, lambda k: 1.0 / fact[k]))


def sig_inner(a: jax.Array, b: jax.Array, d: int, depth: int,
              include_scalar: bool = True) -> jax.Array:
    """Standard (Euclidean tensor) inner product ⟨a, b⟩ over levels 0..depth."""
    ip = jnp.sum(a * b, axis=-1)
    if include_scalar:
        ip = ip + 1.0  # level-0 contribution 1*1
    return ip


@functools.partial(jax.jit, static_argnums=(1, 2))
def identity_like(batch_shape, d: int, depth: int, dtype=jnp.float32) -> jax.Array:
    """Flat representation of the group identity (1, 0, 0, ...)."""
    return jnp.zeros((*batch_shape, sig_dim(d, depth)), dtype=dtype)
