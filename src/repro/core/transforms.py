"""Path-to-path transformations (pySigLib §4), backpropagatable.

Two views are provided:

* ``time_augment`` / ``lead_lag`` — materialise the transformed *path*
  (useful for user code and for oracles).
* ``transform_increments`` — the on-the-fly view: produce the transformed
  path's *increment stream* directly from the raw increments, which is all the
  signature / signature-kernel algorithms consume.  This is the paper's
  "adapting the algorithms internally" — the transformed path never exists in
  memory.

Lead-lag convention ([10, 18, 19], paper §4): with points x_0..x_{L-1},
the lead-lag path has 2L-1 points p_i = (lead_i, lag_i) with
lead_{2k} = lead_{2k-1} = x_k and lag_{2k} = lag_{2k+1} = x_k, so its
increments alternate (dx_k, 0) (lead jumps first) then (0, dx_k).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0) -> jax.Array:
    """x̂_{t_i} = (x_{t_i}, t_i) ∈ R^{d+1} with a uniform time grid."""
    L = path.shape[-2]
    t = jnp.linspace(t0, t1, L, dtype=path.dtype)
    t = jnp.broadcast_to(t[..., :, None], (*path.shape[:-1], 1))
    return jnp.concatenate([path, t], axis=-1)


def lead_lag(path: jax.Array) -> jax.Array:
    """X^LL_{t_i} = (X^Lead_{t_i}, X^Lag_{t_i}) ∈ R^{2d}, length 2L-1."""
    L = path.shape[-2]
    rep = jnp.repeat(path, 2, axis=-2)              # x0 x0 x1 x1 ... (2L)
    leadc = rep[..., 1:, :]                          # lead: x0 x1 x1 x2 x2 ... (2L-1)
    lagc = rep[..., :-1, :]                          # lag:  x0 x0 x1 x1 x2 ... (2L-1)
    return jnp.concatenate([leadc, lagc], axis=-1)


def basepoint(path: jax.Array) -> jax.Array:
    """Prepend the origin, making translation information visible to S(x)."""
    zero = jnp.zeros_like(path[..., :1, :])
    return jnp.concatenate([zero, path], axis=-2)


def transform_increments(z: jax.Array, time_aug: bool, lead_lag_: bool,
                         t0: float = 0.0, t1: float = 1.0) -> jax.Array:
    """On-the-fly transform of an increment stream z (..., L-1, d).

    Matches increments of the materialised transforms above exactly.
    """
    n = z.shape[-2]
    if lead_lag_:
        zeros = jnp.zeros_like(z)
        lead_inc = jnp.concatenate([z, zeros], axis=-1)   # (dx, 0)
        lag_inc = jnp.concatenate([zeros, z], axis=-1)    # (0, dx)
        z = jnp.stack([lead_inc, lag_inc], axis=-2).reshape(
            *z.shape[:-2], 2 * n, 2 * z.shape[-1])
    if time_aug:
        # uniform time grid over the (possibly lead-lagged) point sequence, so
        # this matches time_augment(lead_lag(x)) exactly.
        steps = z.shape[-2]
        dt = jnp.full((*z.shape[:-1], 1), (t1 - t0) / steps, dtype=z.dtype)
        z = jnp.concatenate([z, dt], axis=-1)
    return z
