"""Path-to-path transformations (pySigLib §4), backpropagatable.

Two views are provided:

* ``time_augment`` / ``lead_lag`` — materialise the transformed *path*
  (useful for user code and for oracles).
* ``transform_increments`` — the on-the-fly view: produce the transformed
  path's *increment stream* directly from the raw increments, which is all the
  signature / signature-kernel algorithms consume.  This is the paper's
  "adapting the algorithms internally" — the transformed path never exists in
  memory.

Lead-lag convention ([10, 18, 19], paper §4): with points x_0..x_{L-1},
the lead-lag path has 2L-1 points p_i = (lead_i, lag_i) with
lead_{2k} = lead_{2k-1} = x_k and lag_{2k} = lag_{2k+1} = x_k, so its
increments alternate (dx_k, 0) (lead jumps first) then (0, dx_k).

The canonical pipeline order (what :class:`repro.TransformPipeline`
denotes) is **basepoint → lead-lag → time-aug**, i.e. the materialised
``time_augment(lead_lag(basepoint(x)), t0, t1)``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0) -> jax.Array:
    """x̂_{t_i} = (x_{t_i}, t_i) ∈ R^{d+1} with a uniform time grid."""
    L = path.shape[-2]
    t = jnp.linspace(t0, t1, L, dtype=path.dtype)
    t = jnp.broadcast_to(t[..., :, None], (*path.shape[:-1], 1))
    return jnp.concatenate([path, t], axis=-1)


def lead_lag(path: jax.Array) -> jax.Array:
    """X^LL_{t_i} = (X^Lead_{t_i}, X^Lag_{t_i}) ∈ R^{2d}, length 2L-1."""
    L = path.shape[-2]
    rep = jnp.repeat(path, 2, axis=-2)              # x0 x0 x1 x1 ... (2L)
    leadc = rep[..., 1:, :]                          # lead: x0 x1 x1 x2 x2 ... (2L-1)
    lagc = rep[..., :-1, :]                          # lag:  x0 x0 x1 x1 x2 ... (2L-1)
    return jnp.concatenate([leadc, lagc], axis=-1)


def basepoint(path: jax.Array) -> jax.Array:
    """Prepend the origin, making translation information visible to S(x)."""
    zero = jnp.zeros_like(path[..., :1, :])
    return jnp.concatenate([zero, path], axis=-2)


def transform_increments(z: jax.Array, time_aug: bool, lead_lag_: bool,
                         t0: float = 0.0, t1: float = 1.0, *,
                         basepoint_: bool = False,
                         first: Optional[jax.Array] = None) -> jax.Array:
    """On-the-fly transform of an increment stream z (..., L-1, d).

    Matches increments of the materialised transforms above exactly, in the
    canonical order basepoint → lead-lag → time-aug.  ``basepoint_``
    prepends the increment 0 → x_0 (which equals the first path point), so
    the padded path is never materialised; it needs ``first`` — the (..., d)
    first point of the path — because increments alone don't determine it.
    """
    if basepoint_:
        if first is None:
            raise ValueError(
                "transform_increments(basepoint_=True) needs first= (the "
                "(..., d) first path point): the 0 -> x_0 increment is not "
                "derivable from the increment stream")
        z = jnp.concatenate([first[..., None, :], z], axis=-2)
    n = z.shape[-2]
    if lead_lag_:
        zeros = jnp.zeros_like(z)
        lead_inc = jnp.concatenate([z, zeros], axis=-1)   # (dx, 0)
        lag_inc = jnp.concatenate([zeros, z], axis=-1)    # (0, dx)
        z = jnp.stack([lead_inc, lag_inc], axis=-2).reshape(
            *z.shape[:-2], 2 * n, 2 * z.shape[-1])
    if time_aug:
        # uniform time grid over the (possibly lead-lagged) point sequence, so
        # this matches time_augment(lead_lag(x)) exactly.
        steps = z.shape[-2]
        dt = jnp.full((*z.shape[:-1], 1), (t1 - t0) / steps, dtype=z.dtype)
        z = jnp.concatenate([z, dt], axis=-1)
    return z


def transform_path(path: jax.Array, pipeline) -> jax.Array:
    """Materialise a :class:`repro.TransformPipeline` on a path of points.

    Applies basepoint → lead-lag → time-aug in the canonical order.  Used
    by oracles and by the Δ-from-Gram path of non-linear static-kernel
    lifts (which need actual points, not increments); the signature /
    linear-kernel hot paths stay on :func:`transform_increments`.
    """
    if pipeline.basepoint:
        path = basepoint(path)
    if pipeline.lead_lag:
        path = lead_lag(path)
    if pipeline.time_aug:
        path = time_augment(path, pipeline.t0, pipeline.t1)
    return path


def pipeline_increments(path: jax.Array, pipeline) -> jax.Array:
    """Increment stream of ``transform_path(path, pipeline)`` — computed
    on-the-fly from the raw increments (the transformed path never exists
    in memory)."""
    z = path[..., 1:, :] - path[..., :-1, :]
    return transform_increments(
        z, pipeline.time_aug, pipeline.lead_lag, pipeline.t0, pipeline.t1,
        basepoint_=pipeline.basepoint,
        first=path[..., 0, :] if pipeline.basepoint else None)
