"""Path-to-path transformations (pySigLib §4), backpropagatable.

Two views are provided:

* ``time_augment`` / ``lead_lag`` — materialise the transformed *path*
  (useful for user code and for oracles).
* ``transform_increments`` — the on-the-fly view: produce the transformed
  path's *increment stream* directly from the raw increments, which is all the
  signature / signature-kernel algorithms consume.  This is the paper's
  "adapting the algorithms internally" — the transformed path never exists in
  memory.

Lead-lag convention ([10, 18, 19], paper §4): with points x_0..x_{L-1},
the lead-lag path has 2L-1 points p_i = (lead_i, lag_i) with
lead_{2k} = lead_{2k-1} = x_k and lag_{2k} = lag_{2k+1} = x_k, so its
increments alternate (dx_k, 0) (lead jumps first) then (0, dx_k).

The canonical pipeline order (what :class:`repro.TransformPipeline`
denotes) is **basepoint → lead-lag → time-aug**, i.e. the materialised
``time_augment(lead_lag(basepoint(x)), t0, t1)``.

Ragged batches
--------------

Every transform here accepts an optional ``lengths`` array of per-path true
point counts (2 ≤ lengths[b] ≤ L): the batch stays a dense ``(..., L, d)``
array, but each path is treated as if truncated to its own length.  The
padding *content* is irrelevant — increments at or past the true end are
masked to zero, and the point view clamps every padded index to the last
true point — so NaN-filled padding is as good as edge padding.  The time
grid of ``time_aug`` reaches ``t1`` at each path's true last point (and
stays there), which is exactly the semantics naive padding silently breaks.

Two alignments of the resulting dense stream are offered:

* ``align="start"`` (default) — valid entries first, zeros after.  Trailing
  zero increments are bitwise no-ops for the Horner signature recursion
  (``A ⊗ 0 = 0``), so signatures and ``stream=True`` prefixes read
  naturally.
* ``align="end"`` — valid entries last, zeros (increments) / first-point
  copies (points) before.  A leading zero row/column of Δ keeps the Goursat
  boundary of ones *bitwise* intact (``A(0) = B(0) = 1`` and
  ``(1+1)·1 − 1·1 = 1`` exactly), so the PDE solvers' far-corner readout IS
  the true ``(len_x, len_y)``-corner readout on every backend — this is the
  alignment the sig-kernel/Gram paths use (docs/solver_guide.md).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

#: floor dtype for time-grid construction: a bf16/f16 linspace accumulates
#: visible rounding by L≈4k (bf16 can't even represent the integers past
#: 256), and integer paths have no sensible grid at all — those all build
#: in f32 and cast.  f64 paths keep f64 grids (see _grid_compute_dtype).
_GRID_DTYPE = jnp.float32

#: ragged length axes are padded up to at least this many points, then to
#: the next power of two — the length-bucketing policy bounding how many
#: distinct shapes (== jit traces / autotune keys) a ragged workload creates
_MIN_BUCKET = 8


# ---------------------------------------------------------------------------
# ragged-batch plumbing
# ---------------------------------------------------------------------------

def _check_lengths(lengths, batch_shape, L: int) -> jax.Array:
    """Validate a per-path lengths array against a (..., L, d) batch."""
    arr = jnp.asarray(lengths)
    if not jnp.issubdtype(arr.dtype, jnp.integer):
        raise TypeError(
            f"lengths= must be integer-typed per-path point counts, got "
            f"dtype {arr.dtype}")
    if arr.shape != tuple(batch_shape):
        raise ValueError(
            f"lengths shape {arr.shape} must equal the path batch shape "
            f"{tuple(batch_shape)} (one true length per path)")
    arr = arr.astype(jnp.int32)
    if arr.size:
        try:
            # value checks need concrete lengths; under a trace (tracer
            # input, or a closed-over constant staged by omnistaging) only
            # the shape/dtype checks above apply
            lo, hi = int(arr.min()), int(arr.max())
        except jax.errors.ConcretizationTypeError:
            return arr
        if lo < 2:
            raise ValueError(
                f"lengths= entries must be >= 2 (a path needs at least one "
                f"increment), got min {lo}")
        if hi > L:
            raise ValueError(
                f"lengths= entries must be <= the padded length axis "
                f"({L}), got max {hi}")
    return arr


def bucket_length(L: int, minimum: int = _MIN_BUCKET) -> int:
    """Bucketed (padded) length for a ragged batch: next power of two ≥ L.

    Rounding ragged batches up to a small set of buckets is what keeps jit
    recompilation (and autotune cache growth) bounded: every batch whose max
    length lands in the same bucket shares one trace.  The cost is masked
    compute on at most ~2× the true lengths — see docs/solver_guide.md.
    """
    b = max(int(L), int(minimum))
    return 1 << (b - 1).bit_length()


def pad_ragged(path: jax.Array, lengths, *, bucket: bool = True,
               minimum: int = _MIN_BUCKET):
    """Canonicalise a ragged batch: ``(path, lengths)`` with the length axis
    padded up to :func:`bucket_length` and ``lengths`` as an int32 array.

    Padding repeats the last row (edge mode) purely for debuggability — all
    downstream consumers mask padded entries, so any padding content works.
    Call this *before* ``jax.jit`` so differently-ragged batches sharing a
    bucket hit one trace; the entry points also apply it internally.
    """
    lengths = _check_lengths(lengths, path.shape[:-2], path.shape[-2])
    if bucket:
        L = path.shape[-2]
        target = bucket_length(L, minimum)
        if target > L:
            width = [(0, 0)] * path.ndim
            width[-2] = (0, target - L)
            path = jnp.pad(path, width, mode="edge")
    return path, lengths


def _shift_to_end(stream: jax.Array, counts: jax.Array, *,
                  repeat_first: bool = False) -> jax.Array:
    """Move each path's valid block ``[0, counts)`` to the end of axis -2.

    Freed leading slots become zeros (increment streams) or copies of the
    first entry (point streams, ``repeat_first=True`` — repeated points give
    exactly-zero leading Δ rows through the Δ-from-Gram double difference).
    """
    n = stream.shape[-2]
    src = jnp.arange(n) - (n - counts)[..., None]          # (..., n)
    out = jnp.take_along_axis(stream, jnp.clip(src, 0, n - 1)[..., None],
                              axis=-2)
    if repeat_first:
        return out
    return jnp.where((src >= 0)[..., None], out,
                     jnp.zeros((), stream.dtype))


def _time_values(num: int, t0, t1, lengths: Optional[jax.Array],
                 dtype=_GRID_DTYPE) -> jax.Array:
    """Time grid over [t0, t1] in ``dtype``: (num,) or (..., num) ragged.

    One shared formula for the uniform and ragged cases so a padded path's
    grid is bitwise the truncated path's grid: t_i = t0 + (t1−t0)·i/(m−1)
    with i clamped to the true last index m−1 (padding sits at t1).
    """
    idx = jnp.arange(num, dtype=dtype)
    t0 = jnp.asarray(t0, dtype)
    t1 = jnp.asarray(t1, dtype)
    if lengths is None:
        last = jnp.asarray(max(num - 1, 1), dtype)
        r = idx / last
    else:
        last = (lengths - 1).astype(dtype)[..., None]  # (..., 1)
        r = jnp.minimum(idx, last) / last
    return t0 + (t1 - t0) * r


def _grid_compute_dtype(dtype) -> jnp.dtype:
    """Dtype the grid arithmetic runs in: at least f32, but f64 paths keep
    their full precision (promote_types(bf16|f16|int, f32) -> f32;
    promote_types(f64, f32) -> f64)."""
    return jnp.promote_types(dtype, _GRID_DTYPE)


def _grid_out_dtype(dtype) -> jnp.dtype:
    """Inexact path dtypes keep their dtype; integer paths promote to f32."""
    return dtype if jnp.issubdtype(dtype, jnp.inexact) else _GRID_DTYPE


# ---------------------------------------------------------------------------
# materialised transforms
# ---------------------------------------------------------------------------

def time_augment(path: jax.Array, t0: float = 0.0, t1: float = 1.0,
                 lengths=None) -> jax.Array:
    """x̂_{t_i} = (x_{t_i}, t_i) ∈ R^{d+1} with a uniform time grid.

    The grid is constructed in at-least-f32 and cast once: building it
    directly in the path dtype rounds badly for bf16/f16 at long L and
    breaks outright for integer paths (which now promote to f32); f64
    paths keep f64-exact grids.  With ``lengths=``, path
    ``b``'s grid is uniform over its *own* ``lengths[b]`` points — reaching
    ``t1`` at the true last point and staying there across the padding.
    """
    L = path.shape[-2]
    if lengths is not None:
        lengths = _check_lengths(lengths, path.shape[:-2], L)
    dtype = _grid_out_dtype(path.dtype)
    t = _time_values(L, t0, t1, lengths, _grid_compute_dtype(path.dtype))
    t = jnp.broadcast_to(t, path.shape[:-1]).astype(dtype)[..., None]
    return jnp.concatenate([path.astype(dtype), t], axis=-1)


def lead_lag(path: jax.Array) -> jax.Array:
    """X^LL_{t_i} = (X^Lead_{t_i}, X^Lag_{t_i}) ∈ R^{2d}, length 2L-1."""
    rep = jnp.repeat(path, 2, axis=-2)              # x0 x0 x1 x1 ... (2L)
    leadc = rep[..., 1:, :]                          # lead: x0 x1 x1 x2 x2 ... (2L-1)
    lagc = rep[..., :-1, :]                          # lag:  x0 x0 x1 x1 x2 ... (2L-1)
    return jnp.concatenate([leadc, lagc], axis=-1)


def basepoint(path: jax.Array) -> jax.Array:
    """Prepend the origin, making translation information visible to S(x)."""
    zero = jnp.zeros_like(path[..., :1, :])
    return jnp.concatenate([zero, path], axis=-2)


def transform_increments(z: jax.Array, time_aug: bool, lead_lag_: bool,
                         t0: float = 0.0, t1: float = 1.0, *,
                         basepoint_: bool = False,
                         first: Optional[jax.Array] = None,
                         valid_steps=None) -> jax.Array:
    """On-the-fly transform of an increment stream z (..., L-1, d).

    Matches increments of the materialised transforms above exactly, in the
    canonical order basepoint → lead-lag → time-aug.  ``basepoint_``
    prepends the increment 0 → x_0 (which equals the first path point), so
    the padded path is never materialised; it needs ``first`` — the (..., d)
    first point of the path — because increments alone don't determine it.

    ``valid_steps`` (ragged batches) is the per-path count of valid
    increments *after* the transforms (``pipeline.transformed_steps(len)``):
    the time channel becomes ``(t1−t0)/valid_steps`` on the first
    ``valid_steps`` rows and 0 on the padding, matching a per-path grid
    that ends at ``t1`` at the true length.  Callers are responsible for
    zeroing padded raw increments before calling.
    """
    if basepoint_:
        if first is None:
            raise ValueError(
                "transform_increments(basepoint_=True) needs first= (the "
                "(..., d) first path point): the 0 -> x_0 increment is not "
                "derivable from the increment stream")
        z = jnp.concatenate([first[..., None, :], z], axis=-2)
    n = z.shape[-2]
    if lead_lag_:
        zeros = jnp.zeros_like(z)
        lead_inc = jnp.concatenate([z, zeros], axis=-1)   # (dx, 0)
        lag_inc = jnp.concatenate([zeros, z], axis=-1)    # (0, dx)
        z = jnp.stack([lead_inc, lag_inc], axis=-2).reshape(
            *z.shape[:-2], 2 * n, 2 * z.shape[-1])
    if time_aug:
        # uniform time grid over the (possibly lead-lagged) point sequence, so
        # this matches time_augment(lead_lag(x)) exactly.  dt is built in f32
        # and cast (same discipline — and same formula — as time_augment).
        steps = z.shape[-2]
        dtype = _grid_out_dtype(z.dtype)
        compute = _grid_compute_dtype(z.dtype)
        span = jnp.asarray(t1, compute) - jnp.asarray(t0, compute)
        if valid_steps is None:
            dt = jnp.broadcast_to(span / jnp.asarray(steps, compute),
                                  (*z.shape[:-1], 1))
        else:
            per_path = span / valid_steps.astype(compute)      # (...,)
            on = jnp.arange(steps) < valid_steps[..., None]    # (..., steps)
            dt = jnp.where(on, per_path[..., None],
                           jnp.zeros((), compute))[..., None]
            dt = jnp.broadcast_to(dt, (*z.shape[:-1], 1))
        z = jnp.concatenate([z.astype(dtype), dt.astype(dtype)], axis=-1)
    return z


def transform_path(path: jax.Array, pipeline, lengths=None, *,
                   align: str = "start") -> jax.Array:
    """Materialise a :class:`repro.TransformPipeline` on a path of points.

    Applies basepoint → lead-lag → time-aug in the canonical order.  Used
    by oracles and by the Δ-from-Gram path of non-linear static-kernel
    lifts (which need actual points, not increments); the signature /
    linear-kernel hot paths stay on :func:`transform_increments`.

    With ``lengths=``, padded indices are first clamped to each path's last
    true point (so padding content never matters and padded rows repeat the
    final point — exactly-zero Δ rows through the Gram double difference);
    ``align="end"`` then moves the valid block to the end of the axis with
    leading first-point copies (see the module docstring for why the PDE
    paths want that).
    """
    if align not in ("start", "end"):
        raise ValueError(f"align must be 'start' or 'end', got {align!r}")
    counts = None
    if lengths is not None:
        lengths = _check_lengths(lengths, path.shape[:-2], path.shape[-2])
        idx = jnp.minimum(jnp.arange(path.shape[-2]), lengths[..., None] - 1)
        path = jnp.take_along_axis(path, idx[..., None], axis=-2)
        counts = lengths
    if pipeline.basepoint:
        path = basepoint(path)
        if counts is not None:
            counts = counts + 1
    if pipeline.lead_lag:
        path = lead_lag(path)
        if counts is not None:
            counts = 2 * counts - 1
    if pipeline.time_aug:
        path = time_augment(path, pipeline.t0, pipeline.t1, lengths=counts)
    if counts is not None and align == "end":
        path = _shift_to_end(path, counts, repeat_first=True)
    return path


def pipeline_increments(path: jax.Array, pipeline, lengths=None, *,
                        align: str = "start") -> jax.Array:
    """Increment stream of ``transform_path(path, pipeline)`` — computed
    on-the-fly from the raw increments (the transformed path never exists
    in memory).

    With ``lengths=``, increments at or past each path's true end are
    zeroed (equivalent to repeated-last-point padding, whatever the padding
    holds) and the time channel uses the per-path grid; ``align`` picks
    where the zeros live ("start" keeps valid increments first — what the
    signature scans want; "end" right-aligns them — what the PDE solvers
    want, see the module docstring).
    """
    if align not in ("start", "end"):
        raise ValueError(f"align must be 'start' or 'end', got {align!r}")
    z = path[..., 1:, :] - path[..., :-1, :]
    first = path[..., 0, :] if pipeline.basepoint else None
    if lengths is None:
        return transform_increments(
            z, pipeline.time_aug, pipeline.lead_lag, pipeline.t0,
            pipeline.t1, basepoint_=pipeline.basepoint, first=first)
    lengths = _check_lengths(lengths, path.shape[:-2], path.shape[-2])
    valid = jnp.arange(z.shape[-2]) < (lengths[..., None] - 1)
    z = jnp.where(valid[..., None], z, jnp.zeros((), z.dtype))
    steps = pipeline.transformed_steps(lengths)
    z = transform_increments(
        z, pipeline.time_aug, pipeline.lead_lag, pipeline.t0, pipeline.t1,
        basepoint_=pipeline.basepoint, first=first, valid_steps=steps)
    if align == "end":
        z = _shift_to_end(z, steps)
    return z
