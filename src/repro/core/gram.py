"""The Gram engine: one entry point for every sig-kernel Gram variant.

``sigkernel_gram(X, Y=None, ...)`` unifies what used to be three separate
code paths (dense einsum, row-blocked ``lax.map``, fused-Δ Pallas) behind the
backend registry in :mod:`repro.core.dispatch`:

* **dense** — all ``Bx·By`` Δ matrices materialised at once (small batches);
* **blocked** — ``row_block`` Gram rows live at a time; ``Bx`` is
  zero-padded to the block granularity (zero increments ⇒ k = 1 rows that
  are dropped, so padding is exact — same trick the PDE kernels use for
  strips);
* **fused** (``backend="pallas_fused"``) — Δ is built in VMEM from the
  increments and never exists in HBM, now differentiable end-to-end via the
  checkpointed exact backward;
* **symmetric fast path** — when ``Y`` is omitted only the
  ``Bx·(Bx+1)/2`` upper-triangle pairs are solved (≈2× fewer PDE solves for
  the ``Kxx``/``Kyy`` terms of every loss) and the result is mirrored.

Beyond the single-device engine this module provides the *distributed* and
*streaming* layers (docs/api/public.md § Distributed & streaming Grams):

* :func:`sigkernel_gram_sharded` — the same Gram tiled over a real device
  mesh via ``shard_map``: rows block-cyclic over the ``data`` axis, columns
  block-cyclic over ``model``; the symmetric fast path deals the global
  upper-triangle *pairs* round-robin over every device so the triangular
  tile grid stays load-balanced.
* :func:`sigkernel_gram_reduce` — streaming scalar reductions
  (``ΣK`` with or without the diagonal) that accumulate per-row-block
  partial sums under ``jax.checkpoint``, so neither the forward nor the
  VJP ever materialises the full (Bx, By) Gram.  ``mmd2`` and
  ``scoring_rule`` route through it when ``streaming=`` is on.
* :func:`assert_streaming_reduction` — an ``eval_shape``-style abstract
  trace (no FLOPs) over a reduction's jaxpr that raises
  :class:`StreamingViolation` if any intermediate materialises a
  ``(Bx, By, ...)`` array — the guard against silently densifying.

Row blocks and the Gram tiling are annotated with the logical mesh axes of
:mod:`repro.parallel.api` (rows → ``"batch"``, columns → ``"model"``), so
under a mesh + ``logical_rules`` context a pod-scale Gram is one call; with
no mesh the annotations are no-ops and the same code runs on a laptop.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import dispatch
from . import features as ft
from . import transforms as tf
from .config import (_maybe_scale as _scale, delta_from_gram,
                     resolve_kernel_configs)
from .dispatch import UNSET
from .sigkernel import _sigkernel_from_delta
from repro.parallel.api import shard
from repro.parallel.sharding import block_cyclic_perm, get_shard_map


def _prepare(paths: jax.Array, cfg, kernel, lengths=None) -> jax.Array:
    """Per-path stream the pair solvers consume: transformed *increments*
    for increment-lifting (linear) kernels, transformed *points* for
    everything else (the Δ-from-Gram path needs actual points).

    Either way zero-padding rows with zeros is exact: zero increments and
    all-zero point rows both give Δ = 0 ⇒ k = 1 rows, which are dropped.

    With ``lengths=`` (ragged batches) the streams come back *end-aligned*:
    each path's padding turns into exactly-zero leading Δ rows/columns for
    any pairing, which leaves the Goursat boundary of ones bitwise intact —
    so everything downstream of this function (pair gathers, row blocks,
    the fused kernels, the symmetric fast path, the sharded tiling) is
    ragged-oblivious.
    """
    if kernel.lifts_increments:
        return tf.pipeline_increments(paths, cfg, lengths, align="end")
    return tf.transform_path(paths, cfg, lengths, align="end")


def _pair_delta(sa: jax.Array, sb: jax.Array, kernel) -> jax.Array:
    """Δ for batches of prepared streams (leading dims broadcast)."""
    if kernel.lifts_increments:
        return kernel.delta_from_increments(sa, sb)
    return delta_from_gram(kernel.gram(sa, sb))


def _solve_pairs(sa: jax.Array, sb: jax.Array, kernel, backend: str,
                 g, launch=None) -> jax.Array:
    """Solve one batch of prepared pairs (P, ·, d) × (P, ·, d) -> (P,).

    ``g`` is the resolved :class:`repro.GridConfig`: refinement levels AND
    the scheme / interior-dtype static knobs travel together so every pair
    solver (fused or Δ-materialising) runs the same discretisation.
    """
    if backend == "pallas_fused":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        # fused kernels compute ⟨dx, dy⟩ in VMEM; fold a non-unit linear
        # scale into one side (scale·⟨dx, dy⟩ = ⟨scale·dx, dy⟩ exactly)
        return pde_ops.solve_fused(_scale(sa, kernel.scale), sb, g.lam1,
                                   g.lam2, launch, g.scheme,
                                   g.interior_dtype)
    return _sigkernel_from_delta(_pair_delta(sa, sb, kernel), g.lam1, g.lam2,
                                 backend, launch, g.scheme, g.interior_dtype)


def _gram_block(sxb: jax.Array, sY: jax.Array, kernel, backend: str,
                g, launch=None) -> jax.Array:
    """Gram block from prepared streams (r, ·, d) × (By, ·, d) -> (r, By)."""
    if backend == "pallas_fused":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        return pde_ops.gram_fused(_scale(sxb, kernel.scale), sY, g.lam1,
                                  g.lam2, launch, g.scheme, g.interior_dtype)
    delta = _pair_delta(sxb[:, None], sY[None, :], kernel)
    return _sigkernel_from_delta(delta, g.lam1, g.lam2, backend, launch,
                                 g.scheme, g.interior_dtype)


def _gram_rows(sX: jax.Array, sY: jax.Array, kernel, backend: str,
               g, row_block: Optional[int], launch=None) -> jax.Array:
    """(Bx, ·, d) × (By, ·, d) -> (Bx, By), optionally ``row_block`` rows
    in flight at a time (``Bx`` zero-padded; padded rows dropped)."""
    Bx, By = sX.shape[0], sY.shape[0]
    if row_block is None:
        return _gram_block(sX, sY, kernel, backend, g, launch)
    pad = (-Bx) % row_block
    if pad:  # zero rows -> Δ = 0 -> k = 1 rows, dropped below: exact
        sX = jnp.pad(sX, ((0, pad), (0, 0), (0, 0)))
    n_blocks = (Bx + pad) // row_block
    sXb = sX.reshape(n_blocks, row_block, *sX.shape[1:])
    K = jax.lax.map(
        lambda sxb: _gram_block(sxb, sY, kernel, backend, g, launch),
        sXb)
    return K.reshape(n_blocks * row_block, By)[:Bx]


def _solve_pairs_chunked(sX: jax.Array, a_idx, b_idx, kernel, backend: str,
                         g, chunk: Optional[int], launch=None) -> jax.Array:
    """k values for an explicit pair list into one stream batch, at most
    ``chunk`` pairs of replicated increments live at once.

    Only the (chunk,)-sized index arrays are materialised up front; the
    pair gather itself happens inside the mapped body, one chunk at a
    time, so live replicated increments stay at 2·chunk·L·d floats.
    Padding pairs (0, 0) are solved and dropped (exact; accounted by the
    caller's pair-solve budget).
    """
    a_idx, b_idx = jnp.asarray(a_idx), jnp.asarray(b_idx)
    n = a_idx.shape[0]
    if chunk is None or chunk >= n:
        return _solve_pairs(sX[a_idx], sX[b_idx], kernel, backend, g, launch)
    pad = (-n) % chunk
    a = jnp.concatenate([a_idx, jnp.zeros((pad,), a_idx.dtype)])
    b = jnp.concatenate([b_idx, jnp.zeros((pad,), b_idx.dtype)])
    k = jax.lax.map(
        lambda ab: _solve_pairs(sX[ab[0]], sX[ab[1]], kernel, backend, g,
                                launch),
        (a.reshape(-1, chunk), b.reshape(-1, chunk)))
    return k.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# shared front-end: validation, config resolution, ragged padding, dispatch
# ---------------------------------------------------------------------------

def _resolve_engine(X, Y, symmetric, lengths, lengths_y, transforms, grid,
                    static_kernel, lam1, lam2, time_aug, lead_lag,
                    use_pallas, solver, backend, launch=None,
                    features=None, error_budget=None):
    """The engine front-end every Gram entry point shares.

    Validates shapes/flags, resolves configs + legacy shims, pads ragged
    batches, and resolves ``backend`` through the dispatch registry and
    ``launch`` through :func:`repro.core.dispatch.resolve_launch`
    (explicit > autotuned > defaults).  Returns
    ``(X, Y, cfg, grid_cfg, kernel, backend, symmetric, launch, feats)``
    with ``X``/``Y`` already ragged-padded (masking is burnt into the
    prepared streams downstream, so ``lengths`` are consumed here).

    ``feats`` is the active :class:`repro.core.features.FeatureConfig` or
    None (= exact engine).  An approximation activates one of three ways:
    an explicit ``features=`` config; an explicit approximate *backend
    name* (``"rff"``/``"nystroem"``) together with ``features=`` or
    ``error_budget=`` (without either the dispatch layer refuses — the
    capability-flag contract); or ``backend="auto"`` + ``error_budget=``
    when the autotune cache holds a measured frontier point meeting the
    budget (:func:`repro.core.dispatch.resolve_approx`) — never
    otherwise.
    """
    if X.ndim != 3 or (Y is not None and Y.ndim != 3):
        raise ValueError(
            f"sigkernel_gram expects (B, L, d) paths, got X {X.shape}"
            + ("" if Y is None else f", Y {Y.shape}"))
    if symmetric is None:
        symmetric = Y is None
    if symmetric and not (Y is None or Y is X):
        raise ValueError("symmetric=True requires Y to be None or X itself")
    if not symmetric and Y is None:
        raise ValueError("symmetric=False requires Y (pass Y=X for the "
                         "full symmetric Gram without the fast path)")
    if lengths_y is not None and Y is None:
        raise ValueError("lengths_y= requires Y; for the symmetric Gram "
                         "pass lengths= (it applies to both sides)")

    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    if lengths is not None:
        X, lengths = tf.pad_ragged(X, lengths)
    if lengths_y is not None:
        Y, lengths_y = tf.pad_ragged(Y, lengths_y)
    ragged = lengths is not None or lengths_y is not None
    backend = dispatch.canonicalize(backend, op="gram",
                                    use_pallas=use_pallas, solver=solver)
    if backend == "pallas_fused" and not kernel.lifts_increments:
        raise ValueError(
            "backend='pallas_fused' builds Δ from increments in VMEM and "
            f"only supports the linear lift, got "
            f"static_kernel={type(kernel).__name__}; pass backend='auto'")
    Lx = cfg.transformed_steps(X.shape[1])
    Ly = Lx if Y is None else cfg.transformed_steps(Y.shape[1])
    By = X.shape[0] if Y is None else Y.shape[0]
    key_shape = (X.shape[0], By, Lx << g.lam1, Ly << g.lam2,
                 cfg.transformed_dim(X.shape[-1]))

    feats = ft.resolve_features(features)
    if feats is not None and backend not in ("auto", feats.method):
        raise ValueError(
            f"features=FeatureConfig(method={feats.method!r}) conflicts "
            f"with backend={backend!r}; pass backend='auto' or "
            f"backend={feats.method!r}")
    explicit_approx = (backend in dispatch.backends_for("gram")
                       and dispatch.get(backend).approximate)
    # the feature-map backends only implement the order-1 discretisation
    # (BackendSpec.schemes): a non-default scheme keeps "auto" off the
    # approx frontier entirely; naming one explicitly is refused, with the
    # scheme-capability error rather than the opt-in one (the caller DID
    # opt in — the scheme is what rules the backend out)
    if explicit_approx and (features is not None
                            or error_budget is not None):
        dispatch.check_scheme(backend, g.scheme, op="gram")
    if feats is None and explicit_approx and error_budget is not None \
            and g.scheme == "order1":
        # explicit approx backend + a budget: take the measured frontier
        # rank when the cache is warm, the library default otherwise
        found = dispatch.resolve_approx(
            "gram", key_shape, X.dtype, error_budget=error_budget,
            ragged=ragged)
        rank = found[1] if found is not None and found[0] == backend \
            else ft.FeatureConfig.rank
        feats = ft.FeatureConfig(method=backend, rank=rank)
    if feats is None and backend == "auto" and error_budget is not None \
            and g.scheme == "order1":
        found = dispatch.resolve_approx(
            "gram", key_shape, X.dtype, error_budget=error_budget,
            ragged=ragged)
        if found is not None:
            feats = ft.FeatureConfig(method=found[0], rank=found[1])

    if feats is None and backend == "auto" and error_budget is not None \
            and g.scheme == "order1" and g.interior_dtype == "float32":
        # scheme frontier: a measured (scheme, coarsen, interior_dtype)
        # point meeting the budget may run the EXACT engine cheaper — an
        # order-2 stencil on a coarser grid, or bf16 interiors.  Only
        # consulted from the defaults: an explicit scheme/dtype choice is
        # never overridden.
        g, X, Y, Lx, Ly, key_shape = _apply_scheme_point(
            dispatch.resolve_scheme("gram", key_shape, X.dtype,
                                    error_budget=error_budget,
                                    ragged=ragged),
            g, X, Y, cfg, ragged, By)

    if feats is not None:
        backend = dispatch.resolve(feats.method, op="gram",
                                   allow_approximate=True, scheme=g.scheme)
    else:
        backend = dispatch.resolve(
            backend, op="gram", grid_cells=(Lx << g.lam1) * (Ly << g.lam2),
            shape=key_shape,
            dtype=X.dtype, allow_fused=kernel.lifts_increments,
            ragged=ragged, scheme=g.scheme)
    launch = dispatch.resolve_launch(launch, op="gram", shape=key_shape,
                                     dtype=X.dtype, ragged=ragged)
    return (X, Y, lengths, lengths_y, cfg, g, kernel, backend, symmetric,
            launch, feats)


def _apply_scheme_point(found, g, X, Y, cfg, ragged, By):
    """Apply a scheme-frontier point ``(scheme, coarsen, interior_dtype)``.

    ``coarsen`` halves the PDE grid ``coarsen`` times: via the dyadic
    refinement levels when both are deep enough (exactly what the tuner
    measured), else by stride-subsampling the raw paths (dense batches
    only — ragged lengths would shift, so the point is skipped there).
    Recomputes the transformed lengths and cache-key shape when anything
    changed.
    """
    if found is None:
        return g, X, Y, *_key_dims(X, Y, cfg, g, By)
    scheme_p, coarsen, idt = found
    if coarsen:
        if g.lam1 >= coarsen and g.lam2 >= coarsen:
            g = dataclasses.replace(g, lam1=g.lam1 - coarsen,
                                    lam2=g.lam2 - coarsen)
        elif not ragged and X.shape[1] > (1 << coarsen):
            step = 1 << coarsen
            X = X[:, ::step]
            Y = Y if Y is None else Y[:, ::step]
        else:
            return g, X, Y, *_key_dims(X, Y, cfg, g, By)
    g = dataclasses.replace(g, scheme=scheme_p, interior_dtype=idt)
    return g, X, Y, *_key_dims(X, Y, cfg, g, By)


def _key_dims(X, Y, cfg, g, By):
    """(Lx, Ly, key_shape) for the current paths/config — the per-op
    autotune cache-key shape documented in repro.bench.autotune.cache_key."""
    Lx = cfg.transformed_steps(X.shape[1])
    Ly = Lx if Y is None else cfg.transformed_steps(Y.shape[1])
    key_shape = (X.shape[0], By, Lx << g.lam1, Ly << g.lam2,
                 cfg.transformed_dim(X.shape[-1]))
    return Lx, Ly, key_shape


# ---------------------------------------------------------------------------
# approximate feature maps — phi(X) whose inner products ≈ the exact Gram
# ---------------------------------------------------------------------------

def _nystroem_maps(sX, sY, feats, kernel, backend, g, launch):
    """Nyström features from prepared streams: phi = K(·, Z) · L_w^{-T}.

    Landmarks Z are pivoted-Cholesky-selected from a ``pool``-sized random
    subset of X (the pool Gram costs pool² exact solves — B-independent);
    the per-path cost is one row of ``rank`` exact solves.  The selection
    indices are detached (``stop_gradient``); every gathered value stays
    differentiable.
    """
    Bx = sX.shape[0]
    pool = feats.pool_size(Bx)
    rank = min(feats.rank, pool)
    pool_idx = jax.random.permutation(feats.resolved_key(), Bx)[:pool]
    sP = sX[pool_idx]
    dispatch.record_pair_solves(
        pool * pool + Bx * rank + (0 if sY is None else sY.shape[0] * rank))
    G_pool = _gram_block(sP, sP, kernel, backend, g, launch)
    piv, _ = ft.pivoted_cholesky(G_pool, rank)
    sZ = sP[piv]
    Lw = ft.nystroem_factor(G_pool[piv][:, piv], feats.jitter)
    phiX = ft.nystroem_phi(
        _gram_rows(sX, sZ, kernel, backend, g, None, launch), Lw)
    if sY is None:
        return phiX, None
    phiY = ft.nystroem_phi(
        _gram_rows(sY, sZ, kernel, backend, g, None, launch), Lw)
    return phiX, phiY


def _feature_maps(X, Y, feats, cfg, g, kernel, lengths, lengths_y, launch):
    """phi(X), phi(Y) under ONE shared feature-map draw (phi(Y) is None
    when ``Y`` is) — sharing the draw is what makes ⟨phi(X), phi(Y)⟩ a
    kernel approximation rather than noise."""
    if feats.method == "rff":
        phiX = ft.rff_features(X, feats, cfg, kernel, lengths)
        phiY = None if Y is None else \
            ft.rff_features(Y, feats, cfg, kernel, lengths_y)
        return phiX, phiY
    # nystroem: the pool/cross Grams use the exact engine's auto backend
    exact = dispatch.resolve("auto", op="gram",
                             allow_fused=kernel.lifts_increments,
                             scheme=g.scheme)
    sX = _prepare(X, cfg, kernel, lengths)
    sY = None if Y is None else _prepare(Y, cfg, kernel, lengths_y)
    return _nystroem_maps(sX, sY, feats, kernel, exact, g, launch)


def sigkernel_gram(X: jax.Array, Y: Optional[jax.Array] = None, *,
                   backend: str = "auto", row_block: Optional[int] = None,
                   symmetric: Optional[bool] = None,
                   lengths=None, lengths_y=None,
                   transforms=None, grid=None, static_kernel=None,
                   launch=None, features=None, error_budget=None,
                   lam1=UNSET, lam2=UNSET,
                   time_aug=UNSET, lead_lag=UNSET,
                   use_pallas=UNSET, solver=UNSET) -> jax.Array:
    """Signature-kernel Gram matrix ``K[a, b] = k(X_a, Y_b)``.

    Args:
      X: (Bx, L, d) batch of paths.
      Y: (By, L', d) batch, or ``None`` for the symmetric Gram ``k(X_a, X_b)``
        (solves only the upper triangle — ≈2× fewer PDE solves; large
        batches are auto-chunked so the pair gather never exceeds a fixed
        HBM budget).
      lengths / lengths_y: optional (Bx,) / (By,) int arrays of per-path
        true point counts for ragged batches.  ``K[a, b]`` is then exactly
        ``k(X_a[:lengths[a]], Y_b[:lengths_y[b]])``: padding is masked into
        end-aligned streams whose zero Δ rows/columns leave the Goursat
        boundary bitwise intact, on every backend including the fused-Δ
        Pallas kernels (see docs/solver_guide.md).  Length axes are padded
        to power-of-two buckets so nearby sizes share one jit trace;
        ``lengths_y`` requires ``Y``.
      backend: a name from :mod:`repro.core.dispatch` ("reference" |
        "antidiag" | "pallas" | "pallas_fused") or ``"auto"`` (platform- and
        shape-aware; "pallas_fused" on TPU).  ``"pallas_fused"`` requires
        the linear static kernel (Δ is built from increments in VMEM).
      row_block: if set, at most ``row_block`` Gram rows (or the equivalent
        number of symmetric pairs) are in flight at once; ``Bx`` is
        zero-padded to the block granularity, padded rows are dropped.
      symmetric: force/forbid the symmetric fast path.  Default: ``Y is
        None``.  ``symmetric=True`` requires ``Y`` to be ``None`` or ``X``.
      transforms: a :class:`repro.TransformPipeline` (§4 transforms,
        applied on-the-fly; basepoint included).
      grid: a :class:`repro.GridConfig` — dyadic refinement of the PDE grid.
      static_kernel: the static-kernel lift (:class:`repro.Linear` default,
        :class:`repro.RBF` for the Gaussian lift via the Δ-from-gram path).
      launch: an optional :class:`repro.LaunchConfig` of launch-parameter
        overrides (PDE strip height, Gram ``row_block`` default, antidiag
        band chunking).  ``None`` fields fall back to the autotuned winner
        for this shape bucket (if a tuned cache is warm) and then to the
        library defaults; an explicit ``row_block=`` argument beats
        ``launch.gram_row_block``.  Launch parameters never change the
        math — see docs/benchmarks.md § Launch-parameter tuning.
      features: a :class:`repro.FeatureConfig` activating an *approximate*
        feature-map backend (``"rff"`` / ``"nystroem"``): the result is
        ``phi(X) @ phi(Y).T ≈ K`` with no B×B PDE solve grid — O(B·rank)
        work, differentiable by plain autodiff through the feature maps,
        deterministic given the config's ``key`` leaf.  See
        docs/api/public.md § Approximate kernels.
      error_budget: a relative-error budget allowing ``backend="auto"`` to
        *legally* pick an approximation: used only when the autotune cache
        holds a measured accuracy-vs-speed frontier point for this shape
        bucket meeting the budget (the bench suite's ``approx_frontier``
        workload records them); otherwise the exact engine runs.  Without
        ``features=``/``error_budget=``, approximate backends are refused
        even when named explicitly.
      lam1 / lam2 / time_aug / lead_lag: deprecated aliases for ``grid=`` /
        ``transforms=`` (DeprecationWarning once per call-site).
      use_pallas / solver: deprecated aliases (DeprecationWarning) mapped to
        backend names — see docs/solver_guide.md.

    Returns:
      (Bx, By) Gram matrix (f32), differentiable end-to-end through the
      exact one-pass backward on every backend.

    See also :func:`sigkernel_gram_sharded` (the same Gram tiled over a
    device mesh) and :func:`sigkernel_gram_reduce` (streaming ``ΣK``
    without materialising K — what ``mmd2(streaming=True)`` uses).
    """
    (X, Y, lengths, lengths_y, cfg, g, kernel, backend, symmetric, launch,
     feats) = \
        _resolve_engine(X, Y, symmetric, lengths, lengths_y, transforms,
                        grid, static_kernel, lam1, lam2, time_aug, lead_lag,
                        use_pallas, solver, backend, launch,
                        features=features, error_budget=error_budget)
    if row_block is None:  # explicit arg beats the launch knob
        row_block = launch.gram_row_block

    if feats is not None:
        phiX, phiY = _feature_maps(X, Y, feats, cfg, g, kernel, lengths,
                                   lengths_y, launch)
        K = phiX @ (phiX if phiY is None else phiY).T
        return shard(K, "batch", "model")

    sX = _prepare(X, cfg, kernel, lengths)
    sX = shard(sX, "batch", None, None)
    Bx = sX.shape[0]

    if symmetric:
        return _symmetric_gram(sX, kernel, backend, row_block, g, launch)

    sY = _prepare(Y, cfg, kernel, lengths_y)
    sY = shard(sY, "model", None, None)
    By = sY.shape[0]

    if row_block is None:
        dispatch.record_pair_solves(Bx * By)
    else:
        n_blocks = (Bx + (-Bx) % row_block) // row_block
        dispatch.record_pair_solves(n_blocks * row_block * By)
    K = _gram_rows(sX, sY, kernel, backend, g, row_block, launch)
    return shard(K, "batch", "model")


# the pair-gather replicates increments (2·chunk·L·d floats live at once);
# above this budget an unset row_block is auto-chunked so the symmetric fast
# path never costs more HBM than the dense Gram it replaces
_SYM_GATHER_BUDGET = 64 * 1024 * 1024


def _auto_row_block(other: int, L: int, d: int) -> int:
    """Row block bounding one block's replicated-stream bytes by the
    gather budget: ``row_block`` rows against ``other`` columns."""
    return max(1, _SYM_GATHER_BUDGET // (8 * max(1, other) * L * d))


def _symmetric_gram(sX: jax.Array, kernel, backend: str,
                    row_block: Optional[int], g, launch=None) -> jax.Array:
    """Upper-triangle pair solve + mirror: Bx·(Bx+1)/2 (+ pad) PDE solves."""
    Bx = sX.shape[0]
    a_idx, b_idx = np.triu_indices(Bx)
    n_pairs = a_idx.size

    if row_block is None and 8 * n_pairs * sX.shape[1] * sX.shape[2] \
            > _SYM_GATHER_BUDGET:
        row_block = _auto_row_block(Bx, sX.shape[1], sX.shape[2])

    if row_block is None:
        dispatch.record_pair_solves(n_pairs)
        k = _solve_pairs(sX[a_idx], sX[b_idx], kernel, backend, g, launch)
    else:
        # a block of `row_block` Gram rows ~ row_block·Bx pairs of live Δ
        chunk = max(1, int(row_block)) * Bx
        dispatch.record_pair_solves(n_pairs + (-n_pairs) % chunk)
        k = _solve_pairs_chunked(sX, a_idx, b_idx, kernel, backend, g,
                                 chunk, launch)

    K = jnp.zeros((Bx, Bx), k.dtype).at[a_idx, b_idx].set(k)
    K = K + jnp.triu(K, k=1).T
    return shard(K, "batch", "model")


# ---------------------------------------------------------------------------
# streaming reductions — ΣK without materialising K (mmd2 / scoring_rule)
# ---------------------------------------------------------------------------

class StreamingViolation(RuntimeError):
    """A reduction that was requested to stream materialises the full Gram
    (or the full pairwise Δ stack) as an intermediate."""


def _walk_jaxpr_avals(jaxpr, visit) -> None:
    """Visit the aval of every intermediate in ``jaxpr``, recursing into
    sub-jaxprs (scan/map bodies, custom-vjp branches, pjit calls...)."""
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                visit(aval)
        stack = list(eqn.params.values())
        while stack:
            obj = stack.pop()
            if hasattr(obj, "eqns"):            # a Jaxpr
                _walk_jaxpr_avals(obj, visit)
            elif hasattr(obj, "jaxpr"):         # a ClosedJaxpr
                stack.append(obj.jaxpr)
            elif isinstance(obj, (list, tuple)):
                stack.extend(obj)


def assert_streaming_reduction(fn, *args, gram_shape,
                               what: str = "reduction") -> None:
    """Abstractly trace ``fn(*args)`` and raise :class:`StreamingViolation`
    if any intermediate materialises an array with leading dims
    ``gram_shape = (Bx, By)``.

    This is an ``eval_shape``-grade check: ``fn`` is traced with abstract
    values only (``args`` may be arrays or ``jax.ShapeDtypeStruct``), no
    FLOPs run, and every intermediate of the resulting jaxpr — including
    scan/map bodies and custom-VJP branches — is shape-checked.  Pass
    ``jax.value_and_grad(fn)`` to cover the VJP as well; ``mmd2`` /
    ``scoring_rule`` do exactly that when ``streaming=`` is on.

    The check keys on the *leading-dims* fingerprint of the dense engine:
    the full Gram is ``(Bx, By)`` and the dense pairwise Δ stack is
    ``(Bx, By, Lx, Ly)``, so both are caught by one prefix test.  Pick
    ``Bx != By`` and batch sizes distinct from L/d in tests to avoid
    shape-coincidence false positives (the internal guard behind
    ``mmd2(streaming=True)`` de-aliases them automatically by re-tracing
    with bumped batch sizes — genuine dense intermediates track the batch
    dims, coincidences like a ragged pad width equal to ``Bx`` do not).
    """
    offending = _dense_intermediates(fn, *args, gram_shape=gram_shape)
    if offending:
        bx, by = gram_shape
        raise StreamingViolation(
            f"streaming {what} materialises dense ({bx}, {by}) "
            f"intermediates: {sorted(set(offending))} — the full Gram "
            "(or pairwise Δ stack) must never exist; lower row_block or "
            "report a bug in repro.core.gram")


def _dense_intermediates(fn, *args, gram_shape) -> list:
    """Shapes of every intermediate of the abstract trace of ``fn(*args)``
    whose leading dims equal ``gram_shape``."""
    bx, by = gram_shape
    closed = jax.make_jaxpr(fn)(*args)
    offending = []

    def visit(aval):
        if len(aval.shape) >= 2 and aval.shape[0] == bx \
                and aval.shape[1] == by:
            offending.append(tuple(aval.shape))

    _walk_jaxpr_avals(closed.jaxpr, visit)
    return offending


#: (shape/config) keys whose streaming reduction already passed the guard
_stream_checked: set = set()


def _reduce_guard_key(args) -> Optional[tuple]:
    try:
        hash(args)
        return args
    except TypeError:
        return None  # unhashable config leaf (e.g. traced sigma): recheck


def sigkernel_gram_reduce(X: jax.Array, Y: Optional[jax.Array] = None, *,
                          include_diag: bool = True,
                          backend: str = "auto",
                          row_block: Optional[int] = None,
                          symmetric: Optional[bool] = None,
                          lengths=None, lengths_y=None,
                          transforms=None, grid=None, static_kernel=None,
                          launch=None, features=None, error_budget=None,
                          lam1=UNSET, lam2=UNSET,
                          time_aug=UNSET, lead_lag=UNSET,
                          use_pallas=UNSET, solver=UNSET,
                          check_streaming: bool = False) -> jax.Array:
    """Streaming ``Σ_{a,b} K[a, b]`` — the Gram-sum without the Gram.

    The workhorse of ``mmd2(streaming=True)`` / ``scoring_rule``:
    accumulates per-row-block (asymmetric) or per-pair-chunk (symmetric)
    partial sums under ``jax.checkpoint``, so at most one block of PDE
    solves is live at a time in the forward AND the backward — the VJP
    rematerialises each block instead of stacking residuals.  The full
    (Bx, By) Gram, and the (Bx, By, Lx, Ly) pairwise Δ stack, never exist.

    Args (beyond :func:`sigkernel_gram`'s):
      include_diag: symmetric reductions only — ``False`` drops the
        ``k(x_a, x_a)`` diagonal (the ``Σ − tr`` of the unbiased MMD) at
        zero extra solves (off-diagonal pairs enter with weight 2, the
        diagonal with weight 0).
      row_block: streaming granularity — at most ``row_block`` Gram rows
        (or ``row_block · Bx`` symmetric pairs) in flight.  Default: the
        largest block that fits the engine's pair-gather budget (for small
        problems that is one block, i.e. dense-equivalent).
      features / error_budget: activate an approximate feature-map backend
        exactly as in :func:`sigkernel_gram`.  The reduction then becomes
        pure feature algebra — ``ΣK = ⟨Σ_a phi(X)_a, Σ_b phi(Y)_b⟩`` and
        the diag-dropped symmetric sum ``‖Σ phi‖² − Σ_a ‖phi_a‖²`` — so
        peak memory is O(B·rank) with no row blocking needed, in the value
        and the grad (the streaming-shape guard covers this path too).
      check_streaming: run :func:`assert_streaming_reduction` on this
        reduction (value + grad) once per shape/config key before
        executing — the guard ``mmd2``/``scoring_rule`` enable whenever a
        streaming path is requested.  Skipped when one block covers the
        whole batch (streaming degenerates to dense by construction) —
        except on the feature path, which is checked whenever requested.

    Returns a scalar (f32), differentiable with the same exact one-pass
    backward as the Gram itself.
    """
    if not include_diag and not (symmetric or
                                 (symmetric is None and Y is None)):
        raise ValueError("include_diag=False requires the symmetric "
                         "reduction (Y=None)")
    # capture pre-padding abstract args for the guard: the re-entrant
    # closure below replays the padding itself
    guard_args = (X, Y, lengths, lengths_y)
    (X, Y, lengths, lengths_y, cfg, g, kernel, backend, symmetric, launch,
     feats) = \
        _resolve_engine(X, Y, symmetric, lengths, lengths_y, transforms,
                        grid, static_kernel, lam1, lam2, time_aug, lead_lag,
                        use_pallas, solver, backend, launch,
                        features=features, error_budget=error_budget)
    if row_block is None:  # explicit arg beats the launch knob
        row_block = launch.gram_row_block

    if feats is not None:
        if check_streaming:
            _guard_reduce(guard_args, include_diag=include_diag,
                          backend=backend,
                          row_block=1 if row_block is None else row_block,
                          symmetric=symmetric, transforms=cfg, grid=g,
                          static_kernel=kernel, launch=launch,
                          features=feats)
        phiX, phiY = _feature_maps(X, Y if not symmetric else None, feats,
                                   cfg, g, kernel, lengths, lengths_y,
                                   launch)
        if symmetric:
            s = phiX.sum(axis=0)
            total = s @ s
            if not include_diag:  # ΣK − tr(K), in feature space
                total = total - (phiX * phiX).sum()
            return total
        return phiX.sum(axis=0) @ phiY.sum(axis=0)

    sX = _prepare(X, cfg, kernel, lengths)
    Bx, L, d = sX.shape

    if symmetric:
        rb = row_block if row_block is not None else _auto_row_block(Bx, L, d)
        streams = rb * Bx < Bx * (Bx + 1) // 2
    else:
        By = Y.shape[0]
        rb = row_block if row_block is not None else _auto_row_block(By, L, d)
        streams = rb < Bx

    if check_streaming and streams:
        _guard_reduce(guard_args, include_diag=include_diag,
                      backend=backend, row_block=rb, symmetric=symmetric,
                      transforms=cfg, grid=g, static_kernel=kernel,
                      launch=launch)

    if symmetric:
        return _reduce_symmetric(sX, kernel, backend, rb, g, include_diag,
                                 launch)
    sY = _prepare(Y, cfg, kernel, lengths_y)
    return _reduce_rows(sX, sY, kernel, backend, rb, g, launch)


def _guard_reduce(guard_args, **kw) -> None:
    """Run the streaming-shape guard (value + grad) once per key.

    An abstract trace at the real batch sizes first, and — only if that
    finds a ``(Bx, By)``-shaped intermediate — confirmation traces with
    the batch dims AND ``row_block`` bumped (by one and by two).  A
    genuine dense Gram/Δ intermediate tracks the batch dims and is
    ``row_block``-independent, so it survives every bump.  Shape
    coincidences involve a size that does not track both bumped batch
    dims: static sizes (a ragged pad width equal to ``Bx``, a PDE grid
    dim equal to ``By``) cannot match the batch at two different bumps,
    and block-derived sizes (the symmetric pair chunk ``row_block · Bx``,
    the per-block row count) are pushed off the batch diagonal by the
    ``row_block`` bump — so both classes are cleared as false positives.
    """
    X, Y, lengths, lengths_y = guard_args
    names = [n for n, a in (("lengths", lengths), ("lengths_y", lengths_y))
             if a is not None]
    lens = [jnp.asarray(a) for a in (lengths, lengths_y) if a is not None]
    key = _reduce_guard_key((
        X.shape, str(X.dtype), None if Y is None else (Y.shape, str(Y.dtype)),
        tuple((a.shape, str(a.dtype)) for a in lens), tuple(names),
        tuple(sorted((k, repr(v)) for k, v in kw.items()))))
    if key is not None and key in _stream_checked:
        return
    n_arr = 1 if Y is None else 2
    diff = tuple(range(n_arr))

    def trace(bump):
        kwb = dict(kw, row_block=kw["row_block"] + bump)

        def red(*args):
            arrs, ls = args[:n_arr], args[n_arr:]
            return sigkernel_gram_reduce(*arrs, check_streaming=False,
                                         **dict(zip(names, ls)), **kwb)

        def s(a):
            return jax.ShapeDtypeStruct((a.shape[0] + bump,)
                                        + tuple(a.shape[1:]), a.dtype)
        args = [s(X)] + ([] if Y is None else [s(Y)]) + [s(a) for a in lens]
        bx = X.shape[0] + bump
        by = bx if Y is None else Y.shape[0] + bump
        return _dense_intermediates(
            jax.value_and_grad(red, argnums=diff), *args,
            gram_shape=(bx, by)), (bx, by)

    offending, (bx, by) = trace(0)
    if offending:
        if trace(1)[0] and trace(2)[0]:
            raise StreamingViolation(
                f"streaming Gram reduction materialises dense ({bx}, {by}) "
                f"intermediates: {sorted(set(offending))} — the full Gram "
                "(or pairwise Δ stack) must never exist; lower row_block "
                "or report a bug in repro.core.gram")
    if key is not None:
        _stream_checked.add(key)


def _reduce_symmetric(sX: jax.Array, kernel, backend: str, row_block: int,
                      g, include_diag: bool, launch=None) -> jax.Array:
    """Σ over the symmetric Gram via the upper triangle: off-diagonal
    pairs weighted 2, diagonal 1 (or 0), padding 0."""
    Bx = sX.shape[0]
    a_idx, b_idx = np.triu_indices(Bx)
    w = np.where(a_idx == b_idx, 1.0 if include_diag else 0.0, 2.0)
    n_pairs = a_idx.size
    chunk = max(1, int(row_block)) * Bx
    if chunk == Bx:
        # keep the per-chunk solver's (chunk, ...) intermediates off the
        # (Bx, Bx) fingerprint the streaming-shape guard scans for
        chunk = Bx + 1
    if chunk >= n_pairs:
        dispatch.record_pair_solves(n_pairs)
        k = _solve_pairs(sX[a_idx], sX[b_idx], kernel, backend, g, launch)
        return (jnp.asarray(w, k.dtype) * k).sum()
    pad = (-n_pairs) % chunk
    dispatch.record_pair_solves(n_pairs + pad)
    a = np.concatenate([a_idx, np.zeros(pad, a_idx.dtype)])
    b = np.concatenate([b_idx, np.zeros(pad, b_idx.dtype)])
    wts = np.concatenate([w, np.zeros(pad, w.dtype)])
    a_c = jnp.asarray(a).reshape(-1, chunk)
    b_c = jnp.asarray(b).reshape(-1, chunk)
    w_c = jnp.asarray(wts, sX.dtype).reshape(-1, chunk)

    def block(abw):
        ai, bi, wi = abw
        k = _solve_pairs(sX[ai], sX[bi], kernel, backend, g, launch)
        return (wi * k).sum()

    # checkpoint: lax.map would otherwise stack every block's Δ/grid
    # residuals — the backward rematerialises them one block at a time
    parts = jax.lax.map(jax.checkpoint(block), (a_c, b_c, w_c))
    return parts.sum()


def _reduce_rows(sX: jax.Array, sY: jax.Array, kernel, backend: str,
                 row_block: int, g, launch=None) -> jax.Array:
    """Σ over the (Bx, By) Gram, ``row_block`` rows at a time."""
    Bx, By = sX.shape[0], sY.shape[0]
    rb = max(1, int(row_block))
    if rb == 1 and By == 1:
        # (n_blocks, rb) = (Bx, 1) stacked blocks would alias the (Bx, 1)
        # Gram fingerprint the streaming-shape guard scans for
        rb = 2
    if rb >= Bx:
        dispatch.record_pair_solves(Bx * By)
        return _gram_block(sX, sY, kernel, backend, g, launch).sum()
    pad = (-Bx) % rb
    n_blocks = (Bx + pad) // rb
    dispatch.record_pair_solves(n_blocks * rb * By)
    if pad:
        sX = jnp.pad(sX, ((0, pad), (0, 0), (0, 0)))
    sXb = sX.reshape(n_blocks, rb, *sX.shape[1:])
    # padded rows give k = 1 (zero increments), NOT 0 — mask them out
    valid = (jnp.arange(n_blocks * rb).reshape(n_blocks, rb) < Bx)

    def block(args):
        sxb, v = args
        Kb = _gram_block(sxb, sY, kernel, backend, g, launch)
        return jnp.where(v[:, None], Kb, 0.0).sum()

    parts = jax.lax.map(jax.checkpoint(block), (sXb, valid))
    return parts.sum()


# ---------------------------------------------------------------------------
# sharded Gram — the (Bx, By) tile grid over a real device mesh
# ---------------------------------------------------------------------------

def sigkernel_gram_sharded(X: jax.Array, Y: Optional[jax.Array] = None, *,
                           mesh=None, row_axis: str = "data",
                           col_axis: str = "model", tile: int = 8,
                           backend: str = "auto",
                           row_block: Optional[int] = None,
                           symmetric: Optional[bool] = None,
                           lengths=None, lengths_y=None,
                           transforms=None, grid=None,
                           static_kernel=None, launch=None,
                           features=None, error_budget=None) -> jax.Array:
    """:func:`sigkernel_gram` tiled over a device mesh via ``shard_map``.

    The (Bx, By) Gram tile grid is 2-D **block-cyclic** sharded: row tiles
    of ``tile`` paths dealt round-robin over ``mesh[row_axis]``, column
    tiles over ``mesh[col_axis]``.  Each device solves its tiles' Goursat
    problems entirely locally from replicated prepared streams — no
    collectives cross the PDE solves; only the output concatenation (and
    whatever reduction the caller applies) is cross-device.

    The symmetric fast path is preserved *globally*: when ``Y`` is
    omitted, the ``Bx·(Bx+1)/2`` upper-triangle pairs are dealt
    round-robin over **all** ``mesh[row_axis]·mesh[col_axis]`` devices (the
    cyclic deal is what keeps the triangular tile grid load-balanced — a
    contiguous split would give the last device ~2× the solves of the
    first), solved locally, and mirrored once on the way out.  Total PDE
    solves stay at the triangle count (+ round-up padding), exactly as on
    one device.

    Args (beyond the single-device engine's):
      mesh: a :class:`jax.sharding.Mesh` with ``row_axis`` and ``col_axis``
        axes.  Default: :func:`repro.launch.mesh.make_gram_mesh` over every
        local device (a near-square ``(data, model)`` factorisation).
      tile: block-cyclic tile granularity (rows and columns).
      row_block: per-device sub-chunking — at most ``row_block`` local Gram
        rows (or ``row_block · Bx`` symmetric pairs) in flight per device.

    Ragged batches (``lengths=``) work unchanged: masking is burnt into the
    end-aligned prepared streams *before* the tiles are dealt, so the
    sharded tiling is ragged-oblivious.  Values match the single-device
    engine to reduction-order tolerance (bitwise for the pair solves
    themselves — only concatenation order differs).

    On a 1-device mesh this degenerates to the single-device engine.
    Prove it on a simulated mesh with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (see
    docs/api/public.md § Distributed & streaming Grams and
    ``examples/gram_matrix_distributed.py``).

    ``features=`` / ``error_budget=`` compose here too: with an
    approximation active there is no per-pair solve grid to tile, so the
    feature maps are computed once and the (Bx, By) result is the sharded
    matmul ``phi(X) @ phi(Y).T`` — rows annotated to the ``"batch"`` axis,
    columns to ``"model"``, partitioned by XLA under the active mesh.
    """
    (X, Y, lengths, lengths_y, cfg, g, kernel, backend, symmetric, launch,
     feats) = \
        _resolve_engine(X, Y, symmetric, lengths, lengths_y, transforms,
                        grid, static_kernel, UNSET, UNSET, UNSET, UNSET,
                        UNSET, UNSET, backend, launch,
                        features=features, error_budget=error_budget)
    if feats is not None:
        phiX, phiY = _feature_maps(X, Y, feats, cfg, g, kernel, lengths,
                                   lengths_y, launch)
        phiX = shard(phiX, "batch", None)
        K = phiX @ (phiX if phiY is None else shard(phiY, "model", None)).T
        return shard(K, "batch", "model")
    if row_block is None:  # explicit arg beats the launch knob
        row_block = launch.gram_row_block
    if mesh is None:
        from repro.launch.mesh import make_gram_mesh
        mesh = make_gram_mesh()
    for ax in (row_axis, col_axis):
        if ax not in mesh.shape:
            raise ValueError(
                f"mesh has no {ax!r} axis (axes: {tuple(mesh.shape)}); "
                "pass row_axis=/col_axis= matching your mesh")
    shard_map = get_shard_map()
    nd, nm = mesh.shape[row_axis], mesh.shape[col_axis]

    sX = _prepare(X, cfg, kernel, lengths)
    Bx = sX.shape[0]

    if symmetric:
        D = nd * nm
        a_idx, b_idx = np.triu_indices(Bx)
        n_pairs = a_idx.size
        pad = (-n_pairs) % D
        a_pad = np.concatenate([a_idx, np.zeros(pad, a_idx.dtype)])
        b_pad = np.concatenate([b_idx, np.zeros(pad, b_idx.dtype)])
        n_loc = (n_pairs + pad) // D
        # round-robin deal: device r solves global pairs r, r+D, r+2D, ...
        a_dev = jnp.asarray(a_pad.reshape(n_loc, D).T.copy())   # (D, n_loc)
        b_dev = jnp.asarray(b_pad.reshape(n_loc, D).T.copy())
        dispatch.record_pair_solves(n_pairs + pad)
        chunk = None if row_block is None else max(1, int(row_block)) * Bx

        def local(a_loc, b_loc, sx):
            k = _solve_pairs_chunked(sx, a_loc[0], b_loc[0], kernel,
                                     backend, g, chunk, launch)
            return k[None]

        k_dev = shard_map(
            local, mesh=mesh,
            in_specs=(P((row_axis, col_axis)), P((row_axis, col_axis)),
                      P()),
            out_specs=P((row_axis, col_axis)))(a_dev, b_dev, sX)
        # undo the deal: global pair t·D + r sits at device r, slot t
        k = k_dev.reshape(D, n_loc).T.reshape(-1)[:n_pairs]
        K = jnp.zeros((Bx, Bx), k.dtype).at[a_idx, b_idx].set(k)
        K = K + jnp.triu(K, k=1).T
        return shard(K, "batch", "model")

    sY = _prepare(Y, cfg, kernel, lengths_y)
    By = sY.shape[0]

    def _deal(s, n_shards):
        """Pad + block-cyclic permute dim 0; returns (dealt, inv_perm)."""
        B = s.shape[0]
        t = max(1, min(int(tile), -(-B // n_shards)))
        n_blocks = -(-B // t)
        n_blocks += (-n_blocks) % n_shards
        padded = n_blocks * t
        if padded > B:  # zero rows -> k = 1 tiles, sliced off at the end
            s = jnp.pad(s, ((0, padded - B),) + ((0, 0),) * (s.ndim - 1))
        perm, inv = block_cyclic_perm(padded, n_shards, t)
        return s[jnp.asarray(perm)], inv

    sXp, invR = _deal(sX, nd)
    sYp, invC = _deal(sY, nm)
    dispatch.record_pair_solves(sXp.shape[0] * sYp.shape[0])

    def local(sx, sy):
        return _gram_rows(sx, sy, kernel, backend, g, row_block, launch)

    Kp = shard_map(local, mesh=mesh,
                   in_specs=(P(row_axis), P(col_axis)),
                   out_specs=P(row_axis, col_axis))(sXp, sYp)
    K = Kp[jnp.asarray(invR)][:, jnp.asarray(invC)][:Bx, :By]
    return shard(K, "batch", "model")
