"""The Gram engine: one entry point for every sig-kernel Gram variant.

``sigkernel_gram(X, Y=None, ...)`` unifies what used to be three separate
code paths (dense einsum, row-blocked ``lax.map``, fused-Δ Pallas) behind the
backend registry in :mod:`repro.core.dispatch`:

* **dense** — all ``Bx·By`` Δ matrices materialised at once (small batches);
* **blocked** — ``row_block`` Gram rows live at a time; ``Bx`` is
  zero-padded to the block granularity (zero increments ⇒ k = 1 rows that
  are dropped, so padding is exact — same trick the PDE kernels use for
  strips);
* **fused** (``backend="pallas_fused"``) — Δ is built in VMEM from the
  increments and never exists in HBM, now differentiable end-to-end via the
  checkpointed exact backward;
* **symmetric fast path** — when ``Y`` is omitted only the
  ``Bx·(Bx+1)/2`` upper-triangle pairs are solved (≈2× fewer PDE solves for
  the ``Kxx``/``Kyy`` terms of every loss) and the result is mirrored.

Row blocks and the Gram tiling are annotated with the logical mesh axes of
:mod:`repro.parallel.api` (rows → ``"batch"``, columns → ``"model"``), so
under a mesh + ``logical_rules`` context a pod-scale Gram is one call; with
no mesh the annotations are no-ops and the same code runs on a laptop.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch
from . import transforms as tf
from .config import (_maybe_scale as _scale, delta_from_gram,
                     resolve_kernel_configs)
from .dispatch import UNSET
from .sigkernel import _sigkernel_from_delta
from repro.parallel.api import shard


def _prepare(paths: jax.Array, cfg, kernel, lengths=None) -> jax.Array:
    """Per-path stream the pair solvers consume: transformed *increments*
    for increment-lifting (linear) kernels, transformed *points* for
    everything else (the Δ-from-Gram path needs actual points).

    Either way zero-padding rows with zeros is exact: zero increments and
    all-zero point rows both give Δ = 0 ⇒ k = 1 rows, which are dropped.

    With ``lengths=`` (ragged batches) the streams come back *end-aligned*:
    each path's padding turns into exactly-zero leading Δ rows/columns for
    any pairing, which leaves the Goursat boundary of ones bitwise intact —
    so everything downstream of this function (pair gathers, row blocks,
    the fused kernels, the symmetric fast path) is ragged-oblivious.
    """
    if kernel.lifts_increments:
        return tf.pipeline_increments(paths, cfg, lengths, align="end")
    return tf.transform_path(paths, cfg, lengths, align="end")


def _pair_delta(sa: jax.Array, sb: jax.Array, kernel) -> jax.Array:
    """Δ for batches of prepared streams (leading dims broadcast)."""
    if kernel.lifts_increments:
        return kernel.delta_from_increments(sa, sb)
    return delta_from_gram(kernel.gram(sa, sb))


def _solve_pairs(sa: jax.Array, sb: jax.Array, kernel, backend: str,
                 lam1: int, lam2: int) -> jax.Array:
    """Solve one batch of prepared pairs (P, ·, d) × (P, ·, d) -> (P,)."""
    if backend == "pallas_fused":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        # fused kernels compute ⟨dx, dy⟩ in VMEM; fold a non-unit linear
        # scale into one side (scale·⟨dx, dy⟩ = ⟨scale·dx, dy⟩ exactly)
        return pde_ops.solve_fused(_scale(sa, kernel.scale), sb, lam1, lam2)
    return _sigkernel_from_delta(_pair_delta(sa, sb, kernel), lam1, lam2,
                                 backend)


def _gram_block(sxb: jax.Array, sY: jax.Array, kernel, backend: str,
                lam1: int, lam2: int) -> jax.Array:
    """Gram block from prepared streams (r, ·, d) × (By, ·, d) -> (r, By)."""
    if backend == "pallas_fused":
        from repro.kernels.sigkernel_pde import ops as pde_ops
        return pde_ops.gram_fused(_scale(sxb, kernel.scale), sY, lam1, lam2)
    delta = _pair_delta(sxb[:, None], sY[None, :], kernel)
    return _sigkernel_from_delta(delta, lam1, lam2, backend)


def sigkernel_gram(X: jax.Array, Y: Optional[jax.Array] = None, *,
                   backend: str = "auto", row_block: Optional[int] = None,
                   symmetric: Optional[bool] = None,
                   lengths=None, lengths_y=None,
                   transforms=None, grid=None, static_kernel=None,
                   lam1=UNSET, lam2=UNSET,
                   time_aug=UNSET, lead_lag=UNSET,
                   use_pallas=UNSET, solver=UNSET) -> jax.Array:
    """Signature-kernel Gram matrix ``K[a, b] = k(X_a, Y_b)``.

    Args:
      X: (Bx, L, d) batch of paths.
      Y: (By, L', d) batch, or ``None`` for the symmetric Gram ``k(X_a, X_b)``
        (solves only the upper triangle — ≈2× fewer PDE solves; large
        batches are auto-chunked so the pair gather never exceeds a fixed
        HBM budget).
      lengths / lengths_y: optional (Bx,) / (By,) int arrays of per-path
        true point counts for ragged batches.  ``K[a, b]`` is then exactly
        ``k(X_a[:lengths[a]], Y_b[:lengths_y[b]])``: padding is masked into
        end-aligned streams whose zero Δ rows/columns leave the Goursat
        boundary bitwise intact, on every backend including the fused-Δ
        Pallas kernels (see docs/solver_guide.md).  Length axes are padded
        to power-of-two buckets so nearby sizes share one jit trace;
        ``lengths_y`` requires ``Y``.
      backend: a name from :mod:`repro.core.dispatch` ("reference" |
        "antidiag" | "pallas" | "pallas_fused") or ``"auto"`` (platform- and
        shape-aware; "pallas_fused" on TPU).  ``"pallas_fused"`` requires
        the linear static kernel (Δ is built from increments in VMEM).
      row_block: if set, at most ``row_block`` Gram rows (or the equivalent
        number of symmetric pairs) are in flight at once; ``Bx`` is
        zero-padded to the block granularity, padded rows are dropped.
      symmetric: force/forbid the symmetric fast path.  Default: ``Y is
        None``.  ``symmetric=True`` requires ``Y`` to be ``None`` or ``X``.
      transforms: a :class:`repro.TransformPipeline` (§4 transforms,
        applied on-the-fly; basepoint included).
      grid: a :class:`repro.GridConfig` — dyadic refinement of the PDE grid.
      static_kernel: the static-kernel lift (:class:`repro.Linear` default,
        :class:`repro.RBF` for the Gaussian lift via the Δ-from-Gram path).
      lam1 / lam2 / time_aug / lead_lag: deprecated aliases for ``grid=`` /
        ``transforms=`` (DeprecationWarning once per call-site).
      use_pallas / solver: deprecated aliases (DeprecationWarning) mapped to
        backend names — see docs/solver_guide.md.

    Returns:
      (Bx, By) Gram matrix (f32), differentiable end-to-end through the
      exact one-pass backward on every backend.
    """
    if X.ndim != 3 or (Y is not None and Y.ndim != 3):
        raise ValueError(
            f"sigkernel_gram expects (B, L, d) paths, got X {X.shape}"
            + ("" if Y is None else f", Y {Y.shape}"))
    if symmetric is None:
        symmetric = Y is None
    if symmetric and not (Y is None or Y is X):
        raise ValueError("symmetric=True requires Y to be None or X itself")
    if not symmetric and Y is None:
        raise ValueError("symmetric=False requires Y (pass Y=X for the "
                         "full symmetric Gram without the fast path)")
    if lengths_y is not None and Y is None:
        raise ValueError("lengths_y= requires Y; for the symmetric Gram "
                         "pass lengths= (it applies to both sides)")

    cfg, g, kernel = resolve_kernel_configs(
        transforms, grid, static_kernel, time_aug=time_aug,
        lead_lag=lead_lag, lam1=lam1, lam2=lam2)
    lam1, lam2 = g.lam1, g.lam2
    if lengths is not None:
        X, lengths = tf.pad_ragged(X, lengths)
    if lengths_y is not None:
        Y, lengths_y = tf.pad_ragged(Y, lengths_y)
    ragged = lengths is not None or lengths_y is not None
    backend = dispatch.canonicalize(backend, op="gram",
                                    use_pallas=use_pallas, solver=solver)
    if backend == "pallas_fused" and not kernel.lifts_increments:
        raise ValueError(
            "backend='pallas_fused' builds Δ from increments in VMEM and "
            f"only supports the linear lift, got "
            f"static_kernel={type(kernel).__name__}; pass backend='auto'")
    Lx = cfg.transformed_steps(X.shape[1])
    Ly = Lx if Y is None else cfg.transformed_steps(Y.shape[1])
    By = X.shape[0] if Y is None else Y.shape[0]
    backend = dispatch.resolve(
        backend, op="gram", grid_cells=(Lx << lam1) * (Ly << lam2),
        shape=(X.shape[0], By, Lx << lam1, Ly << lam2,
               cfg.transformed_dim(X.shape[-1])),
        dtype=X.dtype, allow_fused=kernel.lifts_increments, ragged=ragged)

    sX = _prepare(X, cfg, kernel, lengths)
    sX = shard(sX, "batch", None, None)
    Bx = sX.shape[0]

    if symmetric:
        return _symmetric_gram(sX, kernel, backend, row_block, lam1, lam2)

    sY = _prepare(Y, cfg, kernel, lengths_y)
    sY = shard(sY, "model", None, None)
    By = sY.shape[0]

    if row_block is None:
        dispatch.record_pair_solves(Bx * By)
        K = _gram_block(sX, sY, kernel, backend, lam1, lam2)
    else:
        pad = (-Bx) % row_block
        if pad:  # zero rows -> Δ = 0 -> k = 1 rows, dropped below: exact
            sX = jnp.pad(sX, ((0, pad), (0, 0), (0, 0)))
        n_blocks = (Bx + pad) // row_block
        dispatch.record_pair_solves(n_blocks * row_block * By)
        sXb = sX.reshape(n_blocks, row_block, *sX.shape[1:])
        K = jax.lax.map(
            lambda sxb: _gram_block(sxb, sY, kernel, backend, lam1, lam2),
            sXb)
        K = K.reshape(n_blocks * row_block, By)[:Bx]
    return shard(K, "batch", "model")


# the pair-gather replicates increments (2·chunk·L·d floats live at once);
# above this budget an unset row_block is auto-chunked so the symmetric fast
# path never costs more HBM than the dense Gram it replaces
_SYM_GATHER_BUDGET = 64 * 1024 * 1024


def _symmetric_gram(sX: jax.Array, kernel, backend: str,
                    row_block: Optional[int],
                    lam1: int, lam2: int) -> jax.Array:
    """Upper-triangle pair solve + mirror: Bx·(Bx+1)/2 (+ pad) PDE solves."""
    Bx = sX.shape[0]
    a_idx, b_idx = np.triu_indices(Bx)
    n_pairs = a_idx.size

    if row_block is None and 8 * n_pairs * sX.shape[1] * sX.shape[2] \
            > _SYM_GATHER_BUDGET:
        row_block = max(1, _SYM_GATHER_BUDGET
                        // (8 * Bx * sX.shape[1] * sX.shape[2]))

    if row_block is None:
        dispatch.record_pair_solves(n_pairs)
        k = _solve_pairs(sX[a_idx], sX[b_idx], kernel, backend, lam1, lam2)
    else:
        # a block of `row_block` Gram rows ~ row_block·Bx pairs of live Δ.
        # Only the (chunk,)-sized index arrays are materialised up front; the
        # pair gather itself happens inside the mapped body, one chunk at a
        # time, so live replicated increments stay at 2·chunk·L·d floats.
        chunk = max(1, int(row_block)) * Bx
        pad = (-n_pairs) % chunk
        a_pad = np.concatenate([a_idx, np.zeros(pad, a_idx.dtype)])
        b_pad = np.concatenate([b_idx, np.zeros(pad, b_idx.dtype)])
        n_blocks = (n_pairs + pad) // chunk
        dispatch.record_pair_solves(n_pairs + pad)
        a_chunks = jnp.asarray(a_pad).reshape(n_blocks, chunk)
        b_chunks = jnp.asarray(b_pad).reshape(n_blocks, chunk)
        k = jax.lax.map(
            lambda ab: _solve_pairs(sX[ab[0]], sX[ab[1]], kernel, backend,
                                    lam1, lam2),
            (a_chunks, b_chunks))
        k = k.reshape(-1)[:n_pairs]

    K = jnp.zeros((Bx, Bx), k.dtype).at[a_idx, b_idx].set(k)
    K = K + jnp.triu(K, k=1).T
    return shard(K, "batch", "model")
