"""Serving steps: batched prefill and single-token decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.step import cast_compute


def make_prefill_step(model, max_len: int = None):
    """max_len: static decode-cache capacity (defaults to the prompt length)."""
    cdt = jnp.dtype(model.cfg.compute_dtype)

    def prefill_step(params, batch):
        if max_len is not None:
            batch = dict(batch, max_len=max_len)   # static python int
        return model.prefill(cast_compute(params, cdt), batch)

    return prefill_step


def make_decode_step(model, *, greedy: bool = True):
    cdt = jnp.dtype(model.cfg.compute_dtype)

    def decode_step(params, caches, tokens, cur_len):
        """tokens: (B, 1) current tokens; returns (next_tokens, logits, caches)."""
        logits, caches = model.decode(cast_compute(params, cdt), caches,
                                      tokens, cur_len)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, caches

    return decode_step
