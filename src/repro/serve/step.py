"""Serving steps: batched prefill and single-token decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.train.step import cast_compute


def make_prefill_step(model, max_len: int | None = None):
    """max_len: static decode-cache capacity (defaults to the prompt length)."""
    cdt = jnp.dtype(model.cfg.compute_dtype)

    def prefill_step(params, batch):
        if max_len is not None:
            batch = dict(batch, max_len=max_len)   # static python int
        return model.prefill(cast_compute(params, cdt), batch)

    return prefill_step


def make_decode_step(model, *, greedy: bool = True, temperature: float = 1.0):
    """Build a one-token decode step.

    ``greedy=True`` (default) argmaxes the last-position logits and the step
    is ``decode_step(params, caches, tokens, cur_len)``.  ``greedy=False``
    samples from ``softmax(logits / temperature)`` instead, and the step
    takes a trailing PRNG key: ``decode_step(params, caches, tokens,
    cur_len, key)``.
    """
    cdt = jnp.dtype(model.cfg.compute_dtype)

    if greedy:
        def decode_step(params, caches, tokens, cur_len):
            """tokens: (B, 1) current tokens; returns (next_tokens, logits, caches)."""
            logits, caches = model.decode(cast_compute(params, cdt), caches,
                                          tokens, cur_len)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            return nxt, logits, caches

        return decode_step

    if temperature <= 0:
        raise ValueError(
            f"sampling (greedy=False) needs temperature > 0, got "
            f"{temperature}; use greedy=True for argmax decoding")

    def decode_step(params, caches, tokens, cur_len, key):
        """tokens: (B, 1); key: PRNG key; returns (next_tokens, logits, caches)."""
        logits, caches = model.decode(cast_compute(params, cdt), caches,
                                      tokens, cur_len)
        scaled = logits[:, -1, :] / jnp.asarray(temperature, logits.dtype)
        nxt = jax.random.categorical(key, scaled, axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, caches

    return decode_step
