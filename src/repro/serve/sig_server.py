"""A signature-feature server for concurrently growing streams.

:class:`SigFeatureServer` keeps one :class:`repro.Path` per named stream
and turns the tick-by-tick serving pattern into bounded-retrace batched
work:

* **appends are admitted, not applied** — ``append(name, points)`` only
  queues the chunk; ``flush()`` coalesces every pending append across all
  streams into as few batched kernel calls as possible
  (:func:`repro.stream.coalesced_update`), grouping streams by
  ``(capacity, chunk bucket)`` and padding each group to a power-of-two
  size with no-op members, so the number of distinct jit traces stays
  bounded in the stream count, the chunk sizes *and* the group sizes;
* **queries are O(1)** — ``signature`` / ``logsignature`` / ``rolling``
  are Chen combines against each stream's prefix store, never re-scans;
* **feature extraction is config-driven** — ``features(name, ...)`` runs
  the server's :class:`repro.FeatureConfig` (``method="rff"``) over the
  requested window of raw points, honouring the server's
  :class:`repro.TransformPipeline` and static kernel exactly like the
  offline Gram entry points;
* **caches can be pre-warmed** — ``warmup()`` traces the build/update
  kernels for the buckets the steady state will hit, so the first real
  tick is served from a warm cache.

The server is an eager orchestrator: all heavy lifting happens inside the
stream module's jitted kernels, and ``stats()`` exposes the admission
counters (plus the jit-trace counters) that the serving example turns into
a latency/throughput report.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import transforms as tf
from ..core.config import Linear, TransformPipeline
from ..core.features import FeatureConfig, resolve_features, rff_features
from ..stream import path as stream_path
from ..stream.path import Path, RollingConfig, coalesced_update

#: jitted feature map shared by every server instance: the FeatureConfig /
#: TransformPipeline / kernel arguments are pytrees whose knobs are static
#: metadata, so one trace per (window shape, config structure) serves all
#: requests — online features stay bitwise the offline ``rff_features``
_rff_jit = jax.jit(rff_features)


class SigFeatureServer:
    """Serve signature features over named, concurrently growing streams.

    Args:
      depth: signature truncation depth shared by every stream.
      transforms: optional :class:`repro.TransformPipeline` (lead-lag only —
        the streaming restriction of :class:`repro.Path`).
      features: optional :class:`repro.FeatureConfig` enabling
        :meth:`features`.  Only ``method="rff"`` can be served online;
        Nystroem needs landmark PDE solves against a reference batch, which
        is an offline construction — it is rejected at server build time.
      static_kernel: static kernel of the feature lift (default
        :class:`repro.Linear`).
    """

    def __init__(self, depth: int, *,
                 transforms: Optional[TransformPipeline] = None,
                 features: Optional[FeatureConfig] = None,
                 static_kernel=None):
        self.depth = depth
        self.transforms = transforms if transforms is not None \
            else TransformPipeline()
        feats = resolve_features(features)
        if feats is not None and feats.method != "rff":
            raise ValueError(
                f"SigFeatureServer can only serve method='rff' features "
                f"online (got {feats.method!r}): Nystroem landmarks are "
                f"fit against an offline reference batch — precompute "
                f"those features with repro.sig_kernel_gram instead")
        self.features_config = feats
        self.static_kernel = static_kernel if static_kernel is not None \
            else Linear()
        self._streams: Dict[str, Path] = {}
        self._pending: Dict[str, List[jnp.ndarray]] = {}
        self._stats = {
            "streams": 0, "points_appended": 0, "flushes": 0,
            "update_groups": 0, "solo_updates": 0, "coalesced_streams": 0,
            "queries": 0, "feature_requests": 0,
        }

    # -- stream lifecycle ----------------------------------------------------

    def open_stream(self, name: str, points) -> Path:
        """Open stream ``name`` with its initial points (L ≥ 2 rows)."""
        if name in self._streams:
            raise ValueError(f"stream {name!r} already open")
        p = Path.from_points(jnp.asarray(points), self.depth,
                             transforms=self.transforms)
        if p.points.ndim != 2:
            raise ValueError(
                f"streams are single paths: expected (L, d) initial "
                f"points, got shape {tuple(p.points.shape)}")
        self._streams[name] = p
        self._stats["streams"] += 1
        return p

    def close_stream(self, name: str) -> None:
        self._require(name)
        self._streams.pop(name)
        self._pending.pop(name, None)
        self._stats["streams"] -= 1

    def path(self, name: str) -> Path:
        """The stream's current :class:`repro.Path` (pending appends excluded)."""
        return self._require(name)

    def _require(self, name: str) -> Path:
        if name not in self._streams:
            raise KeyError(
                f"unknown stream {name!r}; open it with open_stream() "
                f"(open: {sorted(self._streams)})")
        return self._streams[name]

    # -- admission batching --------------------------------------------------

    def append(self, name: str, points) -> None:
        """Queue new points for ``name``; applied at the next :meth:`flush`."""
        self._require(name)
        pts = jnp.asarray(points)
        if pts.ndim == 1:                      # a single tick: (d,)
            pts = pts[None, :]
        if pts.ndim != 2 or pts.shape[-1] != self._streams[name].d:
            raise ValueError(
                f"append expects (k, {self._streams[name].d}) points for "
                f"stream {name!r}, got shape {tuple(pts.shape)}")
        self._pending.setdefault(name, []).append(pts)
        self._stats["points_appended"] += int(pts.shape[0])

    def flush(self) -> int:
        """Apply all pending appends in coalesced batched kernel calls.

        Streams are grouped by ``(buffer capacity, chunk bucket)``; each
        group becomes ONE batched update (padded to a power-of-two group
        size), so a thousand single-tick streams cost a handful of traces
        and one kernel launch per (capacity, bucket) pair.  Streams whose
        buffers must grow first are updated solo (growth is a bounded,
        logarithmically-rare event).  Returns the number of streams
        updated.
        """
        if not self._pending:
            return 0
        groups: Dict[Tuple[int, int], List[Tuple[str, jnp.ndarray]]] = {}
        solo: List[Tuple[str, jnp.ndarray]] = []
        for name, chunks in self._pending.items():
            chunk = chunks[0] if len(chunks) == 1 \
                else jnp.concatenate(chunks, axis=0)
            p = self._streams[name]
            kc = tf.bucket_length(chunk.shape[0], minimum=1)
            if len(p) + kc > p.capacity:
                solo.append((name, chunk))     # needs growth: solo update
            else:
                key = (p.capacity, kc)
                groups.setdefault(key, []).append((name, chunk))
        n = 0
        for _, members in sorted(groups.items()):
            paths = [self._streams[name] for name, _ in members]
            updated = coalesced_update(paths, [c for _, c in members])
            for (name, _), new_path in zip(members, updated):
                self._streams[name] = new_path
            n += len(members)
            self._stats["update_groups"] += 1
            self._stats["coalesced_streams"] += len(members)
        for name, chunk in solo:
            self._streams[name] = self._streams[name].update(chunk)
            n += 1
            self._stats["solo_updates"] += 1
        self._pending.clear()
        self._stats["flushes"] += 1
        return n

    # -- queries -------------------------------------------------------------

    def signature(self, name: str, i: int = 0, j: Optional[int] = None):
        """Signature of ``stream[i:j]`` — one Chen combine (see Path)."""
        self._stats["queries"] += 1
        return self._require(name).signature(i, j)

    def logsignature(self, name: str, i: int = 0, j: Optional[int] = None,
                     *, mode: str = "lyndon"):
        self._stats["queries"] += 1
        return self._require(name).logsignature(i, j, mode=mode)

    def rolling(self, name: str, window, *, stride: int = 1):
        self._stats["queries"] += 1
        return self._require(name).rolling(window, stride=stride)

    def features(self, name: str, window: Optional[int] = None):
        """RFF signature features of the stream's last ``window`` points.

        ``window=None`` uses the whole stream.  Runs the server's
        :class:`repro.FeatureConfig` over the raw points (transform +
        static-kernel lift + projection scan), exactly as the offline
        ``features=`` path of the Gram entry points — so online features
        are drop-in consistent with offline training features.
        """
        if self.features_config is None:
            raise ValueError(
                "this server has no FeatureConfig; pass features= to "
                "SigFeatureServer to serve feature vectors")
        p = self._require(name)
        L = len(p)
        if window is None:
            window = L
        if not (2 <= window <= L):
            raise ValueError(
                f"features window must be in [2, {L}] for stream "
                f"{name!r}, got {window}")
        self._stats["feature_requests"] += 1
        pts = jax.lax.dynamic_slice_in_dim(p.points, L - window, window,
                                           axis=-2)
        return _rff_jit(pts[None], self.features_config,
                        self.transforms, self.static_kernel)[0]

    # -- cache warmup & stats ------------------------------------------------

    def warmup(self, lengths=(8, 16), chunk_sizes=(1,),
               group_sizes=(1,)) -> float:
        """Trace the build/update kernels for the given buckets up front.

        Steady-state serving then hits only warm jit traces (verified by
        ``stats()['trace_counts']`` staying flat).  Returns the wall time
        spent warming, in seconds.
        """
        t0 = time.perf_counter()
        for L in lengths:
            C = tf.bucket_length(L)
            for g in group_sizes:
                gb = tf.bucket_length(g, minimum=1)
                for k in chunk_sizes:
                    kc = tf.bucket_length(k, minimum=1)
                    if C < kc + 2:
                        continue
                    pts = jnp.linspace(0.0, 1.0, C)[:, None] \
                        * jnp.ones((1, self._warmup_d()))
                    batch = jnp.broadcast_to(pts, (gb, *pts.shape))
                    p = Path.from_points(batch, self.depth,
                                         transforms=self.transforms)
                    chunk = jnp.broadcast_to(pts[:kc], (gb, kc, pts.shape[-1]))
                    stream_path._update_kernel(
                        p.points, p.prefix, p.inv_prefix,
                        jnp.full((gb,), C - kc, jnp.int32), chunk,
                        jnp.full((gb,), k, jnp.int32), depth=self.depth,
                        lead_lag=self.transforms.lead_lag)
        return time.perf_counter() - t0

    def _warmup_d(self) -> int:
        if self._streams:
            return next(iter(self._streams.values())).d
        return 2

    def stats(self) -> dict:
        """Admission/query counters plus the stream jit-trace counters."""
        out = dict(self._stats)
        out["pending_streams"] = len(self._pending)
        out["trace_counts"] = stream_path.trace_counts()
        return out
