"""Serving loops: the signature-feature server and LM decode steps.

:mod:`repro.serve.sig_server` is the streaming-signature serving loop
(admission-batched appends over :class:`repro.Path` streams);
:mod:`repro.serve.step` holds the LM prefill/decode step builders used by
``examples/serve_lm.py``.
"""

from .sig_server import SigFeatureServer  # noqa: F401

__all__ = ["SigFeatureServer"]
