"""AdamW with configurable moment dtype, global-norm clipping and schedules.

Moments can be stored in bf16 for ≥100B-parameter models (nemotron/dbrx/
qwen72/internvl) — the difference between fitting and not fitting a v5e-256
pod (DESIGN.md §6); master params stay fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        mdt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        # global-norm clip (f32 accumulate)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        count = state.count + 1
        lr = self.lr(count)
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        mdt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32)
            v32 = v.astype(jnp.float32)
            m_new = self.b1 * m32 + (1 - self.b1) * g
            v_new = self.b2 * v32 + (1 - self.b2) * g * g
            step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + self.eps)
            if p.ndim >= 2:  # no decay on norms / scalars
                step = step + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * step
            return p_new.astype(p.dtype), m_new.astype(mdt), v_new.astype(mdt)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(count, new_m, new_v), {"grad_norm": gnorm}


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr
