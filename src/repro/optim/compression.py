"""Int8 gradient compression with error feedback for the cross-pod reduce.

Within a pod, ICI is fast (~50 GB/s/link) and gradients stay uncompressed.
BETWEEN pods, the data-center network is the bottleneck at scale; this module
replaces the pod-axis mean with

    all_gather(int8 quantised shards) + local dequant-sum        (EF-SGD)

which moves ~8x fewer bytes than an fp32 ring all-reduce.  Quantisation error
is carried in an error-feedback accumulator (per-parameter, fp32, sharded
like the gradient), which preserves convergence (Karimireddy et al. 2019).

Usage inside a shard_map whose manual axes include "pod":

    g_global, ef_new = psum_compressed(g_local, ef, "pod")
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_compressed(g: jax.Array, ef: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Mean of ``g`` over ``axis_name`` with int8 EF compression.

    g, ef: local fp32 arrays (same shape).  Returns (mean, new_ef).
    """
    x = g + ef
    q, scale = quantize_int8(x)
    ef_new = x - dequantize(q, scale)
    qs = jax.lax.all_gather(q, axis_name)            # (n, ...) int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)        # (n,)
    n = qs.shape[0]
    deq = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * g.ndim)
    return deq.sum(axis=0) / n, ef_new


def psum_compressed_tree(grads, ef_tree, axis_name: str):
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(ef_tree)[0]
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = psum_compressed(g.astype(jnp.float32), e, axis_name)
        out_g.append(m)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_e))


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
