"""Mesh-context helpers: logical-axis activation sharding.

Models annotate activations with *logical* axis names; the mapping onto
physical mesh axes is installed by the launcher (train / serve / dryrun).
Outside any mesh context the annotations are no-ops, so the same model code
runs on a laptop and on a 512-chip mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

_state = threading.local()

# default logical -> physical mapping for a ("data", "model") mesh
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": "data",            # data-parallel batch
    "fsdp": "data",             # parameter/optimizer sharding axis
    "model": "model",           # tensor-parallel axis
    "seq": None,                # sequence axis inside layers
    "residual": "model",        # sequence axis of the residual stream (SP):
                                # shards remat-saved carries and turns TP
                                # all-reduces into reduce-scatter/all-gather
    "expert": "model",          # expert-parallel axis
    None: None,
}

MULTIPOD_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "model": "model",
    "seq": None,
    "residual": "model",
    "expert": "model",
    None: None,
}


def current_rules() -> Optional[Dict[str, Axis]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def logical_rules(rules: Dict[str, Axis]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def resolve(*logical: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec under the active rules.

    Deduplicates mesh axes left-to-right (a mesh axis may appear in at most
    one positional dim — e.g. mamba2 maps both `batch` and `model` onto the
    model axis; the first dim wins)."""
    rules = current_rules() or DEFAULT_RULES
    out, used = [], set()
    for name in logical:
        axis = rules.get(name, None)
        axes = (axis,) if isinstance(axis, str) else tuple(axis or ())
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        out.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    if current_rules() is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, resolve(*logical))
    except (ValueError, RuntimeError):
        return x  # no mesh context / incompatible rank: stay unconstrained
