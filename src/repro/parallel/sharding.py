"""Parameter sharding rules: tree-path pattern -> logical spec -> PartitionSpec.

FSDP/ZeRO-3: weight matrices shard their d_model-like dim over the ``fsdp``
axes (data, and pod when multi-pod) and their TP dim over ``model``.  A
divisibility check demotes any dim that does not divide the mesh axis size to
replicated (e.g. whisper's 20 heads, granite's single KV head) — the generic
mechanism that makes all ten archs shardable with one rule table.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .api import DEFAULT_RULES, MULTIPOD_RULES, Axis


def get_shard_map():
    """The ``shard_map`` transform across supported jax versions.

    Newer jax exposes :func:`jax.shard_map`; older releases only have
    ``jax.experimental.shard_map.shard_map``.  Import at call time so
    importing this module never drags in experimental namespaces.
    """
    try:
        from jax import shard_map  # jax >= 0.6
        return shard_map
    except ImportError:
        from jax.experimental.shard_map import shard_map
        return shard_map


def block_cyclic_perm(n: int, n_shards: int, block: int):
    """Row permutation realising a block-cyclic layout under contiguous sharding.

    Returns ``(perm, inv)`` (numpy int arrays, length ``n``) such that when
    ``x[perm]`` is sharded into ``n_shards`` equal contiguous pieces, shard
    ``i`` holds the *blocks* ``i, i + n_shards, i + 2·n_shards, …`` of the
    original ``x`` (blocks of ``block`` consecutive rows dealt round-robin —
    ScaLAPACK-style block-cyclic).  ``inv`` undoes it: ``x[perm][inv] == x``.

    ``n`` must be divisible by ``n_shards · block`` (pad first); the cyclic
    deal is what keeps the *symmetric* Gram's triangular tile grid balanced
    across shards — contiguous row blocks would give the last shard ~2×
    the PDE solves of the first.
    """
    if n % (n_shards * block) != 0:
        raise ValueError(
            f"block_cyclic_perm needs n divisible by n_shards*block, got "
            f"n={n}, n_shards={n_shards}, block={block}")
    n_blocks = n // block
    # shard i's blocks, concatenated shard-by-shard
    order = np.arange(n_blocks).reshape(-1, n_shards).T.reshape(-1)
    perm = (order[:, None] * block + np.arange(block)[None, :]).reshape(-1)
    inv = np.argsort(perm)
    return perm, inv


def gram_specs(mesh: Mesh, Bx: int, By: int, *,
               row_axis: str = "data", col_axis: str = "model"
               ) -> Tuple[P, P, P]:
    """PartitionSpecs ``(rows_spec, cols_spec, gram_spec)`` for a (Bx, By)
    Gram tiling: X rows over ``row_axis``, Y rows over ``col_axis``, the
    Gram over both.  Reuses :func:`physical_spec`'s divisibility demotion —
    a batch that does not divide its mesh axis is replicated instead of
    erroring, so the same call works on any device count.
    """
    rules = {"batch": row_axis, "model": col_axis, None: None}
    rows = physical_spec(("batch",), (Bx,), mesh, rules)
    cols = physical_spec(("model",), (By,), mesh, rules)
    gram = physical_spec(("batch", "model"), (Bx, By), mesh, rules)
    return rows, cols, gram


# logical specs by trailing path name; rank refers to the UNSTACKED param
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "table":      ("model", "fsdp"),          # embeddings: vocab x d_model
    "wq":         ("fsdp", "model", None),
    "wk":         ("fsdp", "model", None),
    "wv":         ("fsdp", "model", None),
    "wo":         ("model", None, "fsdp"),
    "bq":         ("model", None),
    "bk":         ("model", None),
    "bv":         ("model", None),
    "w_gate":     ("fsdp", "model"),
    "w_up":       ("fsdp", "model"),
    "w_in":       ("fsdp", "model"),
    "w_out":      ("model", "fsdp"),
    "router":     ("fsdp", None),
    "shared_gate": ("fsdp", None),
    "patch_proj": (None, "fsdp"),
    "sig_proj":   (None, None),
    # mamba2 (packed projections: replicate TP, shard over fsdp only)
    "in_proj":    ("fsdp", None),
    "out_proj":   (None, "fsdp"),
    "conv_w":     (None, "model"),
    "conv_b":     ("model",),
    "A_log":      (None,),
    "D":          (None,),
    "dt_bias":    (None,),
    # rg-lru
    "w_x":        ("fsdp", "model"),
    "w_y":        ("fsdp", "model"),
    "w_a":        (None, "model"),
    "w_i":        (None, "model"),
    "b_a":        ("model",),
    "b_i":        ("model",),
    "lam":        ("model",),
    # norms
    "scale":      (None,),
    "bias":       (None,),
}

# MoE expert tensors (parent name "moe"): (E, D, F) / (E, F, D).
# The F dim lists "model" as a fallback: when the expert count does not
# divide the model axis (e.g. Qwen's 60 experts), the per-expert hidden is
# tensor-parallel instead — the used-axis bookkeeping in physical_spec picks
# exactly one of the two automatically.
_MOE_RULES = {
    "w_gate": ("expert", "fsdp", "model"),
    "w_up":   ("expert", "fsdp", "model"),
    "w_out":  ("expert", "model", "fsdp"),
}


def rules_for(cfg, multi_pod: bool) -> Dict[Optional[str], Axis]:
    """Logical -> physical mapping, with per-family overrides."""
    base = dict(MULTIPOD_RULES if multi_pod else DEFAULT_RULES)
    if cfg is not None and getattr(cfg, "family", None) == "ssm":
        # mamba2: packed projections are not TP-friendly; use the model axis
        # as extra batch/FSDP parallelism, but keep it available for the
        # embedding/logits vocab dim and the residual-stream sequence dim
        # (DESIGN.md §Arch-applicability).
        base["batch"] = (("pod", "data") if multi_pod else ("data", "model"))
        base["fsdp"] = (("pod", "data", "model") if multi_pod
                        else ("data", "model"))
        base["expert"] = None
    return base


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    return math.prod(mesh.shape[a] for a in axis)


def logical_spec_for(path: Tuple[str, ...], leaf) -> Tuple[Optional[str], ...]:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    grandparent = path[-3] if len(path) > 2 else ""
    if name in _MOE_RULES and ("moe" in (parent, grandparent)):
        base = _MOE_RULES[name]
    elif name in _RULES:
        base = _RULES[name]
    else:
        base = (None,) * leaf.ndim
    if leaf.ndim == len(base) + 1:          # scan-stacked: leading layer dim
        base = (None,) + base
    elif leaf.ndim != len(base):            # unexpected rank: replicate
        base = (None,) * leaf.ndim
    return base


def physical_spec(logical: Tuple[Optional[str], ...], shape, mesh: Mesh,
                  rules: Dict[Optional[str], Axis]) -> P:
    out = []
    used: set = set()
    for dim, name in zip(shape, logical):
        axis = rules.get(name, None)
        axes = (axis,) if isinstance(axis, str) else tuple(axis or ())
        axes = tuple(a for a in axes if a not in used)
        # progressively drop trailing axes until the dim divides the product
        while axes and dim % math.prod(mesh.shape[a] for a in axes) != 0:
            axes = axes[:-1]
        if axes:
            out.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            out.append(None)
    return P(*out)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(params_shape, cfg, mesh: Mesh, multi_pod: bool):
    """Tree of NamedSharding for a params (or ShapeDtypeStruct) tree."""
    rules = rules_for(cfg, multi_pod)

    def one(path, leaf):
        logical = logical_spec_for(_path_names(path), leaf)
        return NamedSharding(mesh, physical_spec(logical, leaf.shape, mesh, rules))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def batch_shardings(batch_shape, cfg, mesh: Mesh, multi_pod: bool):
    """Inputs: batch dim over the batch axes, everything else replicated."""
    rules = rules_for(cfg, multi_pod)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, physical_spec(("batch",) + (None,) * (leaf.ndim - 1),
                                leaf.shape, mesh, rules))

    return jax.tree.map(one, batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# logical specs for decode-cache leaves, keyed by leaf name (UNSTACKED rank)
_CACHE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "k":    ("batch", None, "model", None),     # (B, S, KV, hd)
    "v":    ("batch", None, "model", None),
    "ck":   ("batch", None, "model", None),     # whisper cross K/V
    "cv":   ("batch", None, "model", None),
    "pos":  (None,),                            # ring positions (W,)
    "conv": ("batch", None, "model"),           # conv tail (B, K, C)
    "state": ("batch", "model", None, None),    # ssm state (B, H, N, P)
    "h":    ("batch", "model"),                 # rg-lru state (B, W)
}


def cache_shardings(cache_shape, cfg, mesh: Mesh, multi_pod: bool):
    """NamedShardings for decode caches (batch dim is NOT dim 0 when layers
    are scan-stacked — handled via the rank adjustment)."""
    rules = rules_for(cfg, multi_pod)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        base = _CACHE_RULES.get(name, None)
        if base is None:
            logical = (None,) * leaf.ndim
        else:
            logical = base
            if name in ("k", "v", "ck", "cv"):
                # TP the cache on KV heads when they divide the model axis;
                # else shard the SEQUENCE dim (flash-decoding style: scores
                # stay seq-sharded, softmax reduces via tiny collectives);
                # else head_dim.  A replicated cache wastes the whole model
                # axis of HBM (DESIGN.md §6).
                S, kv, hd = leaf.shape[-3], leaf.shape[-2], leaf.shape[-1]
                tp = _axis_size(mesh, rules.get("model"))
                if tp > 1 and kv % tp != 0:
                    if S % tp == 0:
                        logical = ("batch", "model", None, None)
                    elif hd % tp == 0:
                        logical = ("batch", None, None, "model")
            if leaf.ndim == len(logical) + 1:   # stacked over layers
                logical = (None,) + tuple(logical)
            elif leaf.ndim != len(logical):
                logical = (None,) * leaf.ndim
        return NamedSharding(mesh, physical_spec(logical, leaf.shape, mesh,
                                                 rules))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
