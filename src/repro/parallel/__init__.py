from .api import shard, logical_rules, resolve, DEFAULT_RULES, MULTIPOD_RULES

__all__ = ["shard", "logical_rules", "resolve", "DEFAULT_RULES",
           "MULTIPOD_RULES"]
