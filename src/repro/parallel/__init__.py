from .api import shard, logical_rules, resolve, DEFAULT_RULES, MULTIPOD_RULES
