"""Pallas TPU kernels for the paper's compute hot-spots.

- sigkernel_pde/: Goursat-PDE wavefront solver (fwd, exact bwd, fused-delta)
- signature/:     Horner truncated-signature kernel

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle).
"""
