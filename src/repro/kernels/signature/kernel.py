"""Pallas TPU kernel: Horner's algorithm for truncated signatures (pySigLib §2.3).

Realises the paper's memory-discipline choices natively in VMEM:

(1) the whole truncated signature (A_1..A_N) lives as ONE flattened
    contiguous scratch buffer of shape (sig_dim, BT) — levels back-to-back on
    the sublane axis, a batch tile of BT paths on the lane axis;
(2) levels are updated in REVERSE order (A_N → A_1) in place, so each
    path-step needs no second signature buffer;
(3) the Horner accumulator B_k is a single register/VMEM value reused by all
    levels (its tensor-product-by-z is a broadcast multiply + contiguous
    reshape — no strided writes);
(4) the final ``B_k ⊗ z + A_k`` accumulates directly into the signature
    buffer.

The tensor product with a level-1 increment in (level, batch) layout is

    C[(a·d + j), b] = A[a, b] · z[j, b]
      == (A[:, None, :] * z[None, :, :]).reshape(-1, BT)

i.e. a VPU broadcast multiply followed by a free (contiguous) reshape — this
is the TPU-native replacement for the paper's reverse-order in-place scalar
loop (DESIGN.md §2).

Grid = (batch_tiles, L_blocks); the signature scratch persists across the
sequential L-block sweep, so arbitrarily long paths stream through a fixed
VMEM working set.  Zero increments are exact no-ops (exp(0) = 1), so ops.py
pads both batch and length freely.
"""

from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.tensoralg import level_offsets, level_sizes, sig_dim


def vmem_scratch(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def horner_kernel(z_ref, out_ref, a_ref, *, d: int, depth: int, LB: int,
                  BT: int, n_lb: int, offs: List[int], sizes: List[int]):
    """One (batch_tile, L_block) grid step: LB Horner path-steps in VMEM."""
    lb = pl.program_id(1)

    @pl.when(lb == 0)
    def _reset():
        a_ref[...] = jnp.zeros_like(a_ref)

    def outer_z(a, z):
        """Tensor product with a level-1 increment: contiguous in this layout."""
        return (a[:, None, :] * z[None, :, :]).reshape(-1, BT)

    def step(li, carry):
        z = z_ref[0, li]                                  # (d, BT)
        # --- Horner's scheme (paper Alg 2), levels updated in reverse ---
        for k in range(depth, 1, -1):
            B = z / float(k)
            for i in range(1, k - 1):
                Ai = a_ref[offs[i - 1]:offs[i - 1] + sizes[i - 1], :]
                B = outer_z(B + Ai, z / float(k - i))
            Akm1 = a_ref[offs[k - 2]:offs[k - 2] + sizes[k - 2], :]
            B = B + Akm1
            sl = slice(offs[k - 1], offs[k - 1] + sizes[k - 1])
            a_ref[sl, :] = a_ref[sl, :] + outer_z(B, z)
        a_ref[offs[0]:offs[0] + sizes[0], :] = \
            a_ref[offs[0]:offs[0] + sizes[0], :] + z
        return carry

    jax.lax.fori_loop(0, LB, step, 0)

    @pl.when(lb == n_lb - 1)
    def _emit():
        out_ref[0] = a_ref[...]


def build_horner(n_tiles: int, Lp: int, d: int, depth: int, *, BT: int,
                 LB: int, interpret: bool):
    """pallas_call for increments laid out as (n_tiles, Lp, d, BT), Lp % LB == 0."""
    if Lp % LB != 0:
        raise ValueError(
            f"Horner kernel needs the padded length Lp={Lp} to be a "
            f"multiple of the length block LB={LB} — pick a "
            f"LaunchConfig.sig_lb that divides the padded length (the "
            f"ops.py wrapper pads to the block automatically)")
    n_lb = Lp // LB
    sd = sig_dim(d, depth)
    kern = functools.partial(
        horner_kernel, d=d, depth=depth, LB=LB, BT=BT, n_lb=n_lb,
        offs=level_offsets(d, depth), sizes=level_sizes(d, depth))
    return pl.pallas_call(
        kern,
        grid=(n_tiles, n_lb),
        in_specs=[pl.BlockSpec((1, LB, d, BT), lambda t, lb: (t, lb, 0, 0))],
        out_specs=pl.BlockSpec((1, sd, BT), lambda t, lb: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, sd, BT), jnp.float32),
        scratch_shapes=[vmem_scratch((sd, BT))],
        interpret=interpret,
    )
