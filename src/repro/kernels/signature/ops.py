"""Jit'd public wrapper for the Horner signature Pallas kernel.

Handles batch/length padding (zero increments are exact no-ops), the
(batch, L, d) -> (tiles, L, d, BT) layout transform, batch-tile sizing under
the VMEM budget, and exact backprop: the backward pass is the time-reversed
signature deconstruction of pySigLib §2.4 (O(1) memory in path length),
reusing the validated pure-JAX implementation in ``repro.core.signature``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tensoralg import sig_dim, level_sizes
from .kernel import build_horner

_VMEM_BUDGET = 10 * 1024 * 1024
_MAX_BT = 128
_LB = 256


def default_use_pallas() -> bool:
    """Backend-based default for ``use_pallas=None``: the compiled kernel is
    the fast path on TPU; elsewhere it runs in interpret mode, so the pure-JAX
    implementation is preferred."""
    return jax.default_backend() == "tpu"


def choose_BT(d: int, depth: int, LB: int) -> int:
    sd = sig_dim(d, depth)
    bmax = d ** max(depth - 1, 1)
    BT = _MAX_BT
    while BT > 8:
        if 4 * BT * (2 * sd + 2 * bmax + LB * d) <= _VMEM_BUDGET:
            break
        BT //= 2
    return BT


@functools.partial(jax.jit, static_argnums=(1,))
def _horner_flat(z: jax.Array, depth: int) -> jax.Array:
    B, Lm1, d = z.shape
    LB = min(_LB, max(Lm1, 1))
    BT = choose_BT(d, depth, LB)
    Bp = -(-B // BT) * BT
    Lp = -(-Lm1 // LB) * LB
    zp = jnp.pad(z.astype(jnp.float32), ((0, Bp - B), (0, Lp - Lm1), (0, 0)))
    n_tiles = Bp // BT
    zt = zp.reshape(n_tiles, BT, Lp, d).transpose(0, 2, 3, 1)  # (t, L, d, BT)
    out = build_horner(n_tiles, Lp, d, depth, BT=BT, LB=LB,
                       interpret=jax.default_backend() == "cpu")(zt)
    sd = sig_dim(d, depth)
    return out.transpose(0, 2, 1).reshape(Bp, sd)[:B]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def signature_from_increments(z: jax.Array, depth: int) -> jax.Array:
    """Truncated signature of increment streams z (..., L-1, d) via Pallas."""
    batch_shape = z.shape[:-2]
    flat = z.reshape((-1,) + z.shape[-2:])
    sig = _horner_flat(flat, depth)
    return sig.reshape(batch_shape + sig.shape[-1:]).astype(z.dtype)


def _fwd(z, depth):
    sig = signature_from_increments(z, depth)
    return sig, (z, sig)


def _bwd(depth, res, g):
    from repro.core.signature import _signature_core_bwd
    z, sig = res
    return _signature_core_bwd(depth, (z, sig.astype(jnp.float32)),
                               g.astype(jnp.float32))


signature_from_increments.defvjp(_fwd, _bwd)


def logsignature_from_increments(z: jax.Array, depth: int,
                                 mode: str = "lyndon") -> jax.Array:
    """Fused increments -> log-signature via the Pallas Horner kernel.

    The Horner recursion (the O(L) hot loop) runs through the same
    ``pallas_call`` as :func:`signature_from_increments` — no forked kernel —
    and the log + Lyndon projection are applied as a cheap epilogue: a fixed
    polynomial in the signature levels followed by a static gather
    (``mode="lyndon"``) or gather+matmul (``mode="brackets"``).  Gradients
    reuse the exact time-reversed deconstruction backward of the signature
    kernel wrapper via autodiff composition.
    """
    from repro.core.logsignature import MODES, _project
    from repro.core.tensoralg import tensor_log
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    d = z.shape[-1]
    sig = signature_from_increments(z, depth)
    return _project(tensor_log(sig, d, depth), d, depth, mode)
