"""Jit'd public wrapper for the Horner signature Pallas kernel.

Handles batch/length padding (zero increments are exact no-ops), the
(batch, L, d) -> (tiles, L, d, BT) layout transform, batch-tile sizing under
the VMEM budget, and exact backprop: the backward pass is the time-reversed
signature deconstruction of pySigLib §2.4 (O(1) memory in path length),
reusing the validated pure-JAX implementation in ``repro.core.signature``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tensoralg import sig_dim, level_sizes
from .kernel import build_horner

_VMEM_BUDGET = 10 * 1024 * 1024
_MAX_BT = 128
_LB = 256


def default_use_pallas() -> bool:
    """Backend-based default for ``use_pallas=None``: the compiled kernel is
    the fast path on TPU; elsewhere it runs in interpret mode, so the pure-JAX
    implementation is preferred."""
    return jax.default_backend() == "tpu"


def choose_BT(d: int, depth: int, LB: int, max_bt: int = _MAX_BT) -> int:
    """Largest batch tile ≤ ``max_bt`` whose working set fits the VMEM budget."""
    sd = sig_dim(d, depth)
    bmax = d ** max(depth - 1, 1)
    BT = max_bt
    while BT > 8:
        if 4 * BT * (2 * sd + 2 * bmax + LB * d) <= _VMEM_BUDGET:
            break
        BT //= 2
    return BT


@functools.partial(jax.jit, static_argnums=(1, 2))
def _horner_flat(z: jax.Array, depth: int, launch=None) -> jax.Array:
    from repro.core.config import resolve_launch
    launch = resolve_launch(launch)
    B, Lm1, d = z.shape
    LB = min(launch.sig_lb or _LB, max(Lm1, 1))
    BT = choose_BT(d, depth, LB, max_bt=launch.sig_bt or _MAX_BT)
    Bp = -(-B // BT) * BT
    Lp = -(-Lm1 // LB) * LB
    zp = jnp.pad(z.astype(jnp.float32), ((0, Bp - B), (0, Lp - Lm1), (0, 0)))
    n_tiles = Bp // BT
    zt = zp.reshape(n_tiles, BT, Lp, d).transpose(0, 2, 3, 1)  # (t, L, d, BT)
    out = build_horner(n_tiles, Lp, d, depth, BT=BT, LB=LB,
                       interpret=jax.default_backend() == "cpu")(zt)
    sd = sig_dim(d, depth)
    return out.transpose(0, 2, 1).reshape(Bp, sd)[:B]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def signature_from_increments(z: jax.Array, depth: int,
                              launch=None) -> jax.Array:
    """Truncated signature of increment streams z (..., L-1, d) via Pallas.

    ``launch`` is an optional :class:`repro.core.config.LaunchConfig` whose
    ``sig_bt`` / ``sig_lb`` knobs set the batch-tile and length-block shapes
    (``None`` fields keep the module defaults).  The tile geometry never
    changes the per-path arithmetic — results are bitwise-identical across
    launch configs.
    """
    batch_shape = z.shape[:-2]
    flat = z.reshape((-1,) + z.shape[-2:])
    sig = _horner_flat(flat, depth, launch)
    return sig.reshape(batch_shape + sig.shape[-1:]).astype(z.dtype)


def _fwd(z, depth, launch):
    sig = signature_from_increments(z, depth, launch)
    return sig, (z, sig)


def _bwd(depth, launch, res, g):
    # The exact §2.4 time-reversed backward is pure JAX — tile-shape free,
    # so every LaunchConfig shares the one validated implementation.
    from repro.core.signature import _signature_core_bwd
    z, sig = res
    return _signature_core_bwd(depth, (z, sig.astype(jnp.float32)),
                               g.astype(jnp.float32))


signature_from_increments.defvjp(_fwd, _bwd)


def logsignature_from_increments(z: jax.Array, depth: int,
                                 mode: str = "lyndon",
                                 launch=None) -> jax.Array:
    """Fused increments -> log-signature via the Pallas Horner kernel.

    The Horner recursion (the O(L) hot loop) runs through the same
    ``pallas_call`` as :func:`signature_from_increments` — no forked kernel —
    and the log + Lyndon projection are applied as a cheap epilogue: a fixed
    polynomial in the signature levels followed by a static gather
    (``mode="lyndon"``) or gather+matmul (``mode="brackets"``).  Gradients
    reuse the exact time-reversed deconstruction backward of the signature
    kernel wrapper via autodiff composition.
    """
    from repro.core.logsignature import MODES, _project
    from repro.core.tensoralg import tensor_log
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")
    d = z.shape[-1]
    sig = signature_from_increments(z, depth, launch)
    return _project(tensor_log(sig, d, depth), d, depth, mode)
