"""Pure-jnp oracle for the Horner signature Pallas kernel.

Uses the *direct* algorithm (paper Alg 1) — an independently-written scheme —
so kernel and oracle share no code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.signature import _signature_scan, _direct_step


def signature_from_increments(z: jax.Array, depth: int) -> jax.Array:
    """Truncated signature from an increment stream z (..., L-1, d)."""
    return _signature_scan(z, z.shape[-1], depth, _direct_step)
