"""Jit'd public wrappers for the sig-kernel PDE Pallas kernels.

Responsibilities:
* dtype discipline (compute in f32; bf16/f16 inputs are upcast),
* batch flattening,
* zero-padding Lx to the strip granularity (Δ = 0 rows/cols leave the Goursat
  solution invariant because A(0) = B(0) = 1, so padding is exact — and the
  padded problem's *exact* adjoint restricted to the real Δ block is the real
  problem's exact adjoint),
* strip-height (T) selection under the VMEM budget,
* interpret-mode selection (CPU: interpret=True; TPU: compiled).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import build_fwd
from .grad_kernel import build_bwd

# ~12 MiB working-set budget out of ~16 MiB VMEM per core
_VMEM_BUDGET = 12 * 1024 * 1024
_MAX_T = 128


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def choose_T(Lx: int, Ly: int, lam1: int, lam2: int,
             max_t: int = _MAX_T) -> int:
    """Largest power-of-two strip height ≤ ``max_t`` whose VMEM working set
    fits."""
    ny = Ly << lam2
    T = max_t
    while T > (1 << lam1):
        R = T >> lam1
        # Δ block + expanded M + skewed S_T (+ ~3x for bwd scratch)
        working = 4 * (R * Ly + T * ny + (ny + T) * T * 4)
        if working <= _VMEM_BUDGET:
            break
        T //= 2
    return max(T, 1 << lam1)


def _pad_batched(delta: jax.Array, R: int):
    B, Lx, Ly = delta.shape
    pad = (-Lx) % R
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
    return delta, Lx + pad


def _max_t(launch) -> int:
    """Strip-height cap from a LaunchConfig (``None`` -> module default)."""
    return getattr(launch, "pde_strip", None) or _MAX_T


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4, 5, 6))
def _solve_flat(delta: jax.Array, lam1: int, lam2: int, with_cps: bool,
                launch=None, scheme: str = "order1",
                interior_dtype: str = "float32"):
    B, Lx, Ly = delta.shape
    T = choose_T(Lx, Ly, lam1, lam2, max_t=_max_t(launch))
    delta, Lxp = _pad_batched(delta, T >> lam1)
    call = build_fwd(B, Lxp, Ly, T=T, lam1=lam1, lam2=lam2,
                     save_cps=with_cps, interpret=_on_cpu(), scheme=scheme,
                     interior_dtype=interior_dtype)
    out = call(delta)
    return out


def solve(delta: jax.Array, lam1: int = 0, lam2: int = 0, launch=None,
          scheme: str = "order1",
          interior_dtype: str = "float32") -> jax.Array:
    """Final kernel values for Δ (..., Lx, Ly) -> (...,)."""
    batch_shape = delta.shape[:-2]
    flat = delta.reshape((-1,) + delta.shape[-2:]).astype(jnp.float32)
    k = _solve_flat(flat, lam1, lam2, False, launch, scheme, interior_dtype)
    return k.reshape(batch_shape)


def solve_with_grid(delta: jax.Array, lam1: int = 0, lam2: int = 0,
                    launch=None, scheme: str = "order1",
                    interior_dtype: str = "float32"):
    """Forward + residuals for the exact backward (checkpoint rows, not the
    full grid).  Returns (k, cps)."""
    batch_shape = delta.shape[:-2]
    flat = delta.reshape((-1,) + delta.shape[-2:]).astype(jnp.float32)
    k, cps = _solve_flat(flat, lam1, lam2, True, launch, scheme,
                         interior_dtype)
    return k.reshape(batch_shape), cps


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6, 7))
def _grad_flat(delta, cps, gbar, lam1, lam2, launch=None,
               scheme: str = "order1", interior_dtype: str = "float32"):
    B, Lx, Ly = delta.shape
    T = choose_T(Lx, Ly, lam1, lam2, max_t=_max_t(launch))
    delta, Lxp = _pad_batched(delta, T >> lam1)
    call = build_bwd(B, Lxp, Ly, T=T, lam1=lam1, lam2=lam2,
                     interpret=_on_cpu(), scheme=scheme,
                     interior_dtype=interior_dtype)
    dd = call(delta, delta, cps, gbar)
    return dd[:, :Lx, :]


def solve_grad(delta: jax.Array, cps: jax.Array, gbar: jax.Array,
               lam1: int = 0, lam2: int = 0, launch=None,
               scheme: str = "order1",
               interior_dtype: str = "float32") -> jax.Array:
    """Exact ∂F/∂Δ (paper Alg 4) from saved checkpoint rows.

    ``launch`` must match the forward's — the checkpoint-row cadence is the
    strip height, so backward strips must line up with the saved rows (and
    the scheme/interior_dtype must match: the backward recomputes strip
    interiors with the SAME stencil and rounding the forward used).
    """
    batch_shape = delta.shape[:-2]
    flat = delta.reshape((-1,) + delta.shape[-2:]).astype(jnp.float32)
    g = gbar.reshape((-1,)).astype(jnp.float32)
    dd = _grad_flat(flat, cps, g, lam1, lam2, launch, scheme, interior_dtype)
    return dd.reshape(batch_shape + dd.shape[-2:]).astype(delta.dtype)


# ---------------------------------------------------------------------------
# fused-Δ variants (beyond-paper: Δ never exists in HBM — see kernel.py)
#
# Both are differentiable: the forward never materialises Δ, and the
# custom_vjp backward falls back to the checkpointed exact scheme (Alg 4) —
# Δ is rebuilt for the reverse sweep only, and the backward kernel itself
# recomputes strip interiors from the forward's checkpoint rows.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def _solve_fused_impl(dx: jax.Array, dy: jax.Array, lam1: int,
                      lam2: int, launch=None, scheme: str = "order1",
                      interior_dtype: str = "float32") -> jax.Array:
    from .kernel import build_fwd_fused
    B, Lx, d = dx.shape
    Ly = dy.shape[1]
    T = choose_T(Lx, Ly, lam1, lam2, max_t=_max_t(launch))
    R = T >> lam1
    pad = (-Lx) % R
    if pad:  # zero increments -> zero Δ rows -> exact no-ops
        dx = jnp.pad(dx, ((0, 0), (0, pad), (0, 0)))
    call = build_fwd_fused(B, Lx + pad, Ly, d, T=T, lam1=lam1, lam2=lam2,
                           interpret=_on_cpu(), scheme=scheme,
                           interior_dtype=interior_dtype)
    return call(dx.astype(jnp.float32), dy.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def solve_fused(dx: jax.Array, dy: jax.Array, lam1: int = 0,
                lam2: int = 0, launch=None, scheme: str = "order1",
                interior_dtype: str = "float32") -> jax.Array:
    """k̂ final values from increments directly. dx: (B, Lx, d), dy: (B, Ly, d)."""
    return _solve_fused_impl(dx, dy, lam1, lam2, launch, scheme,
                             interior_dtype)


def _solve_fused_fwd(dx, dy, lam1, lam2, launch, scheme="order1",
                     interior_dtype="float32"):
    return (_solve_fused_impl(dx, dy, lam1, lam2, launch, scheme,
                              interior_dtype), (dx, dy))


def _delta_pullback(dd, dx, dy):
    """Pull ∂F/∂Δ back through Δ = dx · dyᵀ onto the increments."""
    ddx = jnp.einsum("...ij,...jd->...id", dd, dy.astype(dd.dtype))
    ddy = jnp.einsum("...ij,...id->...jd", dd, dx.astype(dd.dtype))
    return ddx.astype(dx.dtype), ddy.astype(dy.dtype)


def _solve_fused_bwd(lam1, lam2, launch, scheme, interior_dtype, res, gbar):
    dx, dy = res
    delta = jnp.einsum("bid,bjd->bij", dx.astype(jnp.float32),
                       dy.astype(jnp.float32))
    _, cps = solve_with_grid(delta, lam1, lam2, launch, scheme,
                             interior_dtype)
    dd = solve_grad(delta, cps, gbar, lam1, lam2, launch, scheme,
                    interior_dtype)
    return _delta_pullback(dd, dx, dy)


solve_fused.defvjp(_solve_fused_fwd, _solve_fused_bwd)


@functools.partial(jax.jit, static_argnums=(2, 3, 4, 5, 6))
def _gram_fused_impl(dX: jax.Array, dY: jax.Array, lam1: int,
                     lam2: int, launch=None, scheme: str = "order1",
                     interior_dtype: str = "float32") -> jax.Array:
    from .kernel import build_gram_fused
    Bx, Lx, d = dX.shape
    By, Ly = dY.shape[0], dY.shape[1]
    T = choose_T(Lx, Ly, lam1, lam2, max_t=_max_t(launch))
    R = T >> lam1
    pad = (-Lx) % R
    if pad:
        dX = jnp.pad(dX, ((0, 0), (0, pad), (0, 0)))
    call = build_gram_fused(Bx, By, Lx + pad, Ly, d, T=T, lam1=lam1,
                            lam2=lam2, interpret=_on_cpu(), scheme=scheme,
                            interior_dtype=interior_dtype)
    return call(dX.astype(jnp.float32), dY.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def gram_fused(dX: jax.Array, dY: jax.Array, lam1: int = 0,
               lam2: int = 0, launch=None, scheme: str = "order1",
               interior_dtype: str = "float32") -> jax.Array:
    """Full Gram from increments. dX: (Bx, Lx, d), dY: (By, Ly, d) -> (Bx, By)."""
    return _gram_fused_impl(dX, dY, lam1, lam2, launch, scheme,
                            interior_dtype)


def _gram_fused_fwd(dX, dY, lam1, lam2, launch, scheme="order1",
                    interior_dtype="float32"):
    return (_gram_fused_impl(dX, dY, lam1, lam2, launch, scheme,
                             interior_dtype), (dX, dY))


def _gram_fused_bwd(lam1, lam2, launch, scheme, interior_dtype, res, gbar):
    # The reverse sweep materialises the Bx·By pairwise Δ block — bound it by
    # row-blocking the Gram (repro.core.gram), which confines this to one
    # block at a time.
    dX, dY = res
    delta = jnp.einsum("aid,bjd->abij", dX.astype(jnp.float32),
                       dY.astype(jnp.float32))
    _, cps = solve_with_grid(delta, lam1, lam2, launch, scheme,
                             interior_dtype)
    dd = solve_grad(delta, cps, gbar, lam1, lam2, launch, scheme,
                    interior_dtype)
    ddX = jnp.einsum("abij,bjd->aid", dd, dY.astype(dd.dtype))
    ddY = jnp.einsum("abij,aid->bjd", dd, dX.astype(dd.dtype))
    return ddX.astype(dX.dtype), ddY.astype(dY.dtype)


gram_fused.defvjp(_gram_fused_fwd, _gram_fused_bwd)
