"""Pallas TPU kernel: exact backward through the sig-kernel PDE (pySigLib §3.4).

One reverse wavefront pass per strip computes the adjoint

    g[a,b] = g[a,b+1]·A(Δ[a−1,b]) + g[a+1,b]·A(Δ[a,b−1]) − g[a+1,b+1]·B(Δ[a,b])

and accumulates   dΔ[i,j] += g[i+1,j+1]·[(k̂[i+1,j]+k̂[i,j+1])·A'(Δ) − k̂[i,j]·B'(Δ)]

folding refined cells back onto the unrefined Δ block.  Strips are processed
bottom-up (grid index maps reverse the strip order); the adjoint row handed to
the strip above overwrites the carried row in place (reads trail writes — the
mirror image of the forward trick).  k̂ inside the strip is RECOMPUTED from the
forward's checkpoint row — O(nx·ny/T) saved state instead of the full grid,
a beyond-paper improvement (the paper stores the full grid / recomputes fully).

Skew/lane conventions match ``kernel.py``:
cell (r, c) := refined update (i, j) = (strip_top + r, c), value k̂[i+1, c+1],
living at skew-step t = r + c, lane r.

Per-scheme adjoints (derivations in ``stencil.py``; this kernel recomputes
with the SAME stencil the forward used).  The order-2 stencil's skew reads
make cells (a−1, b+1) and (a+1, b−1) additional readers of k̂[a,b], so its
adjoint gains two −C terms::

    g[a,b] = g[a,b+1]·A(Δ[a−1,b]) + g[a+1,b]·A(Δ[a,b−1]) − g[a+1,b+1]·B₂(Δ[a,b])
             − g[a,b+2]·C(Δ[a−1,b+1]) − g[a+2,b]·C(Δ[a+1,b−1])

In lane terms the extra readers are G(r, c+2) (same lane, skew t+2 — the
``gnext2`` carry unshifted) and G(r+2, c) (two lanes down): lane T−2's reaches
row 0 of the strip below (carried ``gbrow``) and lane T−1's reaches row 1 of
the strip below, carried in a SECOND adjoint row ``gbrow2`` with coefficients
from that strip's second refined Δ row.  The dΔ accumulation gains
``− (k̂[i+1,j−1] + k̂[i−1,j+1])·C'(Δ)``; the skew k̂ reads come from the
recomputed strip (``ksk`` two skew-steps back) with lanes 1/0 falling back to
the TWO checkpoint rows (brow, brow2) the order-2 forward saves per strip.
Boundary skew reads were the constant 1 in the forward and carry no adjoint.
``interior_dtype="bfloat16"`` recomputes k̂ with the forward's rounding but
keeps every adjoint quantity f32 (straight-through gradient — see stencil.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import stencil
from .kernel import (coeff_A, coeff_B, cps_rows, skew_to_ST, _expand_dyadic,
                     vmem_scratch)


def coeff_dA(p):
    return 0.5 + p / 6.0


def coeff_dB(p):
    return -p / 6.0


def bwd_kernel(delta_ref, delta_next_ref, cps_ref, gbar_ref, ddelta_ref,
               ksk_ref, gbrow_ref, dsk_ref, gbrow2_ref=None, *,
               T: int, lam1: int, lam2: int, ny: int, Ly: int,
               scheme: str = "order1", interior_dtype: str = "float32"):
    """One (batch, reversed-strip) grid step of the exact backward pass."""
    s_rev = pl.program_id(1)
    n_steps = ny + T - 1
    order2 = scheme == "order2"

    @pl.when(s_rev == 0)
    def _reset():
        gbrow_ref[...] = jnp.zeros_like(gbrow_ref)
        if gbrow2_ref is not None:
            gbrow2_ref[...] = jnp.zeros_like(gbrow2_ref)

    M = _expand_dyadic(delta_ref[0], lam1, lam2)            # (T, ny)
    S_T = skew_to_ST(M, T, ny)                              # (ny+T, T)
    S_Tp = jnp.pad(S_T, ((0, 2), (0, 0)))                   # safe t+2 reads
    scale = 2.0 ** (-(lam1 + lam2))
    # first refined Δ row of the strip below (coefficients for lane T-1)
    d_next = jnp.repeat(delta_next_ref[0, 0:1, :], 2 ** lam2, axis=1) * scale
    d_nextp = jnp.pad(d_next, ((0, 0), (0, T + 3)))         # (1, ny + T + 3)
    if order2:
        # second refined Δ row of the strip below (lane T-1's G(r+2, c) term)
        row2 = 0 if lam1 else 1
        d_next2 = jnp.repeat(delta_next_ref[0, row2:row2 + 1, :],
                             2 ** lam2, axis=1) * scale
        d_next2p = jnp.pad(d_next2, ((0, 0), (0, T + 3)))

    lane = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    zeros = jnp.zeros((1, T), jnp.float32)

    # ---- phase 1: recompute strip interior k̂ from the checkpoint row -------
    def fstep(t, carry):
        prev, prev2 = carry
        p = jax.lax.dynamic_slice(S_T, (t, 0), (1, T))
        up0 = cps_ref[0, 0, t + 1]
        upleft0 = cps_ref[0, 0, t]
        shift_prev = jnp.where(lane == 0, up0, jnp.roll(prev, 1, axis=1))
        shift_prev2 = jnp.where(lane == 0, upleft0, jnp.roll(prev2, 1, axis=1))
        left = jnp.where(lane == t, 1.0, prev)
        upleft = jnp.where(lane == t, 1.0, shift_prev2)
        if order2:
            # same data-gridline fallback as the forward (kernel.py)
            edge = (lane % (1 << lam1) == 0) | ((t - lane) % (1 << lam2) == 0)
            k_dl = jnp.where(lane >= t - 1, 1.0, prev2)
            k_ul = jnp.roll(prev2, 2, axis=1)
            k_ul = jnp.where(lane == 1, cps_ref[0, 0, t], k_ul)
            k_ul = jnp.where(lane == 0, cps_ref[0, 1, t + 1], k_ul)
            cur = ((left + shift_prev) * coeff_A(p)
                   - upleft * stencil.coeff_B2_at(p, edge)
                   - (k_dl + k_ul) * stencil.coeff_C2_at(p, edge))
        else:
            cur = (left + shift_prev) * coeff_A(p) - upleft * coeff_B(p)
        cur = stencil.round_interior(cur, interior_dtype)
        active = (lane <= t) & (lane > t - ny)
        cur = jnp.where(active, cur, 0.0)
        pl.store(ksk_ref, (pl.ds(t, 1), pl.ds(0, T)), cur)
        return (cur, prev)

    jax.lax.fori_loop(0, n_steps, fstep, (zeros, zeros))

    # ---- phase 2: reverse adjoint wavefront --------------------------------
    gbar = gbar_ref[0]

    def bstep(i, carry):
        t = n_steps - 1 - i
        gnext, gnext2 = carry                               # G at skew t+1, t+2
        cT = jnp.maximum(t - (T - 1), 0)                    # column of lane T-1

        p_c = jax.lax.dynamic_slice(S_Tp, (t, 0), (1, T))       # Δ(r, c)
        p_a = jax.lax.dynamic_slice(S_Tp, (t + 1, 0), (1, T))   # Δ(r, c+1)
        p_t2 = jax.lax.dynamic_slice(S_Tp, (t + 2, 0), (1, T))  # Δ(r, c+2)
        p_r1 = jnp.roll(p_a, -1, axis=1)                        # Δ(r+1, c)
        p_r1c1 = jnp.roll(p_t2, -1, axis=1)                     # Δ(r+1, c+1)
        # lane T-1 coefficients come from the strip below
        p_r1 = jnp.where(lane == T - 1, d_nextp[0, cT], p_r1)
        p_r1c1 = jnp.where(lane == T - 1, d_nextp[0, cT + 1], p_r1c1)

        g_right = gnext                                     # G(r, c+1)
        g_down = jnp.roll(gnext, -1, axis=1)                # G(r+1, c)
        g_downright = jnp.roll(gnext2, -1, axis=1)          # G(r+1, c+1)
        g_down = jnp.where(lane == T - 1, gbrow_ref[0, cT + 1], g_down)
        g_downright = jnp.where(lane == T - 1, gbrow_ref[0, cT + 2], g_downright)

        if order2:
            # extra readers of k̂[a,b]: the cells whose skew neighbour it was
            cT2 = jnp.maximum(t - (T - 2), 0)               # column of lane T-2
            p_r2 = jnp.roll(p_t2, -2, axis=1)               # Δ(r+2, c)
            p_r2 = jnp.where(lane == T - 2, d_nextp[0, cT2], p_r2)
            p_r2 = jnp.where(lane == T - 1, d_next2p[0, cT], p_r2)
            g_right2 = gnext2                               # G(r, c+2)
            g_down2 = jnp.roll(gnext2, -2, axis=1)          # G(r+2, c)
            g_down2 = jnp.where(lane == T - 2, gbrow_ref[0, cT2 + 1], g_down2)
            g_down2 = jnp.where(lane == T - 1, gbrow2_ref[0, cT + 1], g_down2)
            # per-WRITER gridline fallback (stencil.py): writer cells are
            # (r+1, c+1) for the −B term, (r, c+2) / (r+2, c) for the −C
            # terms; global row ≡ lane row (mod 2^λ1) because T is a
            # multiple of 2^λ1, so the masks hold across strip boundaries
            m1, m2 = 1 << lam1, 1 << lam2
            col = t - lane
            edge_b = ((lane + 1) % m1 == 0) | ((col + 1) % m2 == 0)
            edge_cr = (lane % m1 == 0) | ((col + 2) % m2 == 0)
            edge_cd = ((lane + 2) % m1 == 0) | (col % m2 == 0)
            cur = (g_right * coeff_A(p_a) + g_down * coeff_A(p_r1)
                   - g_downright * stencil.coeff_B2_at(p_r1c1, edge_b)
                   - g_right2 * stencil.coeff_C2_at(p_t2, edge_cr)
                   - g_down2 * stencil.coeff_C2_at(p_r2, edge_cd))
        else:
            cur = (g_right * coeff_A(p_a) + g_down * coeff_A(p_r1)
                   - g_downright * coeff_B(p_r1c1))
        # seed ∂F/∂k̂[nx, ny] at the bottom-right cell of the bottom strip
        seed_here = (s_rev == 0) & (t == n_steps - 1)
        cur = cur + jnp.where(seed_here & (lane == T - 1), gbar, 0.0)
        active = (lane <= t) & (lane > t - ny)
        cur = jnp.where(active, cur, 0.0)

        # ---- dΔ contribution of cells on this anti-diagonal ----
        k_tm1 = pl.load(ksk_ref, (pl.ds(jnp.maximum(t - 1, 0), 1), pl.ds(0, T)))
        k_tm2 = pl.load(ksk_ref, (pl.ds(jnp.maximum(t - 2, 0), 1), pl.ds(0, T)))
        k_left = jnp.where(lane == t, 1.0, k_tm1)               # k̂[i+1, j]
        k_up = jnp.where(lane == 0, cps_ref[0, 0, jnp.minimum(t + 1, ny + T)],
                         jnp.roll(k_tm1, 1, axis=1))            # k̂[i, j+1]
        k_upleft = jnp.where(lane == 0, cps_ref[0, 0, jnp.minimum(t, ny + T)],
                             jnp.roll(k_tm2, 1, axis=1))
        k_upleft = jnp.where(lane == t, 1.0, k_upleft)          # k̂[i, j]
        if order2:
            k_dl = jnp.where(lane >= t - 1, 1.0, k_tm2)         # k̂[i+1, j-1]
            k_ul = jnp.roll(k_tm2, 2, axis=1)                   # k̂[i-1, j+1]
            k_ul = jnp.where(lane == 1,
                             cps_ref[0, 0, jnp.minimum(t, ny + T)], k_ul)
            k_ul = jnp.where(lane == 0,
                             cps_ref[0, 1, jnp.minimum(t + 1, ny + T)], k_ul)
            # dΔ selects on the contributing cell (r, c) itself
            edge_cell = (lane % (1 << lam1) == 0) \
                | ((t - lane) % (1 << lam2) == 0)
            contrib = cur * ((k_left + k_up) * coeff_dA(p_c)
                             - k_upleft * stencil.coeff_dB2_at(p_c, edge_cell)
                             - (k_dl + k_ul)
                             * stencil.coeff_dC2_at(p_c, edge_cell))
        else:
            contrib = cur * ((k_left + k_up) * coeff_dA(p_c)
                             - k_upleft * coeff_dB(p_c))
        contrib = jnp.where(active, contrib, 0.0)
        pl.store(dsk_ref, (pl.ds(t, 1), pl.ds(0, T)), contrib)

        # hand the r = 0 adjoint row up to the strip above (in-place; reads at
        # indices <= t-T+3 trail these writes in the reverse loop)
        @pl.when(t <= ny - 1)
        def _():
            gbrow_ref[0, t + 1] = cur[0, 0]

        if order2:
            # hand the r = 1 adjoint row up as well (lane T-1's G(r+2, c))
            @pl.when((t >= 1) & (t <= ny))
            def _():
                gbrow2_ref[0, t] = cur[0, 1]

        return (cur, gnext)

    jax.lax.fori_loop(0, n_steps, bstep, (zeros, zeros))

    # ---- phase 3: unskew + dyadic fold -> unrefined dΔ block ----------------
    U = dsk_ref[...].T                                      # (T, n_steps)
    rows = [jax.lax.dynamic_slice(U, (r, r), (1, ny)) for r in range(T)]
    dM = jnp.concatenate(rows, axis=0)                      # (T, ny)
    if lam1 or lam2:
        dM = dM.reshape(T >> lam1, 1 << lam1, Ly, 1 << lam2).sum((1, 3))
    dM = dM * scale
    ddelta_ref[0] = dM.astype(ddelta_ref.dtype)


def build_bwd(batch: int, Lx: int, Ly: int, *, T: int, lam1: int, lam2: int,
              interpret: bool, scheme: str = "order1",
              interior_dtype: str = "float32"):
    from .kernel import check_strip
    R = check_strip(T, lam1, Lx, scheme)
    n_strips = Lx // R
    nx, ny = Lx << lam1, Ly << lam2
    n_steps = ny + T - 1
    rows = cps_rows(scheme)

    kern = functools.partial(bwd_kernel, T=T, lam1=lam1, lam2=lam2, ny=ny,
                             Ly=Ly, scheme=scheme,
                             interior_dtype=interior_dtype)

    def rev(s):
        return n_strips - 1 - s

    scratch = [
        vmem_scratch((n_steps, T)),        # recomputed k̂ (skewed)
        vmem_scratch((1, ny + T + 3)),     # carried adjoint row
        vmem_scratch((n_steps, T)),        # dΔ accumulator (skewed)
    ]
    if scheme == "order2":
        scratch.append(vmem_scratch((1, ny + T + 3)))  # carried row-1 adjoint

    return pl.pallas_call(
        kern,
        grid=(batch, n_strips),
        in_specs=[
            pl.BlockSpec((1, R, Ly), lambda b, s: (b, rev(s), 0)),
            pl.BlockSpec((1, R, Ly),
                         lambda b, s: (b, jnp.minimum(rev(s) + 1, n_strips - 1), 0)),
            pl.BlockSpec((1, rows, ny + T + 1), lambda b, s: (b, rev(s), 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, R, Ly), lambda b, s: (b, rev(s), 0)),
        out_shape=jax.ShapeDtypeStruct((batch, Lx, Ly), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )
