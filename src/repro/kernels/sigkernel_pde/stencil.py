"""Pluggable Goursat cell-update stencils (+ mixed-precision rounding).

Every PDE backend (reference row scan, antidiag wavefront, Pallas strip
kernels and their fused variants) consumes the SAME coefficient set from
here, so a scheme is implemented once and the backends stay consistent —
``GridConfig.scheme`` picks the stencil, ``GridConfig.interior_dtype`` the
interior storage precision, both static.

Schemes
-------

``order1`` (default — the paper's eq. (1) discretisation, bitwise-identical
to the historical solvers)::

    k̂_{i+1,j+1} = (k̂_{i+1,j} + k̂_{i,j+1})·A(p) − k̂_{i,j}·B(p)
    A(p) = 1 + p/2 + p²/12,   B(p) = 1 − p²/12,   p = refined Δ cell.

``order2`` (anti-diagonal curvature correction, after "Numerical Schemes for
Signature Kernels", arxiv 2502.08470): the order-1 update drops a
(p/12)·h²(∂²_s + ∂²_t)k truncation term; estimate it from the two
anti-diagonal neighbours already inside the wavefront's working set,

    h²(k_ss + k_tt) ≈ k̂_{i+1,j−1} + k̂_{i−1,j+1} − 2·k̂_{i,j} + 2p·k̂_{i,j}

(the Taylor sum of the skew neighbours gives h²(k_ss + k_tt − 2k_st), and
the PDE k_st = Δ·k replaces the mixed term by 2p·k̂), and subtract it::

    k̂_{i+1,j+1} = (k̂_{i+1,j} + k̂_{i,j+1})·A(p) − k̂_{i,j}·B₂(p)
                  − C(p)·(k̂_{i+1,j−1} + k̂_{i−1,j+1})
    B₂(p) = 1 − p/6 + p²/12,   C(p) = p/12.

Cells on unrefined data gridlines fall back to order-1.  Δ is
piecewise-constant per *unrefined* cell (the paths are piecewise linear),
so k_ss / k_tt carry kinks along every data gridline — including the k ≡ 1
axes, where the constant extension of the path kinks too.  A writer
k̂_{i+1,j+1} whose skew reads straddle such a line (``i % 2^λ1 == 0 or
j % 2^λ2 == 0`` in refined coordinates) would difference across the kink,
injecting an O(h²) error along O(h⁻¹)-cell strips that drags the whole
solve back below first order (empirically *worse* than order-1).  Those
writers use the order-1 coefficients (B₁, no C) instead: O(h⁴) local error
on the O(h⁻¹) gridline cells keeps the interior order.  The
``coeff_*_at(p, edge)`` helpers below select per-cell so every backend
applies the same rule.  Consequences: ``order2`` differs from ``order1``
only when both λ1 ≥ 1 and λ2 ≥ 1 (at λ = 0 every refined line is a data
line, and the schemes coincide bitwise — docs/solver_guide.md); end-aligned
ragged padding ends on a data gridline, so the ragged kink is handled by
the same rule, and since B₂(0) = B₁(0) = 1 and C(0) = 0, zero-Δ padding
still leaves the solution bitwise invariant, preserving the ragged /
strip-padding exactness arguments of the order-1 solvers unchanged.  The
correction is symmetric in the two skew neighbours and the gridline rule
swaps with (i, λ1) ↔ (j, λ2), so the antidiag backend's lane-transpose
(nx > ny) stays valid.

Exact adjoints (one-pass backward, per scheme)
----------------------------------------------

Differentiating the *recurrence* (not the PDE) gives, with
g[a,b] = ∂F/∂k̂[a,b] and out-of-grid g ≡ 0:

order1::

    g[a,b] = g[a,b+1]·A(p[a−1,b]) + g[a+1,b]·A(p[a,b−1])
             − g[a+1,b+1]·B(p[a,b])
    dΔ[i,j] += g[i+1,j+1]·[(k̂_{i+1,j}+k̂_{i,j+1})·A'(p) − k̂_{i,j}·B'(p)]
    A'(p) = 1/2 + p/6,   B'(p) = −p/6.

order2 — two extra terms, because cells (a−1, b+1) and (a+1, b−1) also read
k̂[a,b] (as their k_dl / k_ul skew neighbours, coefficient −C)::

    g[a,b] = g[a,b+1]·A(p[a−1,b]) + g[a+1,b]·A(p[a,b−1])
             − g[a+1,b+1]·B?(p[a,b])
             − g[a,b+2]·C?(p[a−1,b+1]) − g[a+2,b]·C?(p[a+1,b−1])
    dΔ[i,j] += g[i+1,j+1]·[(k̂_{i+1,j}+k̂_{i,j+1})·A'(p) − k̂_{i,j}·B?'(p)
                            − (k̂_{i+1,j−1}+k̂_{i−1,j+1})·C?'(p)]
    B₂'(p) = −1/6 + p/6,   C'(p) = 1/12.

``B?``/``C?`` are each *writer's own* per-cell selection (the adjoint of a
per-cell-selected forward selects per writer), with
``edge(i, j) = (i % 2^λ1 == 0) | (j % 2^λ2 == 0)`` on cell indices: the
−B term from writer (a+1, b+1) uses B₁ iff ``edge(a, b)``; the −C term
from writer (a, b+2) (a cell (a−1, b+1) write) exists iff
``not edge(a−1, b+1)``, and the one from writer (a+2, b) (a cell
(a+1, b−1) write) iff ``not edge(a+1, b−1)``; the dΔ row selects on the
contributing cell (i, j) itself.  Gridline skew reads appear in dΔ with
exactly the value the forward used — the backward is the exact adjoint of
the discrete forward map, FD-checked per (scheme, backend) in
tests/test_schemes.py.

Mixed precision
---------------

``round_interior(x, "bfloat16")`` rounds interior cell values through bf16
after every update; all arithmetic, the boundary of ones, carried boundary
rows and the readout stay f32 (the contract PR 5's f32 time-grid finding
motivates).  The rounding carries an explicit straight-through gradient
(``jax.custom_vjp`` identity), so each scheme's one-pass backward above IS
the exact adjoint of the rounded forward with full-precision cotangents —
asserted against ``jax.grad`` of the rounded reference solver in
tests/test_schemes.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: cell-update stencils implemented by every exact PDE backend
SCHEMES = ("order1", "order2")

#: interior-cell storage precisions (boundary/readout always f32)
INTERIOR_DTYPES = ("float32", "bfloat16")


def check_scheme(scheme: str) -> str:
    """Validate a scheme name (the kernels' static argument)."""
    if scheme not in SCHEMES:
        raise ValueError(
            f"unknown Goursat scheme {scheme!r}: GridConfig.scheme must be "
            f"one of {SCHEMES}")
    return scheme


def check_interior_dtype(interior_dtype: str) -> str:
    """Validate an interior-dtype name (the kernels' static argument)."""
    if interior_dtype not in INTERIOR_DTYPES:
        raise ValueError(
            f"unknown interior dtype {interior_dtype!r}: "
            f"GridConfig.interior_dtype must be one of {INTERIOR_DTYPES}")
    return interior_dtype


# ---------------------------------------------------------------------------
# forward coefficients
# ---------------------------------------------------------------------------

def coeff_A(p):
    return 1.0 + 0.5 * p + (1.0 / 12.0) * p * p


def coeff_B1(p):
    return 1.0 - (1.0 / 12.0) * p * p


def coeff_B2(p):
    return 1.0 - (1.0 / 6.0) * p + (1.0 / 12.0) * p * p


def coeff_C2(p):
    return (1.0 / 12.0) * p


def coeff_B(p, scheme: str = "order1"):
    """Scheme-dispatched k̂_{i,j} coefficient (B for order1, B₂ for order2)."""
    return coeff_B2(p) if scheme == "order2" else coeff_B1(p)


def coeff_B2_at(p, edge):
    """Per-cell B for order2: B₁ where ``edge`` (order-1 fallback), else B₂.

    ``edge`` marks cells (i, j) with ``i % 2^λ1 == 0 or j % 2^λ2 == 0`` —
    writers whose skew reads would straddle a data-gridline kink (module
    docstring).
    """
    return jnp.where(edge, coeff_B1(p), coeff_B2(p))


def coeff_C2_at(p, edge):
    """Per-cell C for order2: 0 where ``edge`` (order-1 fallback), else C."""
    return jnp.where(edge, jnp.zeros_like(p), coeff_C2(p))


# ---------------------------------------------------------------------------
# adjoint (dΔ) coefficients — derivatives of the above w.r.t. p
# ---------------------------------------------------------------------------

def coeff_dA(p):
    return 0.5 + p / 6.0


def coeff_dB1(p):
    return -p / 6.0


def coeff_dB2(p):
    return -1.0 / 6.0 + p / 6.0


def coeff_dC2(p):
    return jnp.full_like(p, 1.0 / 12.0)


def coeff_dB(p, scheme: str = "order1"):
    """Scheme-dispatched B'(p) (B' for order1, B₂' for order2)."""
    return coeff_dB2(p) if scheme == "order2" else coeff_dB1(p)


def coeff_dB2_at(p, edge):
    """Per-cell B' for order2 dΔ: B₁' where ``edge``, else B₂'."""
    return jnp.where(edge, coeff_dB1(p), coeff_dB2(p))


def coeff_dC2_at(p, edge):
    """Per-cell C' for order2 dΔ: 0 where ``edge``, else 1/12."""
    return jnp.where(edge, jnp.zeros_like(p), coeff_dC2(p))


# ---------------------------------------------------------------------------
# mixed-precision rounding
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _round_bf16(x):
    return x.astype(jnp.bfloat16).astype(x.dtype)


def _round_bf16_fwd(x):
    return _round_bf16(x), None


def _round_bf16_bwd(_, ct):
    return (ct,)


_round_bf16.defvjp(_round_bf16_fwd, _round_bf16_bwd)


def round_interior(x, interior_dtype: str = "float32"):
    """Quantise a freshly updated interior cell per the precision contract.

    ``"float32"`` is the identity (bitwise no-op — not even a cast);
    ``"bfloat16"`` rounds through bf16 while keeping the f32 carried
    representation.  The gradient is straight-through (exact identity
    cotangent — the backward never quantises), so ``jax.grad`` of a
    rounded reference forward matches each scheme's one-pass adjoint.
    """
    if interior_dtype == "float32":
        return x
    return _round_bf16(x)
