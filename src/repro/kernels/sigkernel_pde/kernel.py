"""Pallas TPU kernel: Goursat-PDE signature-kernel solver (pySigLib §3.3).

TPU-native translation of the paper's GPU wavefront scheme (DESIGN.md §2):

* the PDE grid is swept in **row strips of T refined rows** (T = VPU lane
  count, default 128) — the analogue of the paper's 32-thread blocks;
* inside a strip the anti-diagonal wavefront advances one skew-step per loop
  iteration, carrying a **rotating pair of diagonal buffers** (``prev``,
  ``prev2``) in registers/VMEM — the analogue of the paper's 3 rotating
  anti-diagonals in CUDA shared memory;
* the strip's bottom row **overwrites the carried boundary row in place**
  (reads trail writes by T−1 steps), exactly the paper's trick of reusing the
  initial-condition vector between blocks;
* dyadic refinement is applied **on-the-fly**: Δ is expanded from the
  unrefined (R, Ly) HBM block only inside VMEM (refined Δ never exists in
  HBM), with R = T / 2^λ1 original rows per strip;
* Δ itself is precomputed OUTSIDE the kernel by one batched MXU matmul
  (paper design choice (2)) — see ``ops.py``.

Grid = (batch, n_strips); TPU grid iteration is sequential per core, so VMEM
scratch (the boundary row) persists across strips — the TPU-native replacement
for CUDA inter-block synchronisation.

In grad mode the kernel additionally emits one **checkpoint row per strip**
(k̂ at the strip's top boundary; two rows for the order-2 stencil, whose
skew reads reach one row further back).  The backward kernel recomputes the
strip interior from the checkpoint — O(nx·ny / T) activation memory instead
of the full grid, a beyond-paper improvement (the paper stores the full
grid).

Scheme support (``GridConfig.scheme`` — coefficient sets in ``stencil.py``):
the ``"order2"`` stencil reads the two anti-diagonal neighbours
k̂_{i+1,j−1} / k̂_{i−1,j+1}, both living on the ``prev2`` rotating buffer
(same lane / two lanes up).  Lane 1's k̂_{i−1,j+1} comes from the carried
boundary row and lane 0's from a SECOND carried boundary row ``brow2``
(= k̂[strip_top − 1, ·], written by each strip's row T−2, initialised to the
boundary-of-ones extension), so results are independent of the strip height
— order-2 requires T ≥ 2.  ``GridConfig.interior_dtype = "bfloat16"``
rounds every freshly computed cell through bf16 (``stencil.round_interior``)
while the carried boundary rows and the readout stay f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import stencil


def coeff_A(p):
    return 1.0 + 0.5 * p + (1.0 / 12.0) * p * p


def coeff_B(p):
    return 1.0 - (1.0 / 12.0) * p * p


def skew_to_ST(M: jax.Array, T: int, n: int) -> jax.Array:
    """(T, n) -> (n + T, T) skewed so that S_T[t, r] = M[r, t - r].

    Built with T contiguous row writes then one VMEM transpose.
    """
    S = jnp.zeros((T, n + T), M.dtype)
    for r in range(T):
        S = jax.lax.dynamic_update_slice(S, M[r:r + 1], (r, r))
    return S.T


def _expand_dyadic(blk: jax.Array, lam1: int, lam2: int) -> jax.Array:
    """On-the-fly VMEM expansion of an unrefined Δ block (R, Ly) to (T, ny)."""
    scale = 2.0 ** (-(lam1 + lam2))
    M = blk
    if lam1:
        M = jnp.repeat(M, 2 ** lam1, axis=0)
    if lam2:
        M = jnp.repeat(M, 2 ** lam2, axis=1)
    return M * scale


def fused_fwd_kernel(dx_ref, dy_ref, out_ref, brow_ref, brow2_ref=None, *,
                     T: int, lam1: int, lam2: int, ny: int,
                     scheme: str = "order1", interior_dtype: str = "float32"):
    """Fused-Δ forward: the strip's Δ block is computed ON THE FLY in VMEM as
    dx_strip @ dyᵀ (an (R, d) × (d, Ly) MXU matmul) — Δ never exists in HBM.

    Beyond-paper optimisation: pySigLib precomputes Δ with one bmm (design
    choice (2)) because on GPU the bmm is the fast path; on TPU the Goursat
    sweep is HBM-bound on streaming Δ (3·B²·L²·4 bytes for a Gram), so fusing
    the tiny-K matmul into the wavefront kernel converts the workload from
    memory-bound to compute-bound (EXPERIMENTS.md §Perf).
    """
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _reset():
        brow_ref[...] = jnp.ones_like(brow_ref)
        if brow2_ref is not None:
            brow2_ref[...] = jnp.ones_like(brow2_ref)

    blk = jnp.dot(dx_ref[0], dy_ref[0].T,
                  preferred_element_type=jnp.float32)      # (R, Ly) in VMEM
    _wavefront(blk, out_ref, None, brow_ref, brow2_ref, T=T, lam1=lam1,
               lam2=lam2, ny=ny, save_cps=False, scheme=scheme,
               interior_dtype=interior_dtype)


def fwd_kernel(delta_ref, out_ref, cps_ref, brow_ref, brow2_ref=None, *,
               T: int, lam1: int, lam2: int, ny: int, save_cps: bool,
               scheme: str = "order1", interior_dtype: str = "float32"):
    """One (batch, strip) grid step of the forward wavefront solver.

    delta_ref: (1, R, Ly) unrefined Δ rows of this strip (VMEM block).
    out_ref:   (1,) final kernel value k̂[nx, ny] (written every strip;
               the last strip's write is the result).
    cps_ref:   (1, cps_rows, ny + T + 1) checkpoint rows (grad mode only):
               row 0 = brow; row 1 (order-2 only) = brow2.
    brow_ref:  (1, ny + T + 1) scratch — carried boundary row
               brow[c] = k̂[strip_top, c]; persists across grid steps.
    brow2_ref: (1, ny + T + 1) scratch (order-2 only) — the row above it,
               brow2[c] = k̂[strip_top − 1, c] (ones above the first strip).
    """
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _reset():
        brow_ref[...] = jnp.ones_like(brow_ref)
        if brow2_ref is not None:
            brow2_ref[...] = jnp.ones_like(brow2_ref)

    if save_cps:
        cps_ref[0, 0, :] = brow_ref[0, :]
        if brow2_ref is not None:
            cps_ref[0, 1, :] = brow2_ref[0, :]

    _wavefront(delta_ref[0], out_ref, cps_ref, brow_ref, brow2_ref, T=T,
               lam1=lam1, lam2=lam2, ny=ny, save_cps=save_cps, scheme=scheme,
               interior_dtype=interior_dtype)


def _wavefront(blk, out_ref, cps_ref, brow_ref, brow2_ref=None, *, T, lam1,
               lam2, ny, save_cps, scheme="order1",
               interior_dtype="float32"):
    """Anti-diagonal sweep of one strip given its unrefined Δ block (R, Ly)."""
    M = _expand_dyadic(blk, lam1, lam2)                # (T, ny)
    S_T = skew_to_ST(M, T, ny)                         # (ny+T, T): [t, r] = Δ(r, t-r)

    order2 = scheme == "order2"
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)

    def step(t, carry):
        prev, prev2 = carry                            # (1, T) f32
        p = jax.lax.dynamic_slice(S_T, (t, 0), (1, T))  # anti-diagonal of Δ
        A = coeff_A(p)
        up0 = brow_ref[0, t + 1]
        upleft0 = brow_ref[0, t]
        shift_prev = jnp.where(lane == 0, up0, jnp.roll(prev, 1, axis=1))
        shift_prev2 = jnp.where(lane == 0, upleft0, jnp.roll(prev2, 1, axis=1))
        left = jnp.where(lane == t, 1.0, prev)
        upleft = jnp.where(lane == t, 1.0, shift_prev2)
        if order2:
            # Skew neighbours both sit two wavefront steps back (prev2):
            # k_dl = k̂[i+1, c−1] is prev2 at the SAME lane (:= 1 for c ≤ 1 —
            # the boundary of ones extends); k_ul = k̂[i−1, c+1] is prev2 two
            # lanes up, with lanes 1/0 reading the carried boundary rows
            # (brow[t] = k̂[strip_top, t], brow2[t+1] = k̂[strip_top−1, t+1]).
            # Data-gridline fallback (stencil.py): global row = strip·T +
            # lane and T ≡ 0 (mod 2^λ1), so the row test is lane % 2^λ1;
            # the column is c = t − lane.
            edge = (lane % (1 << lam1) == 0) | ((t - lane) % (1 << lam2) == 0)
            k_dl = jnp.where(lane >= t - 1, 1.0, prev2)
            k_ul = jnp.roll(prev2, 2, axis=1)
            k_ul = jnp.where(lane == 1, brow_ref[0, t], k_ul)
            k_ul = jnp.where(lane == 0, brow2_ref[0, t + 1], k_ul)
            cur = ((left + shift_prev) * A
                   - upleft * stencil.coeff_B2_at(p, edge)
                   - (k_dl + k_ul) * stencil.coeff_C2_at(p, edge))
        else:
            cur = (left + shift_prev) * A - upleft * coeff_B(p)
        cur = stencil.round_interior(cur, interior_dtype)
        active = (lane <= t) & (lane > t - ny)
        cur = jnp.where(active, cur, 0.0)

        # bottom strip row becomes next strip's boundary: in-place overwrite,
        # reads (index t+1) trail writes (index t-T+2) by T-1 steps.
        @pl.when(t >= T - 1)
        def _():
            brow_ref[0, t - T + 2] = cur[0, T - 1]

        if order2:
            # row T−2 becomes next strip's brow2 (k̂[next_top − 1, ·]); the
            # lane-0 read (index t+1) never trails this write for T ≥ 2.
            @pl.when(t >= T - 2)
            def _():
                brow2_ref[0, t - T + 3] = cur[0, T - 2]

        return (cur, prev)

    zeros = jnp.zeros((1, T), jnp.float32)
    jax.lax.fori_loop(0, ny + T - 1, step, (zeros, zeros))

    # after the strip, brow[ny] = k̂[strip_bottom, ny]; last strip ⇒ k̂[nx, ny].
    if out_ref is not None:
        out_ref[0] = brow_ref[0, ny]



def check_strip(T: int, lam1: int, Lx: int, scheme: str = "order1") -> int:
    """Validate strip geometry; return R = T >> lam1 (unrefined rows/strip).

    Raises ValueError (not a bare assert) naming the offending shape and the
    LaunchConfig knob that lifts the limit.
    """
    R = T >> lam1
    if R < 1 or R << lam1 != T:
        raise ValueError(
            f"Goursat strip height T={T} must be a power-of-two multiple of "
            f"the dyadic refinement 2**lam1={1 << lam1} — raise "
            f"LaunchConfig.pde_strip (or lower lam1); the default cap is "
            f"{128}")
    if Lx % R != 0:
        raise ValueError(
            f"Lx={Lx} rows are not a multiple of the R={R} unrefined rows "
            f"per strip (T={T}, lam1={lam1}) — the ops.py wrappers zero-pad "
            f"to the strip automatically; when calling the builders directly "
            f"pad Lx or pick a LaunchConfig.pde_strip dividing it")
    if scheme == "order2" and T < 2:
        raise ValueError(
            f"Goursat strip height T={T} cannot run the order-2 stencil, "
            f"whose skew reads span two refined rows — set "
            f"LaunchConfig.pde_strip >= 2 (or scheme='order1')")
    return R


def _scratch_rows(ny: int, T: int, scheme: str):
    """Carried-boundary scratch: one row for order-1, two for order-2."""
    rows = [vmem_scratch((1, ny + T + 1))]
    if scheme == "order2":
        rows.append(vmem_scratch((1, ny + T + 1)))
    return rows


def cps_rows(scheme: str) -> int:
    """Checkpoint rows per strip (brow, plus brow2 for the order-2 stencil)."""
    return 2 if scheme == "order2" else 1


def build_fwd(batch: int, Lx: int, Ly: int, *, T: int, lam1: int, lam2: int,
              save_cps: bool, interpret: bool, scheme: str = "order1",
              interior_dtype: str = "float32"):
    """Construct the pallas_call for the forward solver.

    Lx must be a multiple of R = T >> lam1 (ops.py zero-pads: Δ = 0 rows/cols
    leave the Goursat solution invariant since A(0) = B(0) = 1; the order-2
    stencil preserves this because B₂(0) = 1 and C(0) = 0).
    """
    R = check_strip(T, lam1, Lx, scheme)
    n_strips = Lx // R
    ny = Ly << lam2
    rows = cps_rows(scheme)

    if save_cps:
        kern = functools.partial(fwd_kernel, T=T, lam1=lam1, lam2=lam2, ny=ny,
                                 save_cps=True, scheme=scheme,
                                 interior_dtype=interior_dtype)
    elif scheme == "order2":
        def kern(delta_ref, out_ref, brow_ref, brow2_ref):
            fwd_kernel(delta_ref, out_ref, None, brow_ref, brow2_ref,
                       T=T, lam1=lam1, lam2=lam2, ny=ny, save_cps=False,
                       scheme=scheme, interior_dtype=interior_dtype)
    else:
        def kern(delta_ref, out_ref, brow_ref):
            fwd_kernel(delta_ref, out_ref, None, brow_ref,
                       T=T, lam1=lam1, lam2=lam2, ny=ny, save_cps=False,
                       scheme=scheme, interior_dtype=interior_dtype)

    out_shapes = [jax.ShapeDtypeStruct((batch,), jnp.float32)]
    out_specs = [pl.BlockSpec((1,), lambda b, s: (b,))]
    if save_cps:
        # rows checkpoint rows per strip, folded into one axis so the order-1
        # layout (rows = 1) stays bitwise-identical to the historical one.
        out_shapes.append(jax.ShapeDtypeStruct(
            (batch, n_strips * rows, ny + T + 1), jnp.float32))
        out_specs.append(
            pl.BlockSpec((1, rows, ny + T + 1), lambda b, s: (b, s, 0)))

    return pl.pallas_call(
        kern,
        grid=(batch, n_strips),
        in_specs=[pl.BlockSpec((1, R, Ly), lambda b, s: (b, s, 0))],
        out_specs=out_specs if save_cps else out_specs[0],
        out_shape=out_shapes if save_cps else out_shapes[0],
        scratch_shapes=_scratch_rows(ny, T, scheme),
        interpret=interpret,
    )


def build_fwd_fused(batch: int, Lx: int, Ly: int, d: int, *, T: int,
                    lam1: int, lam2: int, interpret: bool,
                    scheme: str = "order1", interior_dtype: str = "float32"):
    """Fused-Δ forward: inputs are increments dx (B, Lx, d), dy (B, Ly, d)."""
    R = check_strip(T, lam1, Lx, scheme)
    n_strips = Lx // R
    ny = Ly << lam2
    kern = functools.partial(fused_fwd_kernel, T=T, lam1=lam1, lam2=lam2,
                             ny=ny, scheme=scheme,
                             interior_dtype=interior_dtype)
    return pl.pallas_call(
        kern,
        grid=(batch, n_strips),
        in_specs=[pl.BlockSpec((1, R, d), lambda b, s: (b, s, 0)),
                  pl.BlockSpec((1, Ly, d), lambda b, s: (b, 0, 0))],
        out_specs=pl.BlockSpec((1,), lambda b, s: (b,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        scratch_shapes=_scratch_rows(ny, T, scheme),
        interpret=interpret,
    )


def fused_gram_kernel(dx_ref, dy_ref, out_ref, brow_ref, brow2_ref=None, *,
                      T: int, lam1: int, lam2: int, ny: int,
                      scheme: str = "order1", interior_dtype: str = "float32"):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _reset():
        brow_ref[...] = jnp.ones_like(brow_ref)
        if brow2_ref is not None:
            brow2_ref[...] = jnp.ones_like(brow2_ref)

    blk = jnp.dot(dx_ref[0], dy_ref[0].T,
                  preferred_element_type=jnp.float32)
    _wavefront(blk, None, None, brow_ref, brow2_ref, T=T, lam1=lam1,
               lam2=lam2, ny=ny, save_cps=False, scheme=scheme,
               interior_dtype=interior_dtype)
    out_ref[0, 0] = brow_ref[0, ny]


def build_gram_fused(Bx: int, By: int, Lx: int, Ly: int, d: int, *, T: int,
                     lam1: int, lam2: int, interpret: bool,
                     scheme: str = "order1", interior_dtype: str = "float32"):
    """Fused-Δ Gram: grid over (row path, col path, strip); dx/dy blocks are
    fetched from the ORIGINAL increment arrays by index map — neither Δ nor
    any pairwise replication of the paths ever exists in HBM."""
    R = check_strip(T, lam1, Lx, scheme)
    n_strips = Lx // R
    ny = Ly << lam2
    kern = functools.partial(fused_gram_kernel, T=T, lam1=lam1, lam2=lam2,
                             ny=ny, scheme=scheme,
                             interior_dtype=interior_dtype)
    return pl.pallas_call(
        kern,
        grid=(Bx, By, n_strips),
        in_specs=[pl.BlockSpec((1, R, d), lambda a, b, s: (a, s, 0)),
                  pl.BlockSpec((1, Ly, d), lambda a, b, s: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda a, b, s: (a, b)),
        out_shape=jax.ShapeDtypeStruct((Bx, By), jnp.float32),
        scratch_shapes=_scratch_rows(ny, T, scheme),
        interpret=interpret,
    )


def vmem_scratch(shape, dtype=jnp.float32):
    """VMEM scratch allocator (TPU target; also honoured by interpret mode)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
