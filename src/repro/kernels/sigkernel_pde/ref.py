"""Pure-jnp oracle for the sig-kernel PDE Pallas kernels.

Delegates to the independently-written row-scan reference in
``repro.core.sigkernel`` (which is itself validated against truncated
signature inner products and autodiff).  ``scheme`` / ``interior_dtype``
select the cell-update stencil and interior precision (``stencil.py``) and
are honoured identically by the Pallas kernels.
"""

from __future__ import annotations

import jax

from repro.core.sigkernel import (solve_goursat, solve_goursat_grad)


def solve(delta: jax.Array, lam1: int = 0, lam2: int = 0,
          scheme: str = "order1",
          interior_dtype: str = "float32") -> jax.Array:
    """Final kernel values k̂[nx, ny] for a batch of Δ matrices (..., Lx, Ly)."""
    return solve_goursat(delta, lam1, lam2, scheme=scheme,
                         interior_dtype=interior_dtype)


def solve_grid(delta: jax.Array, lam1: int = 0, lam2: int = 0,
               scheme: str = "order1",
               interior_dtype: str = "float32") -> jax.Array:
    """Full refined PDE grids (..., nx+1, ny+1)."""
    return solve_goursat(delta, lam1, lam2, return_grid=True, scheme=scheme,
                         interior_dtype=interior_dtype)


def solve_grad(delta: jax.Array, gbar: jax.Array, lam1: int = 0,
               lam2: int = 0, scheme: str = "order1",
               interior_dtype: str = "float32") -> jax.Array:
    """Exact ∂F/∂Δ (Alg 4) given upstream cotangents gbar (...,)."""
    grid = solve_goursat(delta, lam1, lam2, return_grid=True, scheme=scheme,
                         interior_dtype=interior_dtype)
    return solve_goursat_grad(delta, grid, gbar, lam1, lam2, scheme=scheme,
                              interior_dtype=interior_dtype)
