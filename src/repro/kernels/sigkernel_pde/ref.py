"""Pure-jnp oracle for the sig-kernel PDE Pallas kernels.

Delegates to the independently-written row-scan reference in
``repro.core.sigkernel`` (which is itself validated against truncated
signature inner products and autodiff).
"""

from __future__ import annotations

import jax

from repro.core.sigkernel import (solve_goursat, solve_goursat_grad)


def solve(delta: jax.Array, lam1: int = 0, lam2: int = 0) -> jax.Array:
    """Final kernel values k̂[nx, ny] for a batch of Δ matrices (..., Lx, Ly)."""
    return solve_goursat(delta, lam1, lam2)


def solve_grid(delta: jax.Array, lam1: int = 0, lam2: int = 0) -> jax.Array:
    """Full refined PDE grids (..., nx+1, ny+1)."""
    return solve_goursat(delta, lam1, lam2, return_grid=True)


def solve_grad(delta: jax.Array, gbar: jax.Array, lam1: int = 0,
               lam2: int = 0) -> jax.Array:
    """Exact ∂F/∂Δ (Alg 4) given upstream cotangents gbar (...,)."""
    grid = solve_goursat(delta, lam1, lam2, return_grid=True)
    return solve_goursat_grad(delta, grid, gbar, lam1, lam2)
