"""RecurrentGemma-2B — RG-LRU recurrent blocks + local attention, pattern
(rec, rec, attn) [arXiv:2402.19427]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, act="gelu", head_dim=256,
    lru_width=2560, attn_window=2048,
    block_pattern=("rec", "rec", "attn"), rope_theta=1e4,
))
