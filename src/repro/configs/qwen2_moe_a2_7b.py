"""Qwen1.5-MoE-A2.7B — 4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, qkv_bias=True, act="silu",
    n_experts=60, n_experts_per_tok=4, n_shared_experts=4, moe_d_ff=1408,
    rope_theta=1e6,
))
