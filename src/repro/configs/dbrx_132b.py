"""DBRX-132B — fine-grained 16-expert top-4 MoE [hf:databricks/dbrx-base]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, act="silu",
    n_experts=16, n_experts_per_tok=4, moe_d_ff=10752,
    rope_theta=5e5, moment_dtype="bfloat16",
))
