"""Granite-34B-code — llama-arch with MQA (kv=1) [arXiv:2405.04324]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, act="silu", rope_theta=1e5,
))
