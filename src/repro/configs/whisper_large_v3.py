"""Whisper-large-v3 — encoder-decoder backbone; conv/mel frontend is a stub
providing 1500 precomputed frame embeddings [arXiv:2212.04356]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, act="gelu", n_audio_frames=1500,
    rope_theta=1e4,
))
