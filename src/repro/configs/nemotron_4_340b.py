"""Nemotron-4-340B — dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000, act="relu2", rope_theta=1e4,
    # 340B on a 16 GiB/chip pod: bf16 master (TPU stochastic rounding) +
    # bf16 Adam moments — 8 B/param of optimizer state instead of 16
    moment_dtype="bfloat16", param_dtype="bfloat16",
))
