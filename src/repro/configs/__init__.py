"""Architecture configs (exact public configurations) + registry population."""
from . import (qwen2_moe_a2_7b, dbrx_132b, internvl2_76b, whisper_large_v3,
               mamba2_780m, qwen2_72b, granite_34b, deepseek_7b,
               nemotron_4_340b, recurrentgemma_2b, sigkernel_workload)

__all__ = [
    "qwen2_moe_a2_7b", "dbrx_132b", "internvl2_76b", "whisper_large_v3",
    "mamba2_780m", "qwen2_72b", "granite_34b", "deepseek_7b",
    "nemotron_4_340b", "recurrentgemma_2b", "sigkernel_workload",
    "ASSIGNED",
]

ASSIGNED = [
    "qwen2-moe-a2.7b", "dbrx-132b", "internvl2-76b", "whisper-large-v3",
    "mamba2-780m", "qwen2-72b", "granite-34b", "deepseek-7b",
    "nemotron-4-340b", "recurrentgemma-2b",
]
