"""InternVL2-76B — InternViT (stub frontend) + Llama3-70B-class LM backbone
[arXiv:2404.16821].  Patch embeddings are provided precomputed via
input_specs(); the transformer backbone below is exercised in full."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, act="silu",
    n_patches=256, rope_theta=5e5, moment_dtype="bfloat16",
))
