"""The paper's own workload: large-batch signature-kernel Gram computation
(pySigLib Table 2 scaled to pod size).  Not an LM; used for the sig-specific
dry-run and roofline rows."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="sigkernel-workload", family="sigkernel",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab=0,
))

# Gram-engine settings for the dry-run / roofline cells: per-device row
# blocks keep live Δ memory at row_block·By·L² floats, and the CPU-lowered
# compile cells use the antidiag wavefront (the Pallas backends would lower
# for TPU only).  repro.launch.dryrun reads these.
GRAM_ENGINE_DEFAULTS = dict(backend="antidiag", row_block=2)
