"""The paper's own workload: large-batch signature-kernel Gram computation
(pySigLib Table 2 scaled to pod size).  Not an LM; used for the sig-specific
dry-run and roofline rows."""
from repro.models.config import ArchConfig, register

CONFIG = register(ArchConfig(
    name="sigkernel-workload", family="sigkernel",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab=0,
))
