"""Quickstart: signatures and signature kernels in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro
from repro.core.signature import signature, signature_combine
from repro.core.logsignature import logsignature, logsignature_combine
from repro.core.sigkernel import sigkernel, sigkernel_gram
from repro.core import losses, transforms

key = jax.random.PRNGKey(0)

# --- a batch of 3-dimensional paths (e.g. price streams) -------------------
paths = jax.random.normal(key, (8, 50, 3)) * 0.2

# truncated signature (levels 1..4, flat layout)
sig = signature(paths, depth=4)
print("signature:", sig.shape)                 # (8, 3 + 9 + 27 + 81)

# Chen's identity: signatures compose over concatenation
left, right = signature(paths[:, :25], 4), signature(paths[:, 24:], 4)
print("chen err:", float(jnp.abs(signature_combine(left, right, 3, 4) - sig).max()))

# lead-lag + time augmentation, applied on the fly (paper §4) — configured
# with the API-v1 pytree TransformPipeline (old bool kwargs still work but
# emit a DeprecationWarning; see docs/migration.md)
sig_ll = signature(paths, depth=3, transforms=repro.TransformPipeline(
    lead_lag=True, time_aug=True))
print("lead-lag signature:", sig_ll.shape)

# --- log-signatures: same information, Lyndon-compressed --------------------
logsig = logsignature(paths, depth=4)          # mode="lyndon" (default)
print("log-signature:", logsig.shape, "vs signature:", sig.shape)

# log-signatures also compose over concatenation (via exp -> Chen -> log)
lls, rls = logsignature(paths[:, :25], 4), logsignature(paths[:, 24:], 4)
print("logsig combine err:",
      float(jnp.abs(logsignature_combine(lls, rls, 3, 4) - logsig).max()))

# exact gradients through the log + Lyndon projection too
g_ls = jax.grad(lambda q: logsignature(q, 3).sum())(paths)
print("logsig grad finite:", bool(jnp.isfinite(g_ls).all()))

# --- signature kernels (Goursat PDE, paper §3) ------------------------------
x, y = paths[:4], paths[4:]
k = sigkernel(x, y, grid=repro.GridConfig(1, 1))   # dyadic order (1,1)
print("k(x, y):", k.shape, k[:2])

# Gram matrix + MMD loss between two path distributions
K = sigkernel_gram(x, y)
print("gram:", K.shape)
mmd = losses.mmd2(x, y, unbiased=False)
print("MMD^2:", float(mmd))

# symmetric Gram: omit Y and only the upper triangle is solved (~2x fewer
# PDE solves), mirrored into the full (4, 4) matrix
Kxx = sigkernel_gram(x)
print("symmetric gram:", Kxx.shape,
      "sym err:", float(jnp.abs(Kxx - Kxx.T).max()))

# exact gradients through the PDE solver (paper §3.4) — train anything
g = jax.grad(lambda q: losses.mmd2(q, y, unbiased=False))(x)
print("grad wrt paths:", g.shape, "finite:", bool(jnp.isfinite(g).all()))

# --- backend registry (repro.core.dispatch) ---------------------------------
# every entry point takes backend=; "auto" picks per platform; Pallas
# kernels run in interpret mode on CPU (slow but correct)
k_pallas = sigkernel(x, y, backend="pallas")
print("pallas vs jnp:", float(jnp.abs(k_pallas - sigkernel(x, y)).max()))
sig_pallas = signature(paths, depth=4, backend="pallas")
print("pallas signature err:", float(jnp.abs(sig_pallas - sig).max()))

# the fused-Δ Gram backend (Δ never exists in HBM), differentiable too
K_fused = sigkernel_gram(x, y, backend="pallas_fused")
print("fused gram err:", float(jnp.abs(K_fused - K).max()))

# --- API v1: composable kernel objects (repro top-level namespace) ----------
# class entry points close over pytree configs, so they jit/vmap cleanly;
# static_kernel= swaps the lift under the signature kernel (KSig-style)
sk = repro.SigKernel(static_kernel=repro.RBF(sigma=1.0),
                     transforms=repro.TransformPipeline(time_aug=True),
                     grid=repro.GridConfig(1, 1))
K_rbf = jax.jit(sk.gram)(x)                       # RBF-lift symmetric Gram
print("RBF-lift gram:", K_rbf.shape)
print("RBF-lift MMD^2:", float(sk.mmd2(x, y, unbiased=False)))

# kernel hyper-parameters are pytree *leaves*: differentiate through sigma
dsig = jax.grad(lambda s: repro.SigKernel(
    static_kernel=repro.RBF(sigma=s)).gram(x).sum())(1.0)
print("d gram.sum / d sigma:", float(dsig))

# basepoint transform (translation sensitivity), on the fly as well
sig_bp = repro.Signature(depth=3,
                         transforms=repro.TransformPipeline(basepoint=True))
print("basepoint signature:", sig_bp(paths).shape)

# --- ragged batches: variable-length paths in one dense array ---------------
# real corpora have unequal lengths; lengths= makes each path behave as if
# truncated to its own length (padding content is ignored — even NaN), with
# a per-path time grid that ends at t1 at the TRUE last point
import numpy as np

lens = jnp.asarray([6, 50, 23, 9, 41, 17, 50, 30])  # true points per path
ragged_sig = repro.signature(paths, depth=4, lengths=lens)
oracle = repro.signature(paths[0:1, :6], depth=4)    # truncated by hand
print("ragged == truncated:",
      bool(np.array_equal(np.asarray(ragged_sig[0]), np.asarray(oracle[0]))))

# Gram over two differently-ragged batches, any backend
K_rag = repro.sigkernel_gram(x, y, lengths=jnp.asarray([8, 50, 21, 34]),
                             lengths_y=jnp.asarray([50, 5, 44, 12]))
print("ragged gram:", K_rag.shape)

# jitting yourself? canonicalise outside the trace so nearby max-lengths
# share one compile (power-of-two length buckets)
xp, lp = repro.pad_ragged(x, jnp.asarray([8, 50, 21, 34]))
print("bucketed length:", xp.shape[1], "=", repro.bucket_length(x.shape[1]))
