"""End-to-end driver: train a sequence generator against a signature-kernel
MMD score — the workload pySigLib exists to accelerate (neural-SDE-style
market generation [16, 21, 24]).

A transformer backbone (reduced deepseek-7b family by default; --full-100m
builds a ~100M-parameter generator) maps noise paths to generated paths; the
loss is the unbiased sig-kernel MMD against GBM target paths, differentiated
through the exact one-pass backward (paper §3.4).

    PYTHONPATH=src python examples/train_sigkernel_gan.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.config import GridConfig, TransformPipeline
from repro.data.synthetic import gbm_paths
from repro.models import get_config
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim.adamw import AdamW, cosine_schedule


def build_generator(cfg, path_dim: int, noise_dim: int):
    """Noise path (B, L, noise_dim) -> generated path (B, L, path_dim)."""

    def init(key):
        ks = jax.random.split(key, 4)
        return {
            "in_proj": L.dense_init(ks[0], noise_dim, cfg.d_model),
            "layers": T.stack_init(ks[1], cfg),
            "norm": L.rmsnorm_init(cfg.d_model),
            "out_proj": L.dense_init(ks[2], cfg.d_model, path_dim, scale=0.02),
        }

    def apply(params, noise):
        x = noise @ params["in_proj"]
        positions = jnp.arange(x.shape[1])
        x, _ = T.stack_apply(params["layers"], x, positions, cfg)
        x = L.rmsnorm(params["norm"], x, cfg.norm_eps)
        inc = x @ params["out_proj"]
        path = jnp.cumsum(inc, axis=1)           # increments -> path
        return path - path[:, :1]                # pin at 0

    return init, apply


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--length", type=int, default=24)
    ap.add_argument("--dim", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-100m", action="store_true",
                    help="~100M-param generator instead of the CPU-tiny one")
    ap.add_argument("--dyadic", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    base = get_config("deepseek-7b")
    if args.full_100m:
        cfg = base.replace(n_layers=12, d_model=768, n_heads=12,
                           n_kv_heads=12, d_ff=3072, head_dim=64,
                           vocab=256, scan_layers=True, remat=True,
                           compute_dtype="float32")
    else:
        cfg = base.reduced().replace(n_layers=2)
    noise_dim = 8

    init, apply = build_generator(cfg, args.dim, noise_dim)
    params = init(jax.random.PRNGKey(0))
    n_params = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
    print(f"generator params: {n_params/1e6:.1f}M")

    opt = AdamW(lr=cosine_schedule(args.lr, 20, args.steps),
                weight_decay=0.0)
    opt_state = opt.init(params)

    # API v1 config objects: one kernel spec shared by train + eval
    KERNEL_GRID = GridConfig(lam1=args.dyadic, lam2=args.dyadic)
    KERNEL_TRANSFORMS = TransformPipeline(time_aug=True)

    def loss_fn(params, key, step):
        noise = jax.random.normal(key, (args.batch, args.length, noise_dim))
        fake = apply(params, noise)
        real = gbm_paths(jax.random.fold_in(jax.random.PRNGKey(1), step),
                         args.batch, args.length, args.dim)
        return losses.mmd2(fake, real, grid=KERNEL_GRID,
                           transforms=KERNEL_TRANSFORMS, unbiased=False)

    @jax.jit
    def train_step(params, opt_state, key, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, key, step)
        params, opt_state, m = opt.update(grads, opt_state, params)
        return params, opt_state, loss, m["grad_norm"]

    # fixed held-out evaluation set (large batch, fixed seeds)
    eval_noise = jax.random.normal(jax.random.PRNGKey(100),
                                   (64, args.length, noise_dim))
    eval_real = gbm_paths(jax.random.PRNGKey(101), 64, args.length, args.dim)

    @jax.jit
    def eval_mmd(params):
        return losses.mmd2(apply(params, eval_noise), eval_real,
                           grid=KERNEL_GRID, transforms=KERNEL_TRANSFORMS,
                           unbiased=False)

    first = float(eval_mmd(params))
    print(f"initial eval sig-MMD^2: {first:.5f}")
    t0 = time.time()
    for step in range(args.steps):
        key = jax.random.fold_in(jax.random.PRNGKey(2), step)
        params, opt_state, loss, gnorm = train_step(params, opt_state, key,
                                                    step)
        if (step + 1) % args.log_every == 0:
            print(f"step {step+1:4d}  train MMD^2 {float(loss):.5f}  "
                  f"eval MMD^2 {float(eval_mmd(params)):.5f}  "
                  f"gnorm {float(gnorm):.3f}  "
                  f"({(time.time()-t0)/(step+1)*1e3:.0f} ms/step)", flush=True)
    final = float(eval_mmd(params))
    print(f"eval MMD^2: {first:.5f} -> {final:.5f} "
          f"({'improved' if final < first else 'NOT improved'})")


if __name__ == "__main__":
    main()
