"""Serve signature features over concurrently growing tick streams.

A steady-state serving loop: N named streams receive ticks, the server
coalesces all pending appends per flush into batched bucketed kernel calls
(admission batching), and each stream answers O(1) signature / rolling /
RFF-feature queries from its per-prefix store.  Prints a latency and
throughput report plus the admission-batching counters.

    PYTHONPATH=src python examples/serve_sig_features.py --streams 8 --ticks 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import FeatureConfig, TransformPipeline
from repro.serve import SigFeatureServer
from repro.stream import trace_counts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=64,
                    help="flush rounds (one tick per stream per round)")
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--d", type=int, default=3, help="channels per tick")
    ap.add_argument("--init-len", type=int, default=32)
    ap.add_argument("--window", type=int, default=16,
                    help="rolling / feature query window (points)")
    ap.add_argument("--rank", type=int, default=32, help="RFF feature rank")
    ap.add_argument("--lead-lag", action="store_true")
    args = ap.parse_args()

    tp = TransformPipeline(lead_lag=args.lead_lag)
    srv = SigFeatureServer(
        args.depth, transforms=tp,
        features=FeatureConfig(method="rff", rank=args.rank,
                               depth=args.depth))

    key = jax.random.PRNGKey(0)
    init = 0.1 * jax.random.normal(
        key, (args.streams, args.init_len, args.d))
    for s in range(args.streams):
        srv.open_stream(f"stream-{s}", init[s])

    # warm the build/update traces for the capacity & group buckets the
    # steady state will visit, so tick 0 is served from a warm cache
    from repro.core.transforms import bucket_length
    capacity = bucket_length(args.init_len + args.ticks)
    t_warm = srv.warmup(lengths=(args.init_len, capacity),
                        chunk_sizes=(1,),
                        group_sizes=(args.streams,))

    ticks = 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (args.ticks, args.streams, args.d))

    append_lat, query_lat, feat_lat = [], [], []
    t_loop = time.perf_counter()
    for t in range(args.ticks):
        t0 = time.perf_counter()
        for s in range(args.streams):
            srv.append(f"stream-{s}", ticks[t, s])
        srv.flush()
        sig = srv.signature("stream-0")
        sig.block_until_ready()
        t1 = time.perf_counter()
        roll = srv.rolling("stream-0", args.window)
        roll.block_until_ready()
        t2 = time.perf_counter()
        phi = srv.features("stream-0", window=args.window)
        phi.block_until_ready()
        t3 = time.perf_counter()
        append_lat.append(t1 - t0)
        query_lat.append(t2 - t1)
        feat_lat.append(t3 - t2)
    wall = time.perf_counter() - t_loop

    def report(name, xs, skip=4):
        xs = sorted(xs[skip:]) if len(xs) > skip else sorted(xs)
        p50 = xs[len(xs) // 2]
        p95 = xs[min(len(xs) - 1, int(0.95 * len(xs)))]
        print(f"  {name:<28s} p50 {p50 * 1e3:8.3f} ms   "
              f"p95 {p95 * 1e3:8.3f} ms")

    n_pts = args.ticks * args.streams
    st = srv.stats()
    print(f"serve_sig_features: {args.streams} streams x {args.ticks} "
          f"ticks, depth {args.depth}, d {args.d}, "
          f"lead_lag={args.lead_lag}")
    print(f"  warmup {t_warm:.2f} s; steady loop {wall:.2f} s  "
          f"({n_pts / wall:,.0f} points/s admitted)")
    report("flush + full signature", append_lat)
    report(f"rolling({args.window}) windows", query_lat)
    report(f"rff features (rank {args.rank})", feat_lat)
    print(f"  admission: {st['flushes']} flushes -> "
          f"{st['update_groups']} batched groups "
          f"({st['coalesced_streams']} stream-updates coalesced, "
          f"{st['solo_updates']} solo/growth)")
    print(f"  jit traces: {trace_counts()}")
    # admission batching must keep kernel invocations per flush near 1 —
    # far below one per stream (growth rounds route a few streams solo)
    invocations = st["update_groups"] + st["solo_updates"]
    assert invocations <= 2 * st["flushes"] + args.streams, (
        f"admission batching degraded: {invocations} update invocations "
        f"for {st['flushes']} flushes of {args.streams} streams")


if __name__ == "__main__":
    main()
