"""Serve a small LM: batched prefill + greedy decode with KV caches.

    PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b --tokens 32
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import get_config, build_model
from repro.serve.step import make_prefill_step, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.tokens
    prefill = jax.jit(make_prefill_step(model, max_len=max_len))
    decode = jax.jit(make_decode_step(model))

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    batch = {"tokens": prompt}
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_audio_frames, cfg.d_model))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out = [nxt]
    for t in range(args.prompt_len, max_len - 1):
        nxt, _, caches = decode(params, caches, nxt,
                                jnp.asarray(t, jnp.int32))
        out.append(nxt)
    toks = jnp.concatenate(out, axis=1)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    print(f"arch={args.arch} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.0f} tok/s incl. compile)")
    print("sample:", toks[0, :16].tolist())


if __name__ == "__main__":
    main()
