"""Distributed + streaming signature-kernel Grams — the paper's workload at
pod scale, runnable on a laptop.

Three layers, smallest-to-largest memory footprint:

1. ``sigkernel_gram_sharded`` — the (Bx, By) tile grid of Goursat solves
   block-cyclic sharded over a 2-D device mesh (rows over ``data``, columns
   over ``model``); the symmetric fast path deals the upper-triangle pairs
   round-robin over every device, so the triangular tile grid stays
   load-balanced.
2. ``mmd2(..., row_block=)`` — streaming losses: all three Gram terms are
   accumulated as per-row-block partial sums (forward AND gradient under
   ``jax.checkpoint``), so the full (B, B) Grams never exist; a shape guard
   abstractly traces the reduction to prove it.
3. The classic jit-sharding route through the plain engine, for comparison.

Run with simulated host devices to see the whole thing multi-device on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/gram_matrix_distributed.py

(docs/api/public.md § Distributed & streaming Grams has the recipe.)
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.config import GridConfig
from repro.core.gram import sigkernel_gram, sigkernel_gram_sharded
from repro.core.losses import mmd2
from repro.data.synthetic import gbm_paths
from repro.launch.mesh import make_gram_mesh
from repro.parallel.api import DEFAULT_RULES, logical_rules

n_dev = len(jax.devices())
mesh = make_gram_mesh()          # near-square (data, model) over all devices
print(f"devices: {n_dev}, mesh: {dict(mesh.shape)}")

B, L, d = 32, 64, 4
grid = GridConfig(1, 1)
X = gbm_paths(jax.random.PRNGKey(0), B, L, d)
Y = gbm_paths(jax.random.PRNGKey(1), B, L, d)

# -- 1. the sharded engine: one call, tiles dealt over the whole mesh -------
K = sigkernel_gram_sharded(X, Y, mesh=mesh, grid=grid)
jax.block_until_ready(K)
print("sharded gram:", K.shape, " E[k(X,Y)] =", float(K.mean()))

# symmetric: upper-triangle pairs (~2x fewer PDE solves) dealt round-robin
# over all data*model devices, mirrored once on the way out
Kxx = sigkernel_gram_sharded(X, mesh=mesh, grid=grid)
print("sharded symmetric gram:", Kxx.shape,
      " max asymmetry:", float(jnp.abs(Kxx - Kxx.T).max()))

# shard-count invariance: a sub-mesh over fewer devices gives the same K
K1 = sigkernel_gram_sharded(X, Y, mesh=make_gram_mesh(1), grid=grid)
print("1-device == full-mesh:",
      bool(np.allclose(np.asarray(K1), np.asarray(K), rtol=1e-5, atol=1e-6)))

# ragged batches survive sharding unchanged: masking is burnt into the
# end-aligned prepared streams before the tiles are dealt
lengths = jnp.asarray([L - (i % 7) for i in range(B)])
Kr = sigkernel_gram_sharded(X, Y, lengths=lengths, mesh=mesh, grid=grid)
print("ragged sharded gram:", Kr.shape, "finite:",
      bool(np.isfinite(np.asarray(Kr)).all()))

# -- 2. streaming losses: the (B, B) Grams never exist ----------------------
# row_block= auto-enables streaming: every Gram term becomes a checkpointed
# per-block partial sum, in the forward and in the VJP; an abstract-trace
# shape guard asserts no (B, B) intermediate is materialised.
loss_dense = float(mmd2(X, Y, grid=grid))
loss_stream = float(mmd2(X, Y, grid=grid, row_block=8))
# mmd2 is a small difference of O(1) Gram sums, so compare absolutely:
# summation order differs between the streaming and dense reductions
print(f"mmd2 dense {loss_dense:.6f}  streaming {loss_stream:.6f}  "
      f"match: {bool(np.allclose(loss_dense, loss_stream, atol=1e-5))}")

g = jax.grad(lambda q: mmd2(q, Y, grid=grid, row_block=8))(X)
print("streaming grad:", g.shape, "finite:",
      bool(np.isfinite(np.asarray(g)).all()))

# -- 3. classic route: jit-sharding the plain engine ------------------------
gram_jit = jax.jit(
    lambda x, y: sigkernel_gram(x, y, grid=grid),
    in_shardings=(NamedSharding(mesh, P("data")),
                  NamedSharding(mesh, P("model"))),
    out_shardings=NamedSharding(mesh, P("data", "model")))
with mesh, logical_rules(DEFAULT_RULES):
    Kj = gram_jit(X, Y)
    jax.block_until_ready(Kj)
print("jit-sharded gram:", Kj.shape, "sharding:", Kj.sharding)
print("engines agree:",
      bool(np.allclose(np.asarray(Kj), np.asarray(K), rtol=1e-5, atol=1e-6)))
