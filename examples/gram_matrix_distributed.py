"""Distributed signature-kernel Gram matrices — the paper's workload at pod
scale.

The B×B Gram of PDE solves is tiled over a 2-D mesh: row-block over the
``data`` axis, column-block over ``model``.  Each device solves its tile of
Goursat problems locally (Pallas kernel on TPU); only the MMD reduction
crosses devices.  Run with fake devices to see the sharded lowering:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/gram_matrix_distributed.py
"""

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.config import GridConfig
from repro.core.gram import sigkernel_gram
from repro.data.synthetic import gbm_paths
from repro.parallel.api import DEFAULT_RULES, logical_rules

n_dev = len(jax.devices())
mesh_shape = {1: (1, 1), 2: (2, 1), 4: (2, 2), 8: (4, 2),
              512: (16, 16)}.get(n_dev, (n_dev, 1))
mesh = jax.make_mesh(mesh_shape, ("data", "model"))
print(f"devices: {n_dev}, mesh: {dict(mesh.shape)}")

B, L, d = 32, 64, 4
X = gbm_paths(jax.random.PRNGKey(0), B, L, d)
Y = gbm_paths(jax.random.PRNGKey(1), B, L, d)

gram = jax.jit(
    lambda x, y: sigkernel_gram(x, y, grid=GridConfig(1, 1)),
    in_shardings=(NamedSharding(mesh, P("data")),
                  NamedSharding(mesh, P("model"))),
    out_shardings=NamedSharding(mesh, P("data", "model")))

# under logical_rules the engine's own shard() annotations engage (rows ->
# "batch" -> data axis, columns -> "model"), so the tiling is expressed once
# inside repro.core.gram rather than at every call site
with mesh, logical_rules(DEFAULT_RULES):
    K = gram(X, Y)
    jax.block_until_ready(K)

print("gram:", K.shape, "sharding:", K.sharding)
print("K[:2,:2]:\n", K[:2, :2])

# MMD from sharded Gram blocks — one scalar all-reduce
mmd = float(K.mean())
print("E[k(X,Y)] =", mmd)

# symmetric Gram (Y omitted): only the upper triangle is solved (~2x fewer
# PDE solves), row-blocked so Bx need not divide the block size
sym = jax.jit(lambda x: sigkernel_gram(x, grid=GridConfig(1, 1), row_block=8),
              in_shardings=NamedSharding(mesh, P("data")),
              out_shardings=NamedSharding(mesh, P("data", "model")))
with mesh, logical_rules(DEFAULT_RULES):
    Kxx = sym(X)
    jax.block_until_ready(Kxx)
print("symmetric gram:", Kxx.shape, "sharding:", Kxx.sharding)
print("max asymmetry:", float(jnp.abs(Kxx - Kxx.T).max()))
