"""Multi-device distribution tests (subprocesses own their XLA_FLAGS).

Verifies: (a) sharding rules produce valid, divisible PartitionSpecs for every
arch; (b) a reduced model trains identically on 1 device and on a (2, 2)
data×model mesh; (c) a mini dry-run lowers+compiles on a (2, 2, 2)
pod×data×model mesh (the multi-pod path in miniature)."""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_sub(prog: str, timeout=900):
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout,
                       env={**os.environ, "PYTHONPATH": "src"}, cwd=ROOT)
    assert "OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]


def test_param_specs_all_archs_valid():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax, jax.numpy as jnp
        from repro.models import get_config, build_model
        from repro.parallel import sharding as SH
        from repro.configs import ASSIGNED

        mesh = jax.make_mesh((4, 4), ("data", "model"))
        for name in ASSIGNED:
            cfg = get_config(name)          # FULL config specs, no alloc
            model = build_model(cfg)
            ps = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
            shardings = SH.param_shardings(ps, cfg, mesh, False)
            # every spec must divide its dim
            for leaf, sh in zip(jax.tree.leaves(ps), jax.tree.leaves(shardings)):
                spec = sh.spec
                for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
                    if ax is None: continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    import math
                    size = math.prod(mesh.shape[a] for a in axes)
                    assert dim % size == 0, (name, leaf.shape, spec)
        print("OK")
    """)
    run_sub(prog)


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import get_config, build_model
        from repro.parallel import sharding as SH
        from repro.parallel.api import logical_rules
        from repro.optim.adamw import AdamW, cosine_schedule
        from repro.train.step import make_train_step
        from repro.data.synthetic import TokenLM

        cfg = get_config("deepseek-7b").reduced()
        model = build_model(cfg)
        opt = AdamW(lr=cosine_schedule(1e-3, 2, 20))
        data = TokenLM(vocab=cfg.vocab, seq=16, batch=8, seed=0)

        def train(mesh_axes):
            mesh = jax.make_mesh(mesh_axes, ("data", "model"))
            rules = SH.rules_for(cfg, False)
            params_shape = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
            p_sh = SH.param_shardings(params_shape, cfg, mesh, False)
            o_sh = SH.param_shardings(jax.eval_shape(opt.init, params_shape), cfg, mesh, False)
            pspecs = jax.tree.map(lambda s: s.spec, p_sh)
            step = jax.jit(make_train_step(model, opt, num_microbatches=2,
                                           param_pspecs=pspecs),
                           in_shardings=(p_sh, o_sh, None),
                           out_shardings=(p_sh, o_sh, None))
            with mesh, logical_rules(rules):
                params = jax.jit(model.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
                opt_state = jax.jit(opt.init, out_shardings=o_sh)(params)
                losses = []
                for s in range(5):
                    params, opt_state, m = step(params, opt_state, data.batch_at(s))
                    losses.append(float(m["loss"]))
            return losses

        l_single = train((1, 1))
        l_mesh = train((2, 2))
        # f32 reduction order differs across device meshes; observed drift is
        # ~3e-3 relative after 5 steps on a forced-host 2x2 mesh.
        np.testing.assert_allclose(l_single, l_mesh, rtol=1e-2)
        print("OK")
    """)
    run_sub(prog)


@pytest.mark.slow
def test_mini_multipod_dryrun():
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.models import get_config, build_model
        from repro.parallel import sharding as SH
        from repro.parallel.api import logical_rules
        from repro.optim.adamw import AdamW, cosine_schedule
        from repro.train.step import make_train_step

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("dbrx-132b").reduced().replace(
            d_model=64, n_heads=4, n_kv_heads=2, n_experts=4, scan_layers=True,
            n_layers=2)
        model = build_model(cfg)
        opt = AdamW(lr=cosine_schedule(1e-3, 2, 20))
        rules = SH.rules_for(cfg, True)
        ps = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_sh = SH.param_shardings(ps, cfg, mesh, True)
        o_sh = SH.param_shardings(jax.eval_shape(opt.init, ps), cfg, mesh, True)
        pspecs = jax.tree.map(lambda s: s.spec, p_sh)
        step = make_train_step(model, opt, num_microbatches=2, param_pspecs=pspecs)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        b_sh = SH.batch_shardings(batch, cfg, mesh, True)
        with mesh, logical_rules(rules):
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, None)).lower(ps,
                              jax.eval_shape(opt.init, ps), batch)
            compiled = lowered.compile()
            assert compiled.memory_analysis() is not None
        print("OK")
    """)
    run_sub(prog)
