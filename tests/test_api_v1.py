"""API v1 contract tests: the stable top-level surface, pytree configs,
class entry points, static-kernel lifts, and the deprecation shims.

Covers the PR-4 acceptance criteria:

* ``repro.__all__`` / ``repro.core.__all__`` match the committed snapshot
  ``tests/api_surface.txt`` (changing the public surface requires editing
  that file in the same commit — an intentional speed bump).
* ``jax.jit(repro.SigKernel(static_kernel=repro.RBF(...)).gram)`` compiles,
  agrees with a naive RBF-lift Gram oracle, and its ``jax.grad`` matches
  finite differences.
* Every old-style call (``time_aug=``/``lead_lag=``/``lam1``/``lam2``/
  ``use_pallas=``) emits exactly one DeprecationWarning per call-site and
  returns **bitwise-identical** results to the config-object call.
* ``basepoint`` on the on-the-fly increment path matches the materialised
  ``basepoint(path)`` oracle; ``t0``/``t1`` reach ``transform_increments``.
* ``signature(..., stream=True, backend="pallas")`` raises; auto degrades
  silently.
"""

import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import dispatch
from repro.core import transforms as tf
from repro.core.config import delta_from_gram
from repro.core.sigkernel import delta_matrix, solve_goursat

jax.config.update("jax_platform_name", "cpu")

SURFACE_FILE = os.path.join(os.path.dirname(__file__), "api_surface.txt")


def paths(seed, B=3, L=8, d=2, scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


def _bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape \
        and a.tobytes() == b.tobytes()


# ---------------------------------------------------------------------------
# API snapshot
# ---------------------------------------------------------------------------

def test_api_surface_matches_snapshot():
    with open(SURFACE_FILE, encoding="utf-8") as f:
        committed = [ln.strip() for ln in f
                     if ln.strip() and not ln.startswith("#")]
    live = sorted(f"repro.{n}" for n in repro.__all__) + \
        sorted(f"repro.core.{n}" for n in repro.core.__all__)
    assert live == committed, (
        "public API changed: update tests/api_surface.txt in the same "
        "commit (and docs/api/public.md)")


def test_all_names_exist():
    for name in repro.__all__:
        assert hasattr(repro, name), name
    for name in repro.core.__all__:
        assert hasattr(repro.core, name), name


# ---------------------------------------------------------------------------
# class entry points + RBF lift (the tentpole acceptance)
# ---------------------------------------------------------------------------

def _naive_rbf_gram(X, Y, sigma):
    """Oracle: materialised pointwise RBF Gram -> Δ double increment ->
    reference Goursat solve, pair by pair."""
    X, Y = np.asarray(X), np.asarray(Y)
    K = np.zeros((X.shape[0], Y.shape[0]), np.float32)
    for a in range(X.shape[0]):
        for b in range(Y.shape[0]):
            diff = X[a][:, None, :] - Y[b][None, :, :]
            G = np.exp(-(diff ** 2).sum(-1) / (2.0 * sigma ** 2))
            d = G[1:, 1:] - G[1:, :-1] - G[:-1, 1:] + G[:-1, :-1]
            K[a, b] = float(solve_goursat(jnp.asarray(d)))
    return K


@pytest.mark.slow
def test_jit_rbf_sigkernel_gram_matches_oracle_and_fd():
    X, Y = paths(0, 3, 7, 2, 0.3), paths(1, 4, 6, 2, 0.3)
    sk = repro.SigKernel(static_kernel=repro.RBF(sigma=1.0))
    K = jax.jit(sk.gram)(X, Y)                      # compiles
    np.testing.assert_allclose(K, _naive_rbf_gram(X, Y, 1.0),
                               rtol=5e-4, atol=1e-5)

    g = jax.grad(lambda q: sk.gram(q, Y).sum())(X)
    x0 = np.asarray(X)
    eps = 1e-3
    for idx in [(0, 0, 0), (1, 3, 1), (2, 6, 0)]:
        xp, xm = x0.copy(), x0.copy()
        xp[idx] += eps
        xm[idx] -= eps
        fd = (float(sk.gram(jnp.asarray(xp), Y).sum())
              - float(sk.gram(jnp.asarray(xm), Y).sum())) / (2 * eps)
        assert abs(fd - float(g[idx])) < 2e-2 * max(1.0, abs(fd)), idx


def test_rbf_symmetric_gram_psd():
    X = paths(2, 4, 6, 2, 0.3)
    K = repro.SigKernel(static_kernel=repro.RBF(sigma=0.7)).gram(X)
    np.testing.assert_allclose(K, K.T, rtol=1e-4, atol=1e-5)
    evals = np.linalg.eigvalsh(np.asarray(K, np.float64))
    assert evals.min() > -1e-4


def test_linear_scale_lift():
    """Linear(scale) multiplies Δ — equivalent to scaling one path side."""
    x, y = paths(3), paths(4)
    k_scaled = repro.sigkernel(x, y, static_kernel=repro.Linear(scale=0.25))
    k_manual = repro.sigkernel(0.25 * x, y)
    np.testing.assert_allclose(k_scaled, k_manual, rtol=1e-5, atol=1e-6)


def test_configs_are_pytrees():
    sk = repro.SigKernel(static_kernel=repro.RBF(sigma=2.0),
                         transforms=repro.TransformPipeline(time_aug=True))
    leaves, treedef = jax.tree_util.tree_flatten(sk)
    assert 2.0 in [float(v) for v in leaves]        # sigma is a leaf
    assert jax.tree_util.tree_unflatten(treedef, leaves) == sk
    X = paths(5)
    # object-as-argument jit and vmap over the sigma leaf
    K1 = jax.jit(lambda k, X: k.gram(X))(sk, X)
    np.testing.assert_allclose(K1, sk.gram(X), rtol=1e-6)
    Ks = jax.vmap(lambda s: repro.SigKernel(
        static_kernel=repro.RBF(sigma=s)).gram(X))(jnp.array([0.5, 1.0]))
    assert Ks.shape == (2, X.shape[0], X.shape[0])


def test_grad_wrt_kernel_hyperparameter():
    X = paths(6, 3, 6, 2, 0.3)
    dsig = jax.grad(lambda s: repro.SigKernel(
        static_kernel=repro.RBF(sigma=s)).gram(X).sum())(1.0)
    assert np.isfinite(dsig)
    eps = 1e-3
    f = lambda s: float(repro.SigKernel(
        static_kernel=repro.RBF(sigma=s)).gram(X).sum())
    fd = (f(1.0 + eps) - f(1.0 - eps)) / (2 * eps)
    assert abs(fd - float(dsig)) < 2e-2 * max(1.0, abs(fd))


def test_signature_and_logsignature_classes():
    X = paths(7, 2, 9, 2)
    cfg = repro.TransformPipeline(lead_lag=True)
    np.testing.assert_allclose(
        jax.jit(repro.Signature(depth=3, transforms=cfg))(X),
        repro.signature(X, 3, transforms=cfg), rtol=1e-6)
    np.testing.assert_allclose(
        repro.LogSignature(depth=3, mode="brackets")(X),
        repro.logsignature(X, 3, mode="brackets"), rtol=1e-6)
    sk = repro.SigKernel()
    np.testing.assert_allclose(sk.mmd2(X, X + 0.05, unbiased=False),
                               repro.mmd2(X, X + 0.05, unbiased=False),
                               rtol=1e-6)
    np.testing.assert_allclose(sk.scoring_rule(X, X[0]),
                               repro.scoring_rule(X, X[0]), rtol=1e-6)


def test_pallas_fused_rejects_nonlinear_lift():
    X = paths(8)
    with pytest.raises(ValueError, match="linear lift"):
        repro.sigkernel_gram(X, X, symmetric=False,
                             static_kernel=repro.RBF(sigma=1.0),
                             backend="pallas_fused")
    with pytest.raises(ValueError, match="linear lift"):
        repro.sigkernel(X, X, static_kernel=repro.RBF(sigma=1.0),
                        backend="pallas_fused")


# ---------------------------------------------------------------------------
# deprecation shims: exactly one warning per call-site, bitwise identity
# ---------------------------------------------------------------------------

def _one_warning_bitwise(legacy_fn, config_fn):
    dispatch.reset_warned_sites()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy = legacy_fn()
        legacy2 = legacy_fn()                       # same site: no new warning
    assert [x.category for x in w] == [DeprecationWarning], \
        [str(x.message) for x in w]
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = config_fn()                           # config calls never warn
    assert _bitwise_equal(legacy, cfg)
    assert _bitwise_equal(legacy, legacy2)


@pytest.mark.slow
def test_old_kwargs_bitwise_and_warn_once():
    x, y = paths(10, 2, 7, 2), paths(11, 2, 6, 2)
    X = paths(12, 3, 6, 2)
    TP, GC = repro.TransformPipeline, repro.GridConfig

    _one_warning_bitwise(
        lambda: repro.signature(x, 3, time_aug=True, lead_lag=True),
        lambda: repro.signature(
            x, 3, transforms=TP(time_aug=True, lead_lag=True)))
    _one_warning_bitwise(
        lambda: repro.logsignature(x, 3, time_aug=True),
        lambda: repro.logsignature(x, 3, transforms=TP(time_aug=True)))
    # one call-site mixing transform AND grid legacy kwargs: still one warning
    _one_warning_bitwise(
        lambda: repro.sigkernel(x, y, lam1=1, lam2=2, time_aug=True,
                                lead_lag=True),
        lambda: repro.sigkernel(
            x, y, grid=GC(1, 2), transforms=TP(time_aug=True,
                                               lead_lag=True)))
    _one_warning_bitwise(
        lambda: repro.sigkernel_gram(X, X, symmetric=False, lam1=1, lam2=1),
        lambda: repro.sigkernel_gram(X, X, symmetric=False, grid=GC(1, 1)))
    _one_warning_bitwise(
        lambda: repro.sigkernel(x, y, use_pallas=False),
        lambda: repro.sigkernel(x, y, backend="reference"))
    _one_warning_bitwise(
        lambda: repro.mmd2(X, X + 0.1, lam1=1, lam2=1, time_aug=True,
                           unbiased=False),
        lambda: repro.mmd2(X, X + 0.1, grid=GC(1, 1),
                           transforms=TP(time_aug=True), unbiased=False))
    _one_warning_bitwise(
        lambda: repro.scoring_rule(X, X[0], lead_lag=True),
        lambda: repro.scoring_rule(X, X[0], transforms=TP(lead_lag=True)))
    _one_warning_bitwise(
        lambda: delta_matrix(x, y, time_aug=True),
        lambda: delta_matrix(x, y, transforms=TP(time_aug=True)))
    # mixing a config-shim kwarg with the backend shim: still one warning
    _one_warning_bitwise(
        lambda: repro.sigkernel(x, y, lam1=1, use_pallas=False),
        lambda: repro.sigkernel(x, y, grid=GC(1, 0), backend="reference"))


def test_explicit_config_beats_contradicting_legacy():
    x, y = paths(13, 2, 6, 2), paths(14, 2, 6, 2)
    cfg = repro.GridConfig(2, 0)
    dispatch.reset_warned_sites()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        k = repro.sigkernel(x, y, grid=cfg, lam1=1, lam2=1)  # legacy ignored
    assert [x.category for x in w] == [DeprecationWarning]
    assert "ignored" in str(w[0].message)
    np.testing.assert_allclose(k, repro.sigkernel(x, y, grid=cfg), rtol=1e-6)


# ---------------------------------------------------------------------------
# satellites: basepoint on-the-fly, t0/t1 plumbing, stream backend guard
# ---------------------------------------------------------------------------

def test_basepoint_on_the_fly_matches_materialised_oracle():
    p = paths(20, 2, 7, 3)
    for extra in (repro.TransformPipeline(basepoint=True),
                  repro.TransformPipeline(basepoint=True, lead_lag=True),
                  repro.TransformPipeline(basepoint=True, time_aug=True,
                                          lead_lag=True)):
        on_the_fly = repro.signature(p, 3, transforms=extra)
        # oracle: materialise basepoint(path), then the rest of the pipeline
        rest = repro.TransformPipeline(time_aug=extra.time_aug,
                                       lead_lag=extra.lead_lag)
        oracle = repro.signature(tf.basepoint(p), 3, transforms=rest)
        np.testing.assert_allclose(on_the_fly, oracle, rtol=1e-5, atol=1e-6,
                                   err_msg=str(extra))


def test_basepoint_in_sigkernel_and_gram():
    x, y = paths(21, 2, 6, 2), paths(22, 2, 5, 2)
    cfg = repro.TransformPipeline(basepoint=True)
    k = repro.sigkernel(x, y, transforms=cfg)
    k_oracle = repro.sigkernel(tf.basepoint(x), tf.basepoint(y))
    np.testing.assert_allclose(k, k_oracle, rtol=1e-5)
    K = repro.sigkernel_gram(x, transforms=cfg)
    K_oracle = repro.sigkernel_gram(tf.basepoint(x))
    np.testing.assert_allclose(K, K_oracle, rtol=1e-5, atol=1e-6)


def test_basepoint_increments_need_first_point():
    z = jnp.zeros((2, 5, 2))
    with pytest.raises(ValueError, match="first"):
        tf.transform_increments(z, False, False, basepoint_=True)


def test_t0_t1_reach_transform_increments():
    p = paths(23, 2, 6, 2)
    cfg = repro.TransformPipeline(time_aug=True, t0=-1.0, t1=3.0)
    got = repro.signature(p, 3, transforms=cfg)
    oracle = repro.signature(tf.time_augment(p, -1.0, 3.0), 3)
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-6)
    # and through the kernel Δ path
    d = delta_matrix(p, p, transforms=cfg)
    d_oracle = delta_matrix(tf.time_augment(p, -1.0, 3.0),
                            tf.time_augment(p, -1.0, 3.0))
    np.testing.assert_allclose(d, d_oracle, rtol=1e-5, atol=1e-6)


def test_stream_with_explicit_pallas_raises():
    p = paths(24, 2, 6, 2)
    with pytest.raises(ValueError, match="stream"):
        repro.signature(p, 3, stream=True, backend="pallas")
    with pytest.raises(ValueError, match="stream"):
        repro.logsignature(p, 3, stream=True, backend="pallas")
    # auto still degrades silently to the pure-JAX scan
    out = repro.signature(p, 3, stream=True, backend="auto")
    assert out.shape[-2] == p.shape[-2] - 1


def test_grid_config_validates():
    with pytest.raises(ValueError, match="non-negative"):
        repro.GridConfig(lam1=-1)
    with pytest.raises(ValueError, match="non-negative"):
        repro.GridConfig(lam1=1.5)
    with pytest.raises(ValueError, match="non-negative"):
        repro.GridConfig(lam1=True)  # a stray bool is a caller bug, not λ=1


def test_delta_from_gram_reduces_to_increment_matmul():
    x, y = paths(25, 2, 6, 3), paths(26, 2, 5, 3)
    G = repro.Linear().gram(x, y)
    np.testing.assert_allclose(delta_from_gram(G), delta_matrix(x, y),
                               rtol=1e-4, atol=1e-6)
