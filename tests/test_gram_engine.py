"""The unified Gram engine: symmetric fast path (pair-solve budget),
row-block zero-padding, fused-backend differentiability, shims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch, losses
from repro.core.gram import sigkernel_gram
from repro.core.sigkernel import sigkernel_gram_blocked

jax.config.update("jax_platform_name", "cpu")


def paths(seed, B, L=6, d=2):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * 0.2


@pytest.mark.slow
def test_symmetric_fused_is_differentiable_exact_and_halves_solves():
    """Acceptance: sigkernel_gram(X) on the fused backend is differentiable
    end-to-end via the exact backward, agrees with the reference solver to
    f32 tolerance, and issues <= Bx(Bx+1)/2 + pad pair-solves."""
    Bx = 5
    X = paths(0, Bx, L=7, d=3)

    with dispatch.count_pair_solves() as c:
        K = sigkernel_gram(X, backend="pallas_fused")
    assert c.total <= Bx * (Bx + 1) // 2  # no padding in the dense sym path

    K_ref = sigkernel_gram(X, X, symmetric=False, backend="reference")
    np.testing.assert_allclose(K, K_ref, rtol=5e-4, atol=1e-5)

    g = jax.grad(lambda q: sigkernel_gram(q, backend="pallas_fused").sum())(X)
    g_ref = jax.grad(
        lambda q: sigkernel_gram(q, q, symmetric=False,
                                 backend="reference").sum())(X)
    np.testing.assert_allclose(g, g_ref, rtol=5e-4, atol=1e-5)
    assert np.isfinite(np.asarray(g)).all()


def test_symmetric_halves_solves_vs_full():
    X = paths(1, 4)
    with dispatch.count_pair_solves() as c_sym:
        sigkernel_gram(X, backend="reference")
    with dispatch.count_pair_solves() as c_full:
        sigkernel_gram(X, X, symmetric=False, backend="reference")
    assert c_sym.total == 10 and c_full.total == 16


@pytest.mark.slow
def test_blocked_pads_non_divisible_batch():
    X, Y = paths(2, 5), paths(3, 4, L=8)
    K_dense = sigkernel_gram(X, Y, backend="reference")
    for b in ("reference", "antidiag", "pallas_fused"):
        K = sigkernel_gram(X, Y, row_block=2, backend=b)  # 5 % 2 != 0
        np.testing.assert_allclose(K, K_dense, rtol=5e-4, atol=1e-5)
    # grad flows through the padded blocks
    g = jax.grad(
        lambda q: sigkernel_gram(q, Y, row_block=2,
                                 backend="reference").sum())(X)
    g_ref = jax.grad(
        lambda q: sigkernel_gram(q, Y, backend="reference").sum())(X)
    np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-7)


def test_blocked_symmetric_matches_full():
    X = paths(4, 5)
    K = sigkernel_gram(X, row_block=2, backend="antidiag")
    K_ref = sigkernel_gram(X, X, symmetric=False, backend="reference")
    np.testing.assert_allclose(K, K_ref, rtol=5e-4, atol=1e-5)


def test_gram_blocked_shim_keeps_old_call_sites_working():
    X, Y = paths(5, 4), paths(6, 3)
    K = sigkernel_gram_blocked(X, Y, row_block=2)
    np.testing.assert_allclose(K, sigkernel_gram(X, Y, backend="reference"),
                               rtol=2e-4, atol=1e-5)


def test_engine_under_jit():
    X, Y = paths(7, 3), paths(8, 4)
    K = jax.jit(lambda a, b: sigkernel_gram(a, b, backend="antidiag"))(X, Y)
    np.testing.assert_allclose(K, sigkernel_gram(X, Y, backend="reference"),
                               rtol=2e-4, atol=1e-5)


def test_symmetric_validation():
    X, Y = paths(9, 3), paths(10, 3)
    with pytest.raises(ValueError, match="symmetric=True"):
        sigkernel_gram(X, Y, symmetric=True)
    sigkernel_gram(X, X, symmetric=True)  # Y is X: allowed
    with pytest.raises(ValueError, match="symmetric=False requires Y"):
        sigkernel_gram(X, symmetric=False)
    with pytest.raises(ValueError, match=r"\(B, L, d\)"):
        sigkernel_gram(X[0])


def test_symmetric_auto_chunks_large_pair_gather(monkeypatch):
    """Above the gather budget the symmetric path self-chunks instead of
    replicating all Bx(Bx+1)/2 increment pairs in memory at once."""
    from repro.core import gram as gram_mod
    X = paths(13, 6)
    # force the budget below this problem's gather footprint
    monkeypatch.setattr(gram_mod, "_SYM_GATHER_BUDGET",
                        8 * 6 * 5 * 2)  # one row-block's worth
    with dispatch.count_pair_solves() as c:
        K = sigkernel_gram(X, backend="reference")
    assert c.total >= 21  # pairs + chunk padding
    K_ref = sigkernel_gram(X, X, symmetric=False, backend="reference")
    np.testing.assert_allclose(K, K_ref, rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_losses_route_through_engine():
    X, Y = paths(11, 4), paths(12, 4)
    with dispatch.count_pair_solves() as c:
        m = losses.mmd2(X, Y)
    # Kxx + Kyy upper triangles (10 each) + dense Kxy (16)
    assert c.total == 10 + 10 + 16
    assert np.isfinite(float(m))
    m_fused = losses.mmd2(X, Y, backend="pallas_fused")
    np.testing.assert_allclose(float(m_fused), float(m), rtol=5e-4,
                               atol=1e-5)
    g = jax.grad(lambda q: losses.mmd2(q, Y, backend="pallas_fused"))(X)
    assert np.isfinite(np.asarray(g)).all()
