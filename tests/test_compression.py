"""Gradient compression: quantisation invariants + EF convergence, and a
multi-device shard_map integration test (subprocess with forced devices)."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compression import quantize_int8, dequantize

jax.config.update("jax_platform_name", "cpu")


def test_quantize_bounds():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 10
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.abs(dequantize(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF compensates: the running sum of compressed values tracks the true
    running sum (error does not accumulate)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (128,))
    ef = jnp.zeros_like(x)
    acc_true, acc_comp = jnp.zeros_like(x), jnp.zeros_like(x)
    for _ in range(50):
        g = x + ef
        q, s = quantize_int8(g)
        deq = dequantize(q, s)
        ef = g - deq
        acc_true += x
        acc_comp += deq
    rel = float(jnp.abs(acc_comp - acc_true).max() / jnp.abs(acc_true).max())
    assert rel < 0.01, rel


def test_psum_compressed_multidevice():
    """int8-EF pod reduce inside shard_map matches the exact mean."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.compression import psum_compressed

        try:
            from jax import shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((4,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
        ef = jnp.zeros((4, 256))

        def f(g, ef):
            m, ef_new = psum_compressed(g[0], ef[0], "pod")
            return m[None], ef_new[None]

        fm = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                       out_specs=(P("pod"), P("pod")))
        mean_c, ef_new = fm(g, ef)
        exact = g.mean(axis=0)
        err = float(jnp.abs(mean_c[0] - exact).max())
        scale = float(jnp.abs(g).max()) / 127
        assert err <= 2 * scale + 1e-6, (err, scale)
        # every pod row agrees
        assert float(jnp.abs(mean_c - mean_c[0:1]).max()) < 1e-7
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__('os').environ,
                            "PYTHONPATH": "src"},
                       cwd=__import__('os').path.join(
                           __import__('os').path.dirname(__file__), ".."))
    assert "OK" in r.stdout, r.stdout + r.stderr
