"""Checkpoint manager: atomic roundtrip, gc, resume, async safety."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager

jax.config.update("jax_platform_name", "cpu")


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "b": {"w": jnp.arange(6, dtype=jnp.int32).reshape(2, 3),
                  "s": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = tree()
    mgr.save(5, t, blocking=True)
    restored, step = mgr.restore(5, t)
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for s in (1, 2, 3):
        mgr.save(s, tree(s))
    mgr.wait()
    assert mgr.latest_step() == 3


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(1, 6):
        mgr.save(s, tree(), blocking=True)
    assert mgr.all_steps() == [4, 5]


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree(), blocking=True)
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_restore_with_sharding(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = tree()
    mgr.save(1, t, blocking=True)
    from jax.sharding import SingleDeviceSharding
    shard = jax.tree.map(
        lambda _: SingleDeviceSharding(jax.devices()[0]), t)
    restored, _ = mgr.restore(1, t, shard)
    assert all(x.sharding == SingleDeviceSharding(jax.devices()[0])
               for x in jax.tree.leaves(restored))
