"""Sig-kernel losses: MMD properties, scoring rule, differentiability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import GridConfig
from repro.core import losses
from repro.data.synthetic import gbm_paths, fbm_paths

jax.config.update("jax_platform_name", "cpu")


def test_mmd_same_distribution_small():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = gbm_paths(k1, 12, 10, 2)
    Y = gbm_paths(k2, 12, 10, 2)
    Z = fbm_paths(jax.random.PRNGKey(3), 12, 10, 2) * 0.5
    same = float(losses.mmd2(X, Y, grid=GridConfig(1, 1)))
    diff = float(losses.mmd2(X, Z, grid=GridConfig(1, 1)))
    assert diff > same


def test_mmd_biased_nonnegative():
    X = gbm_paths(jax.random.PRNGKey(1), 8, 10, 2)
    Y = fbm_paths(jax.random.PRNGKey(2), 8, 10, 2) * 0.5
    assert float(losses.mmd2(X, Y, unbiased=False)) > -1e-6


def test_mmd_gradient_flows():
    X = gbm_paths(jax.random.PRNGKey(3), 6, 8, 2)
    Y = gbm_paths(jax.random.PRNGKey(4), 6, 8, 2)
    g = jax.grad(lambda q: losses.mmd2(q, Y, unbiased=False))(X)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_batch_of_one_raises_instead_of_nan():
    """Regression: the unbiased 1/(b·(b−1)) normaliser used to return NaN
    silently for b = 1; now it raises, and the biased estimator still works."""
    X1 = gbm_paths(jax.random.PRNGKey(0), 1, 8, 2)
    Y = gbm_paths(jax.random.PRNGKey(1), 4, 8, 2)
    with pytest.raises(ValueError, match="unbiased"):
        losses.mmd2(X1, Y)
    with pytest.raises(ValueError, match="NaN"):
        losses.mmd2(Y, X1)
    with pytest.raises(ValueError, match="ensemble"):
        losses.scoring_rule(X1, Y[0])
    m = float(losses.mmd2(X1, Y, unbiased=False))
    assert np.isfinite(m)


def test_scoring_rule_finite():
    X = gbm_paths(jax.random.PRNGKey(5), 8, 10, 2)
    y = gbm_paths(jax.random.PRNGKey(6), 1, 10, 2)[0]
    s = losses.scoring_rule(X, y)
    assert np.isfinite(float(s))


@pytest.mark.slow
def test_mmd_minimised_at_match():
    """Gradient descent on MMD moves samples toward the target set."""
    key = jax.random.PRNGKey(7)
    target = gbm_paths(key, 8, 8, 2)
    X = 0.5 * fbm_paths(jax.random.PRNGKey(8), 8, 8, 2)
    loss0 = float(losses.mmd2(X, target, unbiased=False))
    lr = 0.5
    for _ in range(10):
        g = jax.grad(lambda q: losses.mmd2(q, target, unbiased=False))(X)
        X = X - lr * g
    loss1 = float(losses.mmd2(X, target, unbiased=False))
    assert loss1 < loss0


def test_legacy_shim_parity_across_all_three_losses():
    """Every loss accepts the same legacy time_aug=/lead_lag= aliases with
    warn-once semantics and results identical to the config-object call —
    sig_aux_loss used to TypeError on them (regression)."""
    import inspect
    import warnings

    from repro.core import dispatch
    from repro.core.config import TransformPipeline

    for fn in (losses.mmd2, losses.scoring_rule, losses.sig_aux_loss):
        params = inspect.signature(fn).parameters
        for name in ("transforms", "grid", "static_kernel", "backend",
                     "row_block", "lengths", "lam1", "lam2", "time_aug",
                     "lead_lag", "use_pallas"):
            assert name in params, f"{fn.__name__} lacks {name}="

    X = gbm_paths(jax.random.PRNGKey(0), 3, 8, 2)
    Y = gbm_paths(jax.random.PRNGKey(1), 3, 8, 2)
    H = gbm_paths(jax.random.PRNGKey(2), 3, 8, 4)
    proj = jax.random.normal(jax.random.PRNGKey(3), (4, 2)) * 0.3
    cfg = TransformPipeline(time_aug=True, lead_lag=True)
    legacy = dict(time_aug=True, lead_lag=True)
    cases = [
        (lambda **kw: losses.mmd2(X, Y, unbiased=False, **kw)),
        (lambda **kw: losses.scoring_rule(X, Y[0], **kw)),
        (lambda **kw: losses.sig_aux_loss(H, X, proj=proj, **kw)),
    ]
    for call in cases:
        dispatch.reset_warned_sites()
        want = call(transforms=cfg)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            got = call(**legacy)
            call(**legacy)  # same call-site: no second warning
        assert [x.category for x in w] == [DeprecationWarning], \
            f"expected exactly one warning, got {[str(x.message) for x in w]}"
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sig_aux_loss_ragged_sides():
    H = gbm_paths(jax.random.PRNGKey(2), 3, 9, 4)
    T = gbm_paths(jax.random.PRNGKey(4), 3, 11, 2)
    proj = jax.random.normal(jax.random.PRNGKey(3), (4, 2)) * 0.3
    lens_h = jnp.asarray([4, 9, 6])
    lens_t = jnp.asarray([11, 3, 7])
    v = losses.sig_aux_loss(H, T, proj=proj, lengths=lens_h,
                            lengths_target=lens_t)
    assert np.isfinite(float(v))
    # padding must be invisible: poisoning it changes nothing
    Hp = np.asarray(H).copy()
    for i, n in enumerate([4, 9, 6]):
        Hp[i, n:] = 123.0
    v2 = losses.sig_aux_loss(jnp.asarray(Hp), T, proj=proj, lengths=lens_h,
                             lengths_target=lens_t)
    np.testing.assert_allclose(float(v), float(v2), rtol=1e-6)
