"""Sig-kernel losses: MMD properties, scoring rule, differentiability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import GridConfig
from repro.core import losses
from repro.data.synthetic import gbm_paths, fbm_paths

jax.config.update("jax_platform_name", "cpu")


def test_mmd_same_distribution_small():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    X = gbm_paths(k1, 12, 10, 2)
    Y = gbm_paths(k2, 12, 10, 2)
    Z = fbm_paths(jax.random.PRNGKey(3), 12, 10, 2) * 0.5
    same = float(losses.mmd2(X, Y, grid=GridConfig(1, 1)))
    diff = float(losses.mmd2(X, Z, grid=GridConfig(1, 1)))
    assert diff > same


def test_mmd_biased_nonnegative():
    X = gbm_paths(jax.random.PRNGKey(1), 8, 10, 2)
    Y = fbm_paths(jax.random.PRNGKey(2), 8, 10, 2) * 0.5
    assert float(losses.mmd2(X, Y, unbiased=False)) > -1e-6


def test_mmd_gradient_flows():
    X = gbm_paths(jax.random.PRNGKey(3), 6, 8, 2)
    Y = gbm_paths(jax.random.PRNGKey(4), 6, 8, 2)
    g = jax.grad(lambda q: losses.mmd2(q, Y, unbiased=False))(X)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_batch_of_one_raises_instead_of_nan():
    """Regression: the unbiased 1/(b·(b−1)) normaliser used to return NaN
    silently for b = 1; now it raises, and the biased estimator still works."""
    X1 = gbm_paths(jax.random.PRNGKey(0), 1, 8, 2)
    Y = gbm_paths(jax.random.PRNGKey(1), 4, 8, 2)
    with pytest.raises(ValueError, match="unbiased"):
        losses.mmd2(X1, Y)
    with pytest.raises(ValueError, match="NaN"):
        losses.mmd2(Y, X1)
    with pytest.raises(ValueError, match="ensemble"):
        losses.scoring_rule(X1, Y[0])
    m = float(losses.mmd2(X1, Y, unbiased=False))
    assert np.isfinite(m)


def test_scoring_rule_finite():
    X = gbm_paths(jax.random.PRNGKey(5), 8, 10, 2)
    y = gbm_paths(jax.random.PRNGKey(6), 1, 10, 2)[0]
    s = losses.scoring_rule(X, y)
    assert np.isfinite(float(s))


def test_mmd_minimised_at_match():
    """Gradient descent on MMD moves samples toward the target set."""
    key = jax.random.PRNGKey(7)
    target = gbm_paths(key, 8, 8, 2)
    X = 0.5 * fbm_paths(jax.random.PRNGKey(8), 8, 8, 2)
    loss0 = float(losses.mmd2(X, target, unbiased=False))
    lr = 0.5
    for _ in range(10):
        g = jax.grad(lambda q: losses.mmd2(q, target, unbiased=False))(X)
        X = X - lr * g
    loss1 = float(losses.mmd2(X, target, unbiased=False))
    assert loss1 < loss0
