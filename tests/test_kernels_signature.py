"""Pallas Horner signature kernel vs the direct-algorithm oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.signature import ops, ref

jax.config.update("jax_platform_name", "cpu")

CASES = [
    (3, 10, 3, 4), (2, 7, 2, 6), (5, 300, 4, 3), (1, 5, 8, 3),
    (130, 20, 5, 4), (2, 2, 2, 2), (4, 513, 3, 3),
]


def incs(seed, B, L, d, dtype=jnp.float32):
    z = jax.random.normal(jax.random.PRNGKey(seed), (B, L - 1, d)) * 0.3
    return z.astype(dtype)


@pytest.mark.parametrize("B,L,d,N", CASES)
def test_forward_vs_ref(B, L, d, N):
    z = incs(0, B, L, d)
    s_pal = ops.signature_from_increments(z, N)
    s_ref = ref.signature_from_increments(z, N)
    denom = max(float(jnp.abs(s_ref).max()), 1e-6)
    assert float(jnp.abs(s_pal - s_ref).max()) / denom < 5e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    z = incs(1, 2, 9, 3, dtype)
    s_pal = ops.signature_from_increments(z, 3)
    s_ref = ref.signature_from_increments(z.astype(jnp.float32), 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-5
    denom = max(float(jnp.abs(s_ref).max()), 1e-6)
    assert float(jnp.abs(np.asarray(s_pal, np.float32) - s_ref).max()) / denom < tol


@pytest.mark.slow
def test_gradients_exact():
    from repro.core.signature import signature, signature_direct
    p = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 3)) * 0.3
    g1 = jax.grad(lambda q: signature(q, 4, backend="pallas").sum())(p)
    g2 = jax.grad(lambda q: signature_direct(q, 4).sum())(p)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_batch_tile_padding():
    """Batch sizes that do not divide the lane tile must round-trip."""
    for B in (1, 7, 129):
        z = incs(3, B, 6, 2)
        s_pal = ops.signature_from_increments(z, 3)
        s_ref = ref.signature_from_increments(z, 3)
        np.testing.assert_allclose(s_pal, s_ref, rtol=1e-4, atol=1e-6)


def test_length_block_boundary():
    """L-1 crossing the LB block size exercises the carried-scratch path."""
    import repro.kernels.signature.ops as sops
    old = sops._LB
    try:
        sops._LB = 4
        z = incs(4, 2, 11, 2)   # L-1 = 10 -> 3 blocks with padding
        s_pal = ops.signature_from_increments(z, 3)
        s_ref = ref.signature_from_increments(z, 3)
        np.testing.assert_allclose(s_pal, s_ref, rtol=1e-4, atol=1e-6)
    finally:
        sops._LB = old


# ---------------------------------------------------------------------------
# fused increments -> log-signature epilogue
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["lyndon", "brackets", "expand"])
def test_logsignature_fused_vs_pure(mode):
    from repro.core.logsignature import logsignature_from_increments
    z = incs(5, 3, 9, 3)
    ls_pal = ops.logsignature_from_increments(z, 4, mode)
    ls_ref = logsignature_from_increments(z, 4, mode)
    denom = max(float(jnp.abs(ls_ref).max()), 1e-6)
    assert float(jnp.abs(ls_pal - ls_ref).max()) / denom < 5e-5


@pytest.mark.slow
def test_logsignature_fused_gradients():
    from repro.core.logsignature import logsignature
    p = jax.random.normal(jax.random.PRNGKey(6), (2, 7, 3)) * 0.3
    g1 = jax.grad(lambda q: logsignature(q, 3, backend="pallas").sum())(p)
    g2 = jax.grad(lambda q: logsignature(q, 3, backend="reference").sum())(p)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_default_use_pallas_is_backend_aware():
    assert ops.default_use_pallas() == (jax.default_backend() == "tpu")
