"""Data pipeline: determinism, resumability, shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import TokenLM, PathData, gbm_paths

jax.config.update("jax_platform_name", "cpu")


def test_deterministic_and_step_indexed():
    d = TokenLM(vocab=100, seq=16, batch=4, seed=7)
    b1 = d.batch_at(12)
    b2 = d.batch_at(12)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(13)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_resume_exactness():
    """Restarting at step k yields the identical stream — no pipeline state."""
    d = TokenLM(vocab=100, seq=8, batch=2, seed=1)
    first = [d.batch_at(s)["tokens"] for s in range(10)]
    d2 = TokenLM(vocab=100, seq=8, batch=2, seed=1)   # "restarted process"
    second = [d2.batch_at(s)["tokens"] for s in range(5, 10)]
    for a, b in zip(first[5:], second):
        np.testing.assert_array_equal(a, b)


def test_labels_shifted():
    d = TokenLM(vocab=50, seq=8, batch=2, seed=0)
    b = d.batch_at(0)
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)


def test_token_range():
    d = TokenLM(vocab=37, seq=64, batch=8, seed=3)
    b = d.batch_at(2)
    assert int(b["tokens"].min()) >= 0
    assert int(b["tokens"].max()) < 37


def test_gbm_paths_start_at_zero():
    p = gbm_paths(jax.random.PRNGKey(0), 4, 10, 3)
    np.testing.assert_allclose(p[:, 0], jnp.zeros((4, 3)), atol=1e-6)
    assert np.isfinite(np.asarray(p)).all()


def test_path_data():
    d = PathData(batch=3, length=12, dim=2, seed=5)
    p1, p2 = d.batch_at(4), d.batch_at(4)
    np.testing.assert_array_equal(p1, p2)
    assert p1.shape == (3, 12, 2)
