"""Truncated signatures: algorithms, identities, gradients, transforms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

import repro.core.tensoralg as ta
import repro.core.transforms as tf
from repro.core.config import TransformPipeline
from repro.core.signature import (signature, signature_direct,
                                  signature_combine, path_increments)

jax.config.update("jax_platform_name", "cpu")


def paths(seed, B=2, L=10, d=3, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), d=st.integers(2, 4), depth=st.integers(2, 5),
       L=st.integers(2, 12))
def test_direct_equals_horner(seed, d, depth, L):
    p = paths(seed, 2, L, d)
    np.testing.assert_allclose(signature_direct(p, depth), signature(p, depth),
                               rtol=2e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 99), split=st.integers(2, 8))
def test_chen_identity(seed, split):
    p = paths(seed, 2, 10, 3)
    full = signature(p, 4)
    a = signature(p[:, :split + 1], 4)
    b = signature(p[:, split:], 4)
    np.testing.assert_allclose(signature_combine(a, b, 3, 4), full,
                               rtol=2e-5, atol=1e-6)


def test_time_reversal_inverse():
    p = paths(3)
    s = signature(p, 4)
    s_rev = signature(p[:, ::-1], 4)
    ident = ta.chen(s, s_rev, 3, 4)
    np.testing.assert_allclose(ident, np.zeros_like(ident), atol=1e-5)


def test_reparameterisation_invariance():
    """Inserting duplicate points (zero increments) never changes S(x)."""
    p = paths(4, 2, 8, 3)
    p_dup = jnp.concatenate([p[:, :4], p[:, 3:4], p[:, 4:]], axis=1)
    np.testing.assert_allclose(signature(p, 4), signature(p_dup, 4),
                               rtol=1e-5, atol=1e-6)


def test_linear_path_is_tensor_exp():
    z = jnp.array([[0.3, -0.5]])
    p = jnp.stack([jnp.zeros((1, 2)), z], axis=1)       # one segment
    np.testing.assert_allclose(signature(p, 5), ta.tensor_exp(z, 5),
                               rtol=1e-6, atol=1e-7)


def test_custom_vjp_matches_autodiff():
    p = paths(5, 2, 8, 3)
    g1 = jax.grad(lambda q: signature(q, 4).sum())(p)
    g2 = jax.grad(lambda q: signature_direct(q, 4).sum())(p)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_grad_finite_differences():
    p = np.asarray(paths(6, 1, 5, 2))
    f = lambda q: float(signature(jnp.asarray(q), 3).sum())
    g = jax.grad(lambda q: signature(q, 3).sum())(jnp.asarray(p))
    eps = 1e-4
    for idx in [(0, 0, 0), (0, 2, 1), (0, 4, 0)]:
        pp, pm = p.copy(), p.copy()
        pp[idx] += eps
        pm[idx] -= eps
        fd = (f(pp) - f(pm)) / (2 * eps)
        assert abs(fd - float(g[idx])) < 1e-2 * max(1.0, abs(fd))


def test_stream_mode():
    p = paths(7, 2, 6, 3)
    stream = signature(p, 3, stream=True)
    assert stream.shape[-2] == 5
    np.testing.assert_allclose(stream[:, -1], signature(p, 3),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(stream[:, 0],
                               signature(p[:, :2], 3), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("time_aug,lead_lag", [(True, False), (False, True),
                                               (True, True)])
def test_transforms_on_the_fly_vs_materialised(time_aug, lead_lag):
    p = paths(8, 2, 7, 2)
    q = p
    if lead_lag:
        q = tf.lead_lag(q)
    if time_aug:
        q = tf.time_augment(q)
    np.testing.assert_allclose(
        signature(p, 3, transforms=TransformPipeline(
            time_aug=time_aug, lead_lag=lead_lag)),
        signature(q, 3), rtol=1e-5, atol=1e-6)


def test_transform_increments_match_path_increments():
    p = paths(9, 1, 6, 2)
    z = tf.transform_increments(path_increments(p), True, True)
    z_mat = path_increments(tf.time_augment(tf.lead_lag(p)))
    np.testing.assert_allclose(z, z_mat, atol=1e-6)


def test_transforms_differentiable():
    p = paths(10, 1, 6, 2)
    g = jax.grad(lambda q: signature(q, 3, transforms=TransformPipeline(
        lead_lag=True, time_aug=True)).sum())(p)
    assert np.isfinite(np.asarray(g)).all()
