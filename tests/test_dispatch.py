"""Backend registry: capability flags, auto-resolution, deprecation shims,
and the cross-backend agreement contract (every registered backend computes
the same sigkernel / Gram forward AND gradient within f32 tolerance)."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # the registry tests below run without hypothesis; only the
    # random-shape property sweep needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property tests need hypothesis: pip install -r requirements-dev.txt")

from repro.core import dispatch
from repro.core.config import GridConfig, TransformPipeline
from repro.core.gram import sigkernel_gram
from repro.core.sigkernel import sigkernel

jax.config.update("jax_platform_name", "cpu")

SIGKERNEL_BACKENDS = dispatch.backends_for("sigkernel")
GRAM_BACKENDS = dispatch.backends_for("gram")
#: exact Gram backends only — the agreement contract below compares against
#: the reference solver bit-for-bit-ish; approximate feature-map backends
#: (rff/nystroem) answer a different question and are covered by
#: tests/test_features.py
EXACT_GRAM_BACKENDS = tuple(b for b in GRAM_BACKENDS
                            if not dispatch.get(b).approximate)


def paths(seed, B, L, d, scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


# ---------------------------------------------------------------------------
# registry mechanics
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert set(SIGKERNEL_BACKENDS) == {"reference", "antidiag", "pallas",
                                       "pallas_fused"}
    # gram = every exact sigkernel backend + the approximate feature maps
    assert set(GRAM_BACKENDS) == set(SIGKERNEL_BACKENDS) | {"rff",
                                                            "nystroem"}
    assert set(EXACT_GRAM_BACKENDS) == set(SIGKERNEL_BACKENDS)
    assert dispatch.backends_for("signature") == ("pallas", "reference")
    spec = dispatch.get("pallas_fused")
    assert spec.fused and spec.gram_capable and spec.needs_tpu
    assert dispatch.get("reference").grad_exact
    for name in ("rff", "nystroem"):
        aspec = dispatch.get(name)
        assert aspec.approximate and aspec.gram_capable
        assert not aspec.grad_exact and not aspec.needs_tpu
        assert aspec.ops == frozenset({"gram"})
    assert not any(dispatch.get(b).approximate for b in SIGKERNEL_BACKENDS)


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown backend"):
        dispatch.get("cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        sigkernel_gram(paths(0, 2, 5, 2), backend="nope")


def test_op_capability_enforced():
    # antidiag has no signature implementation
    with pytest.raises(ValueError, match="does not implement"):
        dispatch.resolve("antidiag", op="signature")


def test_auto_resolution_on_cpu():
    assert dispatch.resolve("auto", op="signature") == "reference"
    assert dispatch.resolve("auto", op="sigkernel", grid_cells=16) == "reference"
    assert dispatch.resolve("auto", op="sigkernel",
                            grid_cells=1 << 20) == "antidiag"
    # explicit names pass through untouched
    assert dispatch.resolve("pallas", op="sigkernel") == "pallas"


def test_deprecation_shims_warn_and_route():
    dispatch.reset_warned_sites()
    X = paths(1, 2, 5, 2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        k_dep = sigkernel(X, X, use_pallas=False)
        K_dep = sigkernel_gram(X, X, solver="antidiag")
    cats = [x.category for x in w]
    assert cats.count(DeprecationWarning) == 2
    np.testing.assert_allclose(k_dep, sigkernel(X, X, backend="reference"),
                               rtol=1e-6)
    np.testing.assert_allclose(K_dep, sigkernel_gram(X, X, symmetric=False,
                                                     backend="antidiag"),
                               rtol=1e-6)


def test_use_pallas_none_stays_silent():
    X = paths(2, 2, 5, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sigkernel(X, X, use_pallas=None)  # historical documented auto


def test_deprecation_warns_once_per_call_site():
    dispatch.reset_warned_sites()
    X = paths(7, 2, 5, 2)

    def legacy_call():  # one fixed call-site, invoked repeatedly
        return sigkernel(X, X, use_pallas=False)

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy_call()
        legacy_call()
        legacy_call()
    assert [x.category for x in w] == [DeprecationWarning]
    assert "use_pallas= is deprecated" in str(w[0].message)
    # a *different* call-site still gets its own warning
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        sigkernel(X, X, use_pallas=False)
    assert [x.category for x in w] == [DeprecationWarning]
    # resetting the registry re-arms the original site
    dispatch.reset_warned_sites()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        legacy_call()
    assert [x.category for x in w] == [DeprecationWarning]


def test_deprecation_attributed_outside_repro_even_through_shims():
    import os
    from repro.core.sigkernel import sigkernel_gram as alias  # delegator
    dispatch.reset_warned_sites()
    X = paths(8, 2, 5, 2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        alias(X, X, solver="antidiag")  # two distinct call-sites reached
        alias(X, X, solver="antidiag")  # through the same internal shim
    assert [x.category for x in w] == [DeprecationWarning] * 2
    # the warning (and the dedup key) lands on THIS file, not the shim
    assert all(os.path.basename(x.filename) == os.path.basename(__file__)
               for x in w)


# ---------------------------------------------------------------------------
# cross-backend agreement (the dispatch contract)
# ---------------------------------------------------------------------------

def _agree_sigkernel(seed, l1, l2, Lx, Ly, d, time_aug, lead_lag):
    x = paths(seed, 2, Lx, d)
    y = paths(seed + 100, 2, Ly, d)
    kw = dict(grid=GridConfig(l1, l2),
              transforms=TransformPipeline(time_aug=time_aug,
                                           lead_lag=lead_lag))

    k_ref = sigkernel(x, y, backend="reference", **kw)
    g_ref = jax.grad(
        lambda q: sigkernel(q, y, backend="reference", **kw).sum())(x)
    for b in SIGKERNEL_BACKENDS:
        if b == "reference":
            continue
        if b == "pallas_fused" and x.shape[:-2] != y.shape[:-2]:
            continue
        k = sigkernel(x, y, backend=b, **kw)
        np.testing.assert_allclose(k, k_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"forward mismatch: {b}")
        g = jax.grad(lambda q: sigkernel(q, y, backend=b, **kw).sum())(x)
        np.testing.assert_allclose(g, g_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"grad mismatch: {b}")


def _agree_gram(seed, l1, l2, Bx, By, L, d):
    X = paths(seed, Bx, L, d)
    Y = paths(seed + 100, By, L, d)
    kw = dict(grid=GridConfig(l1, l2))

    K_ref = sigkernel_gram(X, Y, backend="reference", **kw)
    g_ref = jax.grad(
        lambda q: sigkernel_gram(q, Y, backend="reference", **kw).sum())(X)
    for b in EXACT_GRAM_BACKENDS:
        if b == "reference":
            continue
        K = sigkernel_gram(X, Y, backend=b, **kw)
        np.testing.assert_allclose(K, K_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"gram forward mismatch: {b}")
        g = jax.grad(
            lambda q: sigkernel_gram(q, Y, backend=b, **kw).sum())(X)
        np.testing.assert_allclose(g, g_ref, rtol=5e-4, atol=1e-5,
                                   err_msg=f"gram grad mismatch: {b}")


def _agree_symmetric(seed, Bx):
    X = paths(seed, Bx, 6, 2)
    K_full = sigkernel_gram(X, X, symmetric=False, backend="reference")
    for b in EXACT_GRAM_BACKENDS:
        K = sigkernel_gram(X, backend=b)
        np.testing.assert_allclose(K, K_full, rtol=5e-4, atol=1e-5,
                                   err_msg=f"symmetric mismatch: {b}")


# fixed cells so the contract is exercised even without hypothesis
@pytest.mark.parametrize("seed,l1,l2,Lx,Ly,d,ta,ll", [
    (0, 0, 0, 5, 7, 2, False, False),
    (1, 1, 2, 6, 4, 3, True, False),
    (2, 2, 0, 8, 8, 1, False, True),
])
@pytest.mark.slow
def test_backends_agree_sigkernel_cases(seed, l1, l2, Lx, Ly, d, ta, ll):
    _agree_sigkernel(seed, l1, l2, Lx, Ly, d, ta, ll)


@pytest.mark.parametrize("seed,l1,l2,Bx,By,L,d", [
    (0, 0, 0, 3, 4, 6, 2), (1, 1, 1, 2, 5, 5, 3), (2, 0, 1, 4, 1, 7, 2),
])
@pytest.mark.slow
def test_backends_agree_gram_cases(seed, l1, l2, Bx, By, L, d):
    _agree_gram(seed, l1, l2, Bx, By, L, d)


def test_backends_agree_symmetric_case():
    _agree_symmetric(3, 4)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 99), l1=st.integers(0, 2),
           l2=st.integers(0, 2), Lx=st.integers(4, 8), Ly=st.integers(4, 8),
           d=st.integers(1, 3), time_aug=st.booleans(),
           lead_lag=st.booleans())
    def test_all_backends_agree_sigkernel_property(seed, l1, l2, Lx, Ly, d,
                                                   time_aug, lead_lag):
        _agree_sigkernel(seed, l1, l2, Lx, Ly, d, time_aug, lead_lag)

    @needs_hypothesis
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 99), l1=st.integers(0, 1),
           l2=st.integers(0, 1), Bx=st.integers(1, 4), By=st.integers(1, 4),
           L=st.integers(4, 7), d=st.integers(1, 3))
    def test_all_backends_agree_gram_property(seed, l1, l2, Bx, By, L, d):
        _agree_gram(seed, l1, l2, Bx, By, L, d)

    @needs_hypothesis
    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 99), Bx=st.integers(2, 4))
    def test_all_backends_agree_symmetric_property(seed, Bx):
        _agree_symmetric(seed, Bx)


def test_deprecation_attributed_to_user_module_named_repro(tmp_path):
    """Regression: the frame walk used to skip any frame whose top-level
    module *name* was "repro", so a user script/package that merely happens
    to be called repro.py absorbed neither warning nor dedup key.  The walk
    now skips only frames whose files live under this library's install
    directory."""
    dispatch.reset_warned_sites()
    X = paths(9, 2, 5, 2)
    user_file = tmp_path / "repro.py"
    user_file.write_text("def call(fn, x):\n    return fn(x, x,"
                         " use_pallas=False)\n")
    ns = {"__name__": "repro"}  # what the buggy name-based skip keyed on
    exec(compile(user_file.read_text(), str(user_file), "exec"), ns)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        ns["call"](sigkernel, X)
        ns["call"](sigkernel, X)  # same user call-site: deduped
    assert [x.category for x in w] == [DeprecationWarning]
    assert w[0].filename == str(user_file), (
        f"warning attributed to {w[0].filename}, not the user module")


def test_warned_sites_growth_is_bounded(monkeypatch):
    """A caller minting fresh call-sites forever (exec'd snippets) must not
    grow the dedup set without bound — past the cap new sites still warn,
    they just stop deduplicating."""
    dispatch.reset_warned_sites()
    monkeypatch.setattr(dispatch, "_MAX_WARNED_SITES", 3)
    X = paths(10, 2, 5, 2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for i in range(6):  # six distinct synthetic call-sites
            ns = {}
            exec(compile("def call(fn, x):\n    return fn(x, x,"
                         " use_pallas=False)\n", f"<site-{i}>", "exec"), ns)
            ns["call"](sigkernel, X)
    assert len(w) == 6  # every new site warns, capped set or not
    assert len(dispatch._warned_sites) <= 3
