"""Streaming ``repro.Path``: prefix store, O(1) queries, incremental update.

Contracts under test (ISSUE 9 acceptance criteria):

* prefix queries ``signature(0, j)`` are **bitwise** the reference
  full-recompute oracle (the prefix store IS the reference stream scan),
  and agree with the Pallas exact backend to its own cross-backend
  tolerance; general ``(i, j)`` intervals are exact group arithmetic —
  tight-allclose vs a fresh recompute and exactly consistent under
  Chen-splicing;
* interval / rolling queries perform ZERO Horner scan steps and O(1)
  Chen combines (asserted via the op counters in ``repro.core.dispatch``,
  which record at trace time);
* ``update()`` scans only the appended chunk (scan-step counter == chunk
  bucket, not path length) and reuses a warm jit trace for same-bucket
  appends (asserted via ``repro.stream.trace_counts``);
* buffers use the PR 5 power-of-two buckets: nearby lengths share one
  build trace;
* gradients flow through the stored prefixes back to the input points.

Counter tests use distinctive (d, depth) combinations so their kernels
are traced fresh inside the test regardless of what ran earlier in the
process (the counters record nothing on warm-cache calls, by design).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.config import TransformPipeline
from repro.core.logsignature import logsignature
from repro.core.signature import signature
from repro.stream import (Path, RollingConfig, coalesced_update,
                          trace_counts)

jax.config.update("jax_platform_name", "cpu")


def _pts(seed, *shape, scale=0.3):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


def _bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return a.dtype == b.dtype and a.shape == b.shape \
        and a.tobytes() == b.tobytes()


PIPELINES = {
    "plain": TransformPipeline(),
    "lead_lag": TransformPipeline(lead_lag=True),
}


# ---------------------------------------------------------------------------
# interval queries vs the full-recompute oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", PIPELINES, ids=PIPELINES.keys())
def test_prefix_queries_bitwise_vs_reference(pipeline):
    tp = PIPELINES[pipeline]
    pts = _pts(0, 13, 3)
    p = Path.from_points(pts, depth=3, transforms=tp)
    for j in (2, 5, 11, 13):
        oracle = signature(pts[:j], 3, transforms=tp, backend="reference")
        assert _bitwise(p.signature(0, j), oracle), j
    # the no-arg full signature is the j = length prefix
    assert _bitwise(p.signature(),
                    signature(pts, 3, transforms=tp, backend="reference"))


def test_prefix_queries_vs_pallas_backend():
    # the Pallas kernel is exact but uses its own op order: compare to its
    # own cross-backend tolerance (tests/test_kernels_signature.py)
    pts = _pts(1, 10, 3)
    p = Path.from_points(pts, depth=3)
    for j in (4, 10):
        oracle = signature(pts[:j], 3, backend="pallas")
        got = p.signature(0, j)
        denom = max(float(jnp.abs(oracle).max()), 1e-6)
        assert float(jnp.abs(got - oracle).max()) / denom < 5e-5, j


@pytest.mark.parametrize("pipeline", PIPELINES, ids=PIPELINES.keys())
@pytest.mark.parametrize("i,j", [(1, 3), (3, 8), (5, 13), (11, 13)])
def test_interval_queries_vs_recompute(pipeline, i, j):
    tp = PIPELINES[pipeline]
    pts = _pts(2, 13, 3)
    p = Path.from_points(pts, depth=3, transforms=tp)
    oracle = signature(pts[i:j], 3, transforms=tp, backend="reference")
    got = p.signature(i, j)
    # exact group arithmetic: a few ULPs of cancellation vs the fresh scan
    np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-5)


def test_interval_queries_chen_consistent():
    # exactness the float tolerance can't show: splicing two interval
    # signatures that share an endpoint (points[2:8] ends where points[7:14]
    # starts) through Chen reproduces the whole interval to machine roundoff
    from repro.core.tensoralg import chen
    pts = _pts(3, 16, 2)
    p = Path.from_points(pts, depth=4)
    a = p.signature(2, 8)
    b = p.signature(7, 14)
    ab = p.signature(2, 14)
    np.testing.assert_allclose(chen(a, b, 2, 4), ab, rtol=2e-6, atol=1e-7)


def test_logsignature_intervals():
    pts = _pts(4, 12, 3)
    p = Path.from_points(pts, depth=3)
    for mode in ("lyndon", "brackets", "expand"):
        oracle = logsignature(pts[3:9], 3, mode=mode, backend="reference")
        got = p.logsignature(3, 9, mode=mode)
        np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-5)
    # prefix logsignatures ride on the bitwise prefix store
    oracle0 = logsignature(pts[:7], 3, backend="reference")
    np.testing.assert_allclose(p.logsignature(0, 7), oracle0,
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# O(1) queries: zero scan steps, one combine (counters record at trace)
# ---------------------------------------------------------------------------

def test_interval_query_is_one_combine_no_scan():
    # d=4 / depth=2 is unique to this test -> the query kernel traces here
    pts = _pts(5, 40, 4)
    p = Path.from_points(pts, depth=2)
    with dispatch.count_scan_steps() as sc, dispatch.count_combines() as cc:
        p.signature(3, 37)
    assert sc.total == 0, "interval query re-scanned the path"
    assert cc.total == 1, cc.total
    # warm repeat records nothing (same trace) and still agrees
    with dispatch.count_scan_steps() as sc2:
        q = p.signature(3, 37)
    assert sc2.total == 0
    oracle = signature(pts[3:37], 2, backend="reference")
    np.testing.assert_allclose(q, oracle, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# update(): O(chunk) scans, warm traces, agreement vs recompute
# ---------------------------------------------------------------------------

def test_update_agrees_with_recompute():
    pts = _pts(6, 11, 3)
    more = _pts(7, 6, 3)
    p = Path.from_points(pts, depth=3).update(more)
    full = jnp.concatenate([pts, more])
    assert len(p) == 17
    np.testing.assert_allclose(
        p.signature(), signature(full, 3, backend="reference"),
        rtol=1e-5, atol=1e-6)
    # interval straddling the append boundary
    np.testing.assert_allclose(
        p.signature(8, 15), signature(full[8:15], 3, backend="reference"),
        rtol=1e-4, atol=1e-5)


def test_update_scans_only_the_chunk():
    # d=5 / depth=2 unique -> both kernels trace inside the counters.
    # Capacity 64 holds a long path; the 3-point chunk buckets to 4.
    pts = _pts(8, 50, 5)
    chunk = _pts(9, 3, 5)
    with dispatch.count_scan_steps() as sc_build:
        p = Path.from_points(pts, depth=2)
    assert sc_build.total == p.capacity - 1, "build scans the buffer once"
    with dispatch.count_scan_steps() as sc, dispatch.count_combines():
        p2 = p.update(chunk)
    assert sc.total == 4, (
        f"update() scanned {sc.total} steps for a 3-point chunk "
        f"(bucket 4) on a 50-point path — full re-scan detected")
    full = jnp.concatenate([pts, chunk])
    np.testing.assert_allclose(
        p2.signature(), signature(full, 2, backend="reference"),
        rtol=1e-5, atol=1e-6)


def test_update_reuses_warm_trace_per_bucket():
    # d=6 / depth=2 unique -> fresh trace-count deltas for this geometry
    pts = _pts(10, 20, 6)
    p = Path.from_points(pts, depth=2)
    before = trace_counts()
    p = p.update(_pts(11, 1, 6))
    after_first = trace_counts()
    assert after_first["update"] - before["update"] == 1
    # same chunk bucket, same capacity -> zero new traces, many appends
    for seed in range(12, 18):
        p = p.update(_pts(seed, 1, 6))
    assert trace_counts()["update"] == after_first["update"], \
        "same-bucket appends retraced the update kernel"
    full = jnp.concatenate([_pts(10, 20, 6)]
                           + [_pts(s, 1, 6) for s in range(11, 18)])
    np.testing.assert_allclose(
        p.signature(), signature(full, 2, backend="reference"),
        rtol=1e-5, atol=1e-6)


def test_build_bucket_trace_reuse():
    # d=7 / depth=2 unique; lengths 9 and 15 share the 16-bucket
    before = trace_counts()
    p1 = Path.from_points(_pts(20, 9, 7), depth=2)
    mid = trace_counts()
    p2 = Path.from_points(_pts(21, 15, 7), depth=2)
    after = trace_counts()
    assert p1.capacity == p2.capacity == 16
    assert mid["build"] - before["build"] == 1
    assert after["build"] == mid["build"], \
        "same-bucket builds retraced the build kernel"


def test_update_grows_capacity():
    pts = _pts(22, 14, 2)
    p = Path.from_points(pts, depth=3)
    assert p.capacity == 16
    more = _pts(23, 9, 2)
    p2 = p.update(more)
    assert p2.capacity == 32 and len(p2) == 23
    full = jnp.concatenate([pts, more])
    np.testing.assert_allclose(
        p2.signature(), signature(full, 3, backend="reference"),
        rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        p2.signature(10, 20), signature(full[10:20], 3,
                                        backend="reference"),
        rtol=1e-4, atol=1e-5)


def test_update_lead_lag():
    tp = TransformPipeline(lead_lag=True)
    pts = _pts(24, 9, 2)
    more = _pts(25, 4, 2)
    p = Path.from_points(pts, depth=2, transforms=tp).update(more)
    full = jnp.concatenate([pts, more])
    np.testing.assert_allclose(
        p.signature(), signature(full, 2, transforms=tp,
                                 backend="reference"),
        rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# rolling windows
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,stride", [(2, 1), (5, 1), (4, 3), (13, 5)])
def test_rolling_vs_oracle(window, stride):
    pts = _pts(26, 17, 3)
    p = Path.from_points(pts, depth=3)
    out = p.rolling(window, stride=stride)
    cfg = RollingConfig(window=window, stride=stride)
    assert out.shape == (cfg.num_windows(17), p.sig_dim)
    for w in range(out.shape[0]):
        s0 = w * stride
        oracle = signature(pts[s0:s0 + window], 3, backend="reference")
        np.testing.assert_allclose(out[w], oracle, rtol=1e-4, atol=1e-5,
                                   err_msg=f"window {w}")


def test_rolling_config_and_validation():
    pts = _pts(27, 10, 2)
    p = Path.from_points(pts, depth=2)
    cfg = RollingConfig(window=4, stride=2)
    out = p.rolling(cfg)
    np.testing.assert_allclose(out, p.rolling(4, stride=2))
    with pytest.raises(ValueError, match="window"):
        RollingConfig(window=1)
    with pytest.raises(ValueError, match="stride"):
        RollingConfig(window=3, stride=0)
    with pytest.raises(ValueError, match="window fits"):
        p.rolling(11)


def test_rolling_is_combines_not_scans():
    # d=3 / depth=5 unique to this test
    pts = _pts(28, 33, 3)
    p = Path.from_points(pts, depth=5)
    with dispatch.count_scan_steps() as sc, dispatch.count_combines() as cc:
        out = p.rolling(8, stride=4)
    assert sc.total == 0, "rolling re-scanned the path"
    assert out.shape[0] == 7
    assert cc.total == 8, cc.total     # bucketed window count (7 -> 8)


# ---------------------------------------------------------------------------
# pytree / jit / grad
# ---------------------------------------------------------------------------

def test_path_is_a_pytree_through_jit():
    pts = _pts(29, 9, 2)
    p = Path.from_points(pts, depth=3)

    @jax.jit
    def query(path):
        return path.signature(2, 7)

    np.testing.assert_allclose(query(p), p.signature(2, 7),
                               rtol=1e-6, atol=1e-7)
    leaves, treedef = jax.tree_util.tree_flatten(p)
    p_back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert _bitwise(p_back.signature(), p.signature())


def test_gradients_flow_through_stored_prefixes():
    pts = _pts(30, 10, 2)

    def via_path(points):
        p = Path.from_points(points, depth=3)
        return jnp.sum(p.signature(2, 8) ** 2)

    def direct(points):
        return jnp.sum(signature(points[2:8], 3,
                                 backend="reference") ** 2)

    g_path = jax.grad(via_path)(pts)
    g_direct = jax.grad(direct)(pts)
    assert bool(jnp.all(jnp.isfinite(g_path)))
    np.testing.assert_allclose(g_path, g_direct, rtol=1e-3, atol=1e-4)
    # points outside [i, j) must not receive gradient from the query
    assert float(jnp.abs(g_path[9]).max()) == 0.0


def test_gradients_through_update():
    base = _pts(31, 8, 2)

    def loss(chunk):
        p = Path.from_points(base, depth=2).update(chunk)
        return jnp.sum(p.signature() ** 2)

    def loss_direct(chunk):
        full = jnp.concatenate([base, chunk])
        return jnp.sum(signature(full, 2, backend="reference") ** 2)

    chunk = _pts(32, 3, 2)
    g = jax.grad(loss)(chunk)
    g_ref = jax.grad(loss_direct)(chunk)
    np.testing.assert_allclose(g, g_ref, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# coalesced updates (the serving hot path)
# ---------------------------------------------------------------------------

def test_coalesced_update_matches_solo_updates():
    chunks = [_pts(40, 1, 3), _pts(41, 3, 3), _pts(42, 2, 3)]
    paths = [Path.from_points(_pts(43 + i, 9 + i, 3), depth=3)
             for i in range(3)]
    got = coalesced_update(paths, chunks)
    for p, c, out in zip(paths, chunks, got):
        solo = p.update(c)
        assert len(out) == len(solo)
        # same group arithmetic; the batched kernel pads the group and the
        # chunk bucket, both exact no-ops
        np.testing.assert_allclose(out.signature(), solo.signature(),
                                   rtol=1e-6, atol=1e-7)


def test_coalesced_update_is_one_kernel_invocation():
    # d=2 / depth=5 unique -> the batched update traces inside the counter
    paths = [Path.from_points(_pts(50 + i, 10, 2), depth=5)
             for i in range(3)]
    chunks = [_pts(60 + i, 1, 2) for i in range(3)]
    before = trace_counts()
    with dispatch.count_scan_steps() as sc:
        coalesced_update(paths, chunks)
    assert trace_counts()["update"] - before["update"] == 1
    # one batched scan over the shared chunk bucket — not one per stream
    assert sc.total == 1, sc.total
    # group padded to the power-of-two bucket (3 -> 4): same trace again
    # for any group size in the bucket
    before = trace_counts()
    coalesced_update(paths[:4 - 1], chunks[:4 - 1])
    assert trace_counts()["update"] == before["update"]


def test_coalesced_update_validates_groups():
    p16 = Path.from_points(_pts(70, 9, 2), depth=2)    # capacity 16
    p32 = Path.from_points(_pts(71, 20, 2), depth=2)   # capacity 32
    with pytest.raises(ValueError, match="homogeneous"):
        coalesced_update([p16, p32], [_pts(72, 1, 2), _pts(73, 1, 2)])
    with pytest.raises(ValueError, match="chunks"):
        coalesced_update([p16], [])


# ---------------------------------------------------------------------------
# validation & transform restrictions
# ---------------------------------------------------------------------------

def test_transform_restrictions():
    pts = _pts(80, 8, 2)
    with pytest.raises(ValueError, match="lead_lag only"):
        Path.from_points(pts, depth=2,
                         transforms=TransformPipeline(time_aug=True))
    with pytest.raises(ValueError, match="lead_lag only"):
        Path.from_points(pts, depth=2,
                         transforms=TransformPipeline(basepoint=True))
    Path.from_points(pts, depth=2,
                     transforms=TransformPipeline(lead_lag=True))


def test_interval_validation():
    p = Path.from_points(_pts(81, 8, 2), depth=2)
    for bad in [(-1, 5), (3, 4), (5, 5), (0, 9)]:
        with pytest.raises(ValueError, match="interval"):
            p.signature(*bad)
    with pytest.raises(ValueError, match="at least 2 points"):
        Path.from_points(_pts(82, 1, 2), depth=2)
    with pytest.raises(ValueError, match="at least one new point"):
        p.update(jnp.zeros((0, 2)))
    with pytest.raises(ValueError, match="new points"):
        p.update(jnp.zeros((3, 5)))


# ---------------------------------------------------------------------------
# eviction & retention (ISSUE 10): drop history by group-inverse splices —
# zero re-scans, O(retained) memory for endless streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pipeline", [TransformPipeline(),
                                      TransformPipeline(lead_lag=True)],
                         ids=["plain", "lead_lag"])
def test_evict_matches_fresh_build(pipeline):
    pts = _pts(90, 21, 3)
    p = Path.from_points(pts, depth=3, transforms=pipeline).evict(before=7)
    fresh = Path.from_points(pts[7:], depth=3, transforms=pipeline)
    assert len(p) == len(fresh) == 14
    assert p.capacity == fresh.capacity  # buffers shrank to the new bucket
    for i, j in [(0, None), (0, 5), (2, 9), (4, 14)]:
        np.testing.assert_allclose(p.signature(i, j), fresh.signature(i, j),
                                   rtol=2e-5, atol=2e-6)
    # the evicted path's full signature still matches the reference scan
    np.testing.assert_allclose(
        p.signature(),
        signature(pts[7:][None], 3, transforms=pipeline)[0],
        rtol=2e-5, atol=2e-6)


def test_evict_is_combines_not_scans():
    # distinctive (d, depth) so the evict kernel traces inside the counters
    pts = _pts(91, 19, 4)
    p = Path.from_points(pts, depth=3)
    with dispatch.count_scan_steps() as scans, \
            dispatch.count_combines() as combines:
        pe = p.evict(before=5)
    assert scans.total == 0          # not one increment re-folded
    # two batched Chen combines over the shrunken store (C=16 -> M=15)
    assert combines.total == 2 * (pe.capacity - 1)
    np.testing.assert_allclose(
        pe.signature(), signature(pts[5:][None], 3)[0],
        rtol=2e-5, atol=2e-6)


def test_evict_validation():
    p = Path.from_points(_pts(92, 10, 2), depth=2)
    assert p.evict(before=0) is p
    for bad in (-1, 1.5, True):
        with pytest.raises(ValueError, match="evict"):
            p.evict(before=bad)
    with pytest.raises(ValueError, match="at least one increment"):
        p.evict(before=9)
    p.evict(before=8)  # leaves exactly 2 points: fine


def test_retention_caps_memory_with_zero_rescans():
    cap = 16
    p = Path.from_points(_pts(93, 8, 3), depth=2, retention=cap)
    with dispatch.count_scan_steps() as scans:
        history = np.asarray(p.points[:len(p)])
        for step in range(12):
            chunk = _pts(94 + step, 4, 3)
            history = np.concatenate([history, np.asarray(chunk)])
            p = p.update(chunk)
            assert len(p) <= cap
            assert p.capacity <= 2 * cap  # O(retention) memory, forever
    # scans only ever folded chunk buckets, never the retained history
    assert scans.total <= 2 * 4  # <= traces (2 shapes) x chunk bucket
    np.testing.assert_allclose(
        p.signature(), signature(history[-len(p):][None], 2)[0],
        rtol=5e-5, atol=5e-6)


def test_retention_validation():
    pts = _pts(95, 10, 2)
    for bad in (1, 0, -3, 2.5, True):
        with pytest.raises(ValueError, match="retention"):
            Path.from_points(pts, depth=2, retention=bad)
    with pytest.raises(ValueError, match="retention"):
        Path.from_points(pts, depth=2, retention=8)  # 10 points > cap 8
    Path.from_points(pts, depth=2, retention=10)


def test_coalesced_update_honours_retention():
    ps = [Path.from_points(_pts(96 + i, 12, 2), depth=2, retention=14)
          for i in range(3)]
    chunks = [_pts(99 + i, 4, 2) for i in range(3)]
    got = coalesced_update(ps, chunks)
    for p, chunk, base in zip(got, chunks, range(3)):
        assert len(p) == 14
        full = np.concatenate([np.asarray(_pts(96 + base, 12, 2)),
                               np.asarray(chunk)])
        np.testing.assert_allclose(
            p.signature(), signature(full[-14:][None], 2)[0],
            rtol=5e-5, atol=5e-6)


def test_gradients_flow_through_evict():
    pts = _pts(97, 12, 2)

    def loss(x):
        return Path.from_points(x, depth=2).evict(before=4).signature().sum()

    g = jax.grad(loss)(pts)
    assert np.isfinite(np.asarray(g)).all()
    # evicted points cancel through the inverse splice (up to f32 round-off)
    np.testing.assert_allclose(np.asarray(g[:3]), 0.0, atol=1e-5)
    assert float(jnp.abs(g[5:]).max()) > 0
