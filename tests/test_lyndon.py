"""Lyndon-word machinery: enumeration, Witt's formula, basis changes."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.lyndon as ly
import repro.core.tensoralg as ta

jax.config.update("jax_platform_name", "cpu")


def brute_force_lyndon(d, n):
    """All length-n words strictly smaller than every proper rotation."""
    out = []
    for w in itertools.product(range(d), repeat=n):
        if all(w < w[i:] + w[:i] for i in range(1, n)):
            out.append(w)
    return out


@pytest.mark.parametrize("d,depth", [(2, 5), (3, 4), (5, 3)])
def test_enumeration_matches_brute_force(d, depth):
    words = ly.lyndon_words(d, depth)
    by_len = {}
    for w in words:
        by_len.setdefault(len(w), []).append(w)
    for n in range(1, depth + 1):
        expect = sorted(brute_force_lyndon(d, n))
        assert by_len.get(n, []) == expect          # lex-sorted within length


@pytest.mark.parametrize("d,depth", [(2, 6), (3, 5), (4, 4), (5, 5), (7, 3)])
def test_witt_formula_counts(d, depth):
    words = ly.lyndon_words(d, depth)
    counts = [sum(1 for w in words if len(w) == n) for n in range(1, depth + 1)]
    assert counts == ly.witt_dims(d, depth)
    assert len(words) == ly.logsig_dim(d, depth)


def test_known_witt_values():
    # necklace-polynomial classics
    assert ly.witt_dims(2, 5) == [2, 1, 2, 3, 6]
    assert ly.witt_dims(3, 4) == [3, 3, 8, 18]


def test_standard_bracketing():
    assert ly.bracket_string((0, 1)) == "[0, 1]"
    assert ly.bracket_string((0, 0, 1)) == "[0, [0, 1]]"
    assert ly.bracket_string((0, 1, 1)) == "[[0, 1], 1]"
    with pytest.raises(ValueError):
        ly.standard_bracketing((1, 0))              # not Lyndon


def test_expansion_is_unitriangular():
    """Bracket of word w expands to w + lex-greater words of the same length."""
    d, depth = 3, 4
    words = ly.lyndon_words(d, depth)
    E = ly.expand_matrix(d, depth)
    idx = ly.lyndon_flat_indices(d, depth)
    for i, w in enumerate(words):
        assert E[i, idx[i]] == 1.0
        for j in range(len(words)):
            if E[j, idx[i]] != 0.0:
                assert len(words[j]) == len(w) and words[i] >= words[j]


@pytest.mark.parametrize("mode", ["lyndon", "brackets"])
@pytest.mark.parametrize("d,depth", [(2, 5), (3, 4), (5, 3)])
def test_expand_compress_roundtrip(d, depth, mode):
    key = jax.random.PRNGKey(0)
    c = jax.random.normal(key, (4, ly.logsig_dim(d, depth)))
    back = ly.compress(ly.expand(c, d, depth, mode), d, depth, mode)
    np.testing.assert_allclose(back, c, rtol=1e-5, atol=1e-6)


def test_expanded_element_is_lie():
    """expand() lands in the free Lie algebra: log(exp(u)) == u there, and the
    shuffle-degeneracy witness level-2 symmetric part vanishes."""
    d, depth = 3, 3
    c = jax.random.normal(jax.random.PRNGKey(1), (ly.logsig_dim(d, depth),))
    u = ly.expand(c, d, depth, "brackets")
    lvl2 = ta.split_levels(u, d, depth)[1].reshape(d, d)
    np.testing.assert_allclose(lvl2 + lvl2.T, np.zeros((d, d)), atol=1e-5)


def test_bad_mode_raises():
    with pytest.raises(ValueError):
        ly.compress(jnp.zeros((ta.sig_dim(2, 2),)), 2, 2, "nope")
    with pytest.raises(ValueError):
        ly.expand(jnp.zeros((ly.logsig_dim(2, 2),)), 2, 2, "nope")
