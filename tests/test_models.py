"""Per-architecture smoke tests: reduced config, forward/train/decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_config, build_model
from repro.configs import ASSIGNED

# whole-module smoke runs dominate the default suite; CI's full job still runs them
pytestmark = pytest.mark.slow

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 12


def make_batch(cfg, key=2, seq=S):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, seq), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks, "max_len": 32}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, 8, 1024), jnp.float32) * 0.1
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_audio_frames, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ASSIGNED:
        cfg = get_config(name).reduced()
        m = build_model(cfg)
        out[name] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return out


@pytest.mark.parametrize("name", ASSIGNED)
def test_loss_finite(built, name):
    cfg, m, params = built[name]
    loss, metrics = m.loss(params, make_batch(cfg))
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_grads_finite(built, name):
    cfg, m, params = built[name]
    g = jax.grad(lambda p: m.loss(p, make_batch(cfg))[0])(params)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_consistency(built, name):
    cfg, m, params = built[name]
    batch = make_batch(cfg)
    toks = batch["tokens"]
    l_full, _ = m.prefill(params, batch)
    b2 = dict(batch)
    b2["tokens"] = toks[:, :S - 1]
    _, cache = m.prefill(params, b2)
    l_dec, _ = m.decode(params, cache, toks[:, S - 1:],
                        jnp.asarray(S - 1, jnp.int32))
    err = np.abs(np.asarray(l_full)[..., :cfg.vocab]
                 - np.asarray(l_dec)[..., :cfg.vocab]).max()
    assert err < 2e-4, err


@pytest.mark.parametrize("name", ["recurrentgemma-2b", "mamba2-780m"])
def test_long_recurrent_decode(built, name):
    """Decode far past the local-attention window / via SSM recurrence."""
    cfg, m, params = built[name]
    S2 = 20
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S2), 0, cfg.vocab)
    batch = make_batch(cfg)
    batch["tokens"] = toks
    batch["max_len"] = 64
    l_full, _ = m.prefill(params, batch)
    b2 = dict(batch)
    b2["tokens"] = toks[:, :3]
    _, cache = m.prefill(params, b2)
    l_dec = None
    for t in range(3, S2):
        l_dec, cache = m.decode(params, cache, toks[:, t:t + 1],
                                jnp.asarray(t, jnp.int32))
    err = np.abs(np.asarray(l_full)[..., :cfg.vocab]
                 - np.asarray(l_dec)[..., :cfg.vocab]).max()
    assert err < 2e-4, err


def test_train_step_decreases_loss():
    from repro.optim.adamw import AdamW, cosine_schedule
    from repro.train.step import make_train_step
    cfg = get_config("deepseek-7b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=cosine_schedule(1e-3, 2, 50))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(m, opt, num_microbatches=2))
    batch = make_batch(cfg)
    losses = []
    for i in range(15):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_sig_loss_attaches_and_trains():
    """The paper-technique hook: sig-kernel aux loss on hidden trajectories."""
    cfg = get_config("mamba2-780m").reduced().replace(sig_loss=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    batch["sig_target"] = 0.1 * jax.random.normal(
        jax.random.PRNGKey(7), (B, 32, cfg.sig_loss_dim))
    loss, metrics = m.loss(params, batch)
    assert "sig" in metrics and np.isfinite(float(metrics["sig"]))
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


def test_moe_aux_loss_present():
    cfg = get_config("dbrx-132b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    _, metrics = m.loss(params, make_batch(cfg))
    assert float(metrics["aux"]) > 0
