"""Fused-Δ Pallas kernels (beyond-paper §Perf it.3): Δ computed in VMEM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sigkernel_pde import ops, ref
from repro.core.signature import path_increments
from repro.core.sigkernel import sigkernel_gram

jax.config.update("jax_platform_name", "cpu")


def paths(seed, B, L, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * 0.2


@pytest.mark.parametrize("B,Lx,Ly,d,l1,l2", [
    (2, 9, 7, 3, 0, 0), (3, 20, 15, 4, 1, 1), (1, 33, 12, 2, 0, 2)])
def test_fused_forward(B, Lx, Ly, d, l1, l2):
    dx = path_increments(paths(0, B, Lx + 1, d))
    dy = path_increments(paths(1, B, Ly + 1, d))
    delta = jnp.einsum("bid,bjd->bij", dx, dy)
    k_f = ops.solve_fused(dx, dy, l1, l2)
    k_r = ref.solve(delta, l1, l2)
    np.testing.assert_allclose(k_f, k_r, rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("Bx,By,L,d", [(3, 4, 8, 3), (2, 5, 12, 2)])
def test_fused_gram(Bx, By, L, d):
    X, Y = paths(2, Bx, L, d), paths(3, By, L, d)
    K_f = ops.gram_fused(path_increments(X), path_increments(Y))
    K_r = sigkernel_gram(X, Y)
    np.testing.assert_allclose(K_f, K_r, rtol=5e-4, atol=1e-5)
