import functools
import os
import subprocess
import sys

import pytest

# NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
# smoke tests and benches must see exactly 1 device.  Multi-device tests
# spawn subprocesses that set their own XLA_FLAGS (see test_distribution.py
# and the `simulated_mesh` fixture below).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..")

_PROBE = (
    "import jax; ds = jax.devices(); "
    "assert len(ds) == 8, len(ds); print('MESH-OK')"
)


@functools.lru_cache(maxsize=1)
def _simulated_mesh_available() -> bool:
    """Can a subprocess on this host actually see 8 simulated CPU devices?

    Probes once per session by spawning the same way the tests do.  False
    on exotic jax builds where --xla_force_host_platform_device_count is
    ignored (e.g. a GPU-pinned backend) — the multidevice tier then skips
    gracefully instead of failing on an environment limitation.
    """
    from repro.launch.mesh import simulated_mesh_env
    try:
        r = subprocess.run(
            [sys.executable, "-c", _PROBE], capture_output=True, text=True,
            timeout=300, cwd=ROOT,
            env={**simulated_mesh_env(8), "PYTHONPATH": "src"})
    except (OSError, subprocess.TimeoutExpired):
        return False
    return "MESH-OK" in r.stdout


@pytest.fixture(scope="session")
def simulated_mesh():
    """Runner for programs on a simulated 8-device host mesh.

    XLA's host device count is fixed at backend init, so the program runs
    in a fresh subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (built by
    :func:`repro.launch.mesh.simulated_mesh_env`).  The returned callable
    takes python source, runs it, and asserts it prints ``OK``; the whole
    fixture skips when the host cannot simulate the mesh.
    """
    if not _simulated_mesh_available():
        pytest.skip("host cannot simulate an 8-device mesh "
                    "(--xla_force_host_platform_device_count ignored)")
    from repro.launch.mesh import simulated_mesh_env

    def run(prog: str, n_devices: int = 8, timeout: int = 900):
        r = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            timeout=timeout, cwd=ROOT,
            env={**simulated_mesh_env(n_devices), "PYTHONPATH": "src"})
        assert "OK" in r.stdout, r.stdout[-3000:] + r.stderr[-3000:]
        return r.stdout

    return run
