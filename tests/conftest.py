import os
import sys

# NOTE: XLA_FLAGS / device-count overrides are deliberately NOT set here —
# smoke tests and benches must see exactly 1 device.  Multi-device tests
# spawn subprocesses that set their own XLA_FLAGS (see test_distribution.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
