"""SigFeatureServer: admission batching, query modes, decode-step sampling.

The server contract: appends queue until ``flush()``, which coalesces all
pending appends into one batched kernel call per (capacity, chunk-bucket)
group — results identical to per-stream updates, kernel invocations far
fewer, jit traces bounded.  Queries and features must match the offline
entry points on the equivalent fully-materialised path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.config import TransformPipeline
from repro.core.features import FeatureConfig, rff_features
from repro.core.signature import signature
from repro.serve import SigFeatureServer
from repro.serve.step import make_decode_step
from repro.stream import trace_counts

jax.config.update("jax_platform_name", "cpu")


def _pts(seed, *shape, scale=0.3):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


def _server_with_streams(n=4, depth=3, d=2, **kw):
    srv = SigFeatureServer(depth, **kw)
    data = {}
    for s in range(n):
        pts = _pts(100 + s, 9 + s, d)
        data[f"s{s}"] = pts
        srv.open_stream(f"s{s}", pts)
    return srv, data


# ---------------------------------------------------------------------------
# admission batching
# ---------------------------------------------------------------------------

def test_flush_coalesces_and_matches_recompute():
    srv, data = _server_with_streams()
    ticks = {name: _pts(200 + i, 1, 2)
             for i, name in enumerate(data)}
    for name, t in ticks.items():
        srv.append(name, t)
    assert srv.flush() == len(data)
    st = srv.stats()
    # all four same-capacity streams coalesced into ONE batched update
    assert st["update_groups"] == 1 and st["coalesced_streams"] == 4
    assert st["solo_updates"] == 0
    for name in data:
        full = jnp.concatenate([data[name], ticks[name]])
        np.testing.assert_allclose(
            srv.signature(name), signature(full, 3, backend="reference"),
            rtol=1e-5, atol=1e-6, err_msg=name)


def test_steady_state_has_bounded_traces():
    srv, data = _server_with_streams(n=3, d=4, depth=2)
    # first flush pays the (capacity, chunk-bucket, group-bucket) traces
    for name in data:
        srv.append(name, _pts(300, 1, 4))
    srv.flush()
    before = trace_counts()
    for step in range(4):
        for name in data:
            srv.append(name, _pts(301 + step, 1, 4))
        srv.flush()
    assert trace_counts() == before, \
        "steady-state flushes retraced a kernel"


def test_multiple_appends_per_stream_concatenate():
    srv, data = _server_with_streams(n=1)
    a, b = _pts(400, 2, 2), _pts(401, 3, 2)
    srv.append("s0", a)
    srv.append("s0", b)
    srv.flush()
    full = jnp.concatenate([data["s0"], a, b])
    assert len(srv.path("s0")) == full.shape[0]
    np.testing.assert_allclose(
        srv.signature("s0"), signature(full, 3, backend="reference"),
        rtol=1e-5, atol=1e-6)


def test_growth_routes_solo_and_stays_correct():
    srv, data = _server_with_streams(n=2)
    big = _pts(500, 20, 2)               # overflows the 16-point capacity
    srv.append("s0", big)
    srv.append("s1", _pts(501, 1, 2))
    srv.flush()
    st = srv.stats()
    assert st["solo_updates"] == 1       # the growing stream went solo
    full = jnp.concatenate([data["s0"], big])
    np.testing.assert_allclose(
        srv.signature("s0"), signature(full, 3, backend="reference"),
        rtol=1e-5, atol=1e-6)


def test_single_tick_accepts_1d_points():
    srv, data = _server_with_streams(n=1)
    srv.append("s0", jnp.asarray([0.1, -0.2]))     # (d,) one tick
    srv.flush()
    assert len(srv.path("s0")) == data["s0"].shape[0] + 1


# ---------------------------------------------------------------------------
# queries & features
# ---------------------------------------------------------------------------

def test_query_modes_match_offline():
    tp = TransformPipeline(lead_lag=True)
    srv = SigFeatureServer(2, transforms=tp)
    pts = _pts(600, 12, 2)
    srv.open_stream("x", pts)
    np.testing.assert_allclose(
        srv.signature("x", 3, 9),
        signature(pts[3:9], 2, transforms=tp, backend="reference"),
        rtol=1e-4, atol=1e-5)
    from repro.core.logsignature import logsignature
    np.testing.assert_allclose(
        srv.logsignature("x", 0, 7),
        logsignature(pts[:7], 2, transforms=tp, backend="reference"),
        rtol=1e-6, atol=1e-7)
    roll = srv.rolling("x", 4, stride=2)
    assert roll.shape[0] == 5
    np.testing.assert_allclose(
        roll[2], signature(pts[4:8], 2, transforms=tp,
                           backend="reference"),
        rtol=1e-4, atol=1e-5)


def test_features_match_offline_rff():
    feats = FeatureConfig(method="rff", rank=8, depth=2)
    srv = SigFeatureServer(2, features=feats)
    pts = _pts(601, 10, 3)
    srv.open_stream("x", pts)
    got = srv.features("x", window=6)
    want = rff_features(pts[-6:][None], feats, srv.transforms,
                        srv.static_kernel)[0]
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    # whole-stream features by default
    got_full = srv.features("x")
    want_full = rff_features(pts[None], feats, srv.transforms,
                             srv.static_kernel)[0]
    np.testing.assert_allclose(got_full, want_full, rtol=1e-6, atol=1e-7)


def test_server_validation():
    with pytest.raises(ValueError, match="rff"):
        SigFeatureServer(2, features=FeatureConfig(method="nystroem",
                                                   rank=4))
    srv = SigFeatureServer(2)
    with pytest.raises(KeyError, match="unknown stream"):
        srv.signature("nope")
    pts = _pts(700, 8, 2)
    srv.open_stream("x", pts)
    with pytest.raises(ValueError, match="already open"):
        srv.open_stream("x", pts)
    with pytest.raises(ValueError, match="no FeatureConfig"):
        srv.features("x")
    srv.close_stream("x")
    with pytest.raises(KeyError, match="unknown stream"):
        srv.append("x", pts[:1])
    srv2 = SigFeatureServer(2,
                            features=FeatureConfig(method="rff", rank=4))
    srv2.open_stream("y", pts)
    with pytest.raises(ValueError, match="window"):
        srv2.features("y", window=100)


def test_warmup_bounds_first_tick_traces():
    srv = SigFeatureServer(2)
    srv.open_stream("a", _pts(800, 10, 2))
    srv.open_stream("b", _pts(801, 12, 2))
    srv.warmup(lengths=(16,), chunk_sizes=(1,), group_sizes=(2,))
    before = trace_counts()
    srv.append("a", _pts(802, 1, 2))
    srv.append("b", _pts(803, 1, 2))
    srv.flush()
    assert trace_counts()["update"] == before["update"], \
        "warmup missed the steady-state update trace"


# ---------------------------------------------------------------------------
# decode-step satellite: greedy flag honoured
# ---------------------------------------------------------------------------

class _StubCfg:
    compute_dtype = "float32"


class _StubModel:
    """Minimal model: decode() returns fixed per-vocab logits."""

    cfg = _StubCfg()

    def __init__(self, logits):
        self._logits = jnp.asarray(logits, jnp.float32)

    def decode(self, params, caches, tokens, cur_len):
        B = tokens.shape[0]
        out = jnp.broadcast_to(self._logits[None, None, :],
                               (B, 1, self._logits.shape[0]))
        return out, caches


def test_decode_step_greedy_argmaxes():
    model = _StubModel([0.0, 3.0, -1.0, 1.0])
    step = make_decode_step(model)                # greedy by default
    nxt, logits, caches = step({}, None, jnp.zeros((2, 1), jnp.int32), 0)
    assert nxt.shape == (2, 1) and nxt.dtype == jnp.int32
    assert np.all(np.asarray(nxt) == 1)


def test_decode_step_sampling_honours_greedy_flag():
    # peaked logits: sampling must agree with argmax almost surely
    model = _StubModel([0.0, 50.0, -1.0, 1.0])
    step = make_decode_step(model, greedy=False)
    nxt, _, _ = step({}, None, jnp.zeros((3, 1), jnp.int32), 0,
                     jax.random.PRNGKey(0))
    assert np.all(np.asarray(nxt) == 1)
    # uniform logits: different keys must produce different draws
    model = _StubModel([0.0, 0.0, 0.0, 0.0])
    step = make_decode_step(model, greedy=False)
    draws = {int(step({}, None, jnp.zeros((1, 1), jnp.int32), 0,
                      jax.random.PRNGKey(k))[0][0, 0])
             for k in range(12)}
    assert len(draws) > 1, "sampling ignored the PRNG key"


def test_decode_step_temperature_validation():
    model = _StubModel([0.0, 1.0])
    with pytest.raises(ValueError, match="temperature"):
        make_decode_step(model, greedy=False, temperature=0.0)
    # temperature is sampling-only; the greedy branch ignores it
    step = make_decode_step(model, greedy=True, temperature=0.0)
    nxt, _, _ = step({}, None, jnp.zeros((1, 1), jnp.int32), 0)
    assert int(nxt[0, 0]) == 1
