"""The README quickstart must be executable as written.

Extracts every ```python fenced block from README.md and runs them in order
in one shared namespace (later blocks may use names from earlier ones).
"""

import os
import re

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
README = os.path.join(ROOT, "README.md")

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _blocks():
    with open(README, encoding="utf-8") as f:
        return _FENCE.findall(f.read())


def test_readme_has_python_snippets():
    assert len(_blocks()) >= 3


def test_readme_snippets_execute():
    ns = {}
    for i, block in enumerate(_blocks()):
        try:
            exec(compile(block, f"README.md:block{i}", "exec"), ns)
        except Exception as e:      # pragma: no cover - failure path
            pytest.fail(f"README python block {i} failed: {e}\n---\n{block}")
