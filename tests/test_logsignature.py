"""Log-signatures vs the tensor_log(signature) oracle, gradients, modes.

Runs in float64 (module-scoped fixture) so the 1e-6 oracle tolerances are
meaningful; x64 is restored on module teardown.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.lyndon as ly
import repro.core.tensoralg as ta
from repro.core.config import TransformPipeline
from repro.core.logsignature import (logsignature, logsignature_combine,
                                     logsignature_dim,
                                     logsignature_from_increments)
from repro.core.signature import (path_increments, signature_direct,
                                  transformed_dim)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True, scope="module")
def _x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", old)


def paths(seed, B=2, L=6, d=3, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d),
                             dtype=jnp.float64) * scale


def oracle(p, d, depth, mode):
    """Independent reference: log of the Algorithm-1 signature, projected."""
    flat = ta.tensor_log(signature_direct(p, depth), d, depth)
    if mode == "expand":
        return flat
    return ly.compress(flat, d, depth, mode)


@pytest.mark.parametrize("mode", ["lyndon", "brackets", "expand"])
@pytest.mark.parametrize("d", [2, 3, 5])
@pytest.mark.parametrize("depth", [1, 2, 3, 4, 5])
def test_matches_tensor_log_oracle(d, depth, mode):
    p = paths(depth * 10 + d, B=2, L=6, d=d)
    got = logsignature(p, depth, mode=mode, backend="reference")
    np.testing.assert_allclose(got, oracle(p, d, depth, mode),
                               rtol=1e-6, atol=1e-6)
    assert got.shape[-1] == logsignature_dim(d, depth, mode)


@pytest.mark.parametrize("d,depth", [(2, 4), (3, 3)])
def test_output_width_is_witt_dimension(d, depth):
    p = paths(0, d=d)
    assert logsignature(p, depth, backend="reference").shape[-1] == \
        sum(ly.witt_dims(d, depth))


@pytest.mark.parametrize("time_aug,lead_lag", [(True, False), (False, True),
                                               (True, True)])
def test_transforms_on_the_fly(time_aug, lead_lag):
    import repro.core.transforms as tf
    p = paths(1, B=2, L=5, d=2)
    q = p
    if lead_lag:
        q = tf.lead_lag(q)
    if time_aug:
        q = tf.time_augment(q)
    d_eff = transformed_dim(2, time_aug, lead_lag)
    got = logsignature(p, 3, transforms=TransformPipeline(
        time_aug=time_aug, lead_lag=lead_lag),
                       backend="reference")
    np.testing.assert_allclose(got, oracle(q, d_eff, 3, "lyndon"),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["lyndon", "brackets", "expand"])
def test_grad_finite_differences(mode):
    p = np.asarray(paths(2, B=1, L=5, d=2))
    f = lambda q: logsignature(jnp.asarray(q), 4, mode=mode,
                               backend="reference").sum()
    g = jax.grad(f)(jnp.asarray(p))
    eps = 1e-6
    for idx in [(0, 0, 0), (0, 2, 1), (0, 4, 0)]:
        pp, pm = p.copy(), p.copy()
        pp[idx] += eps
        pm[idx] -= eps
        fd = (float(f(pp)) - float(f(pm))) / (2 * eps)
        assert abs(fd - float(g[idx])) < 1e-6 * max(1.0, abs(fd)), (idx, mode)


@pytest.mark.slow
def test_grad_matches_autodiff_through_oracle():
    p = paths(3, B=2, L=6, d=3)
    g1 = jax.grad(lambda q: logsignature(q, 4, backend="reference").sum())(p)
    g2 = jax.grad(lambda q: oracle(q, 3, 4, "lyndon").sum())(p)
    np.testing.assert_allclose(g1, g2, rtol=1e-8, atol=1e-10)


@pytest.mark.parametrize("mode", ["lyndon", "brackets", "expand"])
def test_combine_is_chen_compatible(mode):
    d, depth = 3, 4
    p = paths(4, B=2, L=8, d=d)
    a = logsignature(p[:, :5], depth, mode=mode, backend="reference")
    b = logsignature(p[:, 4:], depth, mode=mode, backend="reference")
    full = logsignature(p, depth, mode=mode, backend="reference")
    np.testing.assert_allclose(logsignature_combine(a, b, d, depth, mode),
                               full, rtol=1e-8, atol=1e-10)


def test_stream_mode():
    p = paths(5, B=2, L=6, d=3)
    stream = logsignature(p, 3, stream=True, backend="reference")
    assert stream.shape[-2] == 5
    np.testing.assert_allclose(stream[:, -1],
                               logsignature(p, 3, backend="reference"),
                               rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(stream[:, 0],
                               logsignature(p[:, :2], 3, backend="reference"),
                               rtol=1e-10, atol=1e-12)


def test_depth_one_is_total_increment():
    p = paths(6, B=2, L=7, d=4)
    np.testing.assert_allclose(logsignature(p, 1, backend="reference"),
                               p[:, -1] - p[:, 0], rtol=1e-12, atol=1e-12)


def test_from_increments_matches_path_api():
    p = paths(7, B=3, L=6, d=2)
    np.testing.assert_allclose(
        logsignature_from_increments(path_increments(p), 4),
        logsignature(p, 4, backend="reference"), rtol=1e-12, atol=1e-12)


def test_bad_mode_raises():
    p = paths(8)
    with pytest.raises(ValueError):
        logsignature(p, 3, mode="words")
    with pytest.raises(ValueError):
        logsignature_combine(p[..., 0], p[..., 0], 3, 3, mode="nope")
