"""Signature kernels: PDE solver, exact backward, dyadic refinement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

import repro.core.tensoralg as ta
from repro.core.config import GridConfig, TransformPipeline
from repro.core.signature import signature
from repro.core.sigkernel import (sigkernel, sigkernel_gram, delta_matrix,
                                  solve_goursat, solve_goursat_grad,
                                  solve_goursat_antidiag,
                                  solve_goursat_grad_pde_approx)

jax.config.update("jax_platform_name", "cpu")


def paths(seed, B=2, L=6, d=2, scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


def test_kernel_matches_truncated_inner_product():
    x, y = paths(1), paths(2, L=7)
    k_pde = sigkernel(x, y, grid=GridConfig(3, 3))
    k_tr = ta.sig_inner(signature(x, 10), signature(y, 10), 2, 10)
    np.testing.assert_allclose(k_pde, k_tr, rtol=2e-4)


def test_symmetry():
    x, y = paths(3), paths(4)
    np.testing.assert_allclose(sigkernel(x, y, grid=GridConfig(1, 2)),
                               sigkernel(y, x, grid=GridConfig(2, 1)), rtol=1e-5)


def test_constant_path_gives_one():
    x = jnp.zeros((1, 5, 2))
    y = paths(5, 1)
    np.testing.assert_allclose(sigkernel(x, y), jnp.ones((1,)), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 99), l1=st.integers(0, 2), l2=st.integers(0, 2))
def test_exact_backward_vs_autodiff(seed, l1, l2):
    x = paths(seed, 2, 5, 2)
    y = paths(seed + 100, 2, 6, 2)
    g1 = jax.grad(lambda q: sigkernel(q, y, grid=GridConfig(l1, l2)).sum())(x)
    g2 = jax.grad(
        lambda q: solve_goursat(delta_matrix(q, y), l1, l2).sum())(x)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_backward_wrt_second_argument():
    x, y = paths(6), paths(7)
    g1 = jax.grad(lambda q: sigkernel(x, q, grid=GridConfig(1, 1)).sum())(y)
    g2 = jax.grad(
        lambda q: solve_goursat(delta_matrix(x, q), 1, 1).sum())(y)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_gradient_finite_differences():
    x, y = paths(8, 1, 5, 2), paths(9, 1, 5, 2)
    f = lambda q: float(sigkernel(jnp.asarray(q), y, grid=GridConfig(1, 1))[0])
    g = jax.grad(lambda q: sigkernel(q, y, grid=GridConfig(1, 1)).sum())(x)
    x0 = np.asarray(x)
    eps = 1e-4
    for idx in [(0, 0, 0), (0, 2, 1), (0, 4, 0)]:
        xp, xm = x0.copy(), x0.copy()
        xp[idx] += eps
        xm[idx] -= eps
        fd = (f(xp) - f(xm)) / (2 * eps)
        assert abs(fd - float(g[idx])) < 2e-2 * max(1.0, abs(fd))


def test_antidiag_solver_matches_rowscan():
    delta = jax.random.normal(jax.random.PRNGKey(0), (3, 12, 9)) * 0.1
    for l1, l2 in [(0, 0), (1, 1), (0, 2)]:
        np.testing.assert_allclose(solve_goursat_antidiag(delta, l1, l2),
                                   solve_goursat(delta, l1, l2),
                                   rtol=1e-4, atol=1e-6)


def test_exact_backward_beats_pde_approximation():
    """pySigLib §3.4: the one-pass exact backward is exact at any resolution;
    the second-PDE adjoint of [30] carries O(h) discretisation error."""
    x, y = paths(10, 1, 6, 2, scale=0.4), paths(11, 1, 6, 2, scale=0.4)
    delta = delta_matrix(x, y)
    grid = solve_goursat(delta, 0, 0, return_grid=True)
    gbar = jnp.ones(delta.shape[:-2])
    d_exact = solve_goursat_grad(delta, grid, gbar, 0, 0)
    d_auto = jax.grad(lambda d: solve_goursat(d, 0, 0).sum())(delta)
    d_approx = solve_goursat_grad_pde_approx(delta, grid, gbar, 0, 0)
    err_exact = float(jnp.abs(d_exact - d_auto).max())
    err_approx = float(jnp.abs(d_approx - d_auto).max())
    assert err_exact < 1e-5
    assert err_approx > 10 * max(err_exact, 1e-8)


def test_gram_matrix():
    X, Y = paths(12, 3), paths(13, 4)
    K = sigkernel_gram(X, Y, grid=GridConfig(1, 1))
    assert K.shape == (3, 4)
    np.testing.assert_allclose(K[1, 2],
                               sigkernel(X[1], Y[2], grid=GridConfig(1, 1)),
                               rtol=1e-5)


def test_gram_psd():
    X = paths(14, 4, 6, 2)
    K = sigkernel_gram(X, X, grid=GridConfig(2, 2))
    np.testing.assert_allclose(K, K.T, rtol=1e-4, atol=1e-5)
    evals = np.linalg.eigvalsh(np.asarray(K, np.float64))
    assert evals.min() > -1e-4


def test_transforms_in_kernel():
    x, y = paths(15), paths(16)
    k1 = sigkernel(x, y, transforms=TransformPipeline(time_aug=True, lead_lag=True),
                   grid=GridConfig(1, 1))
    import repro.core.transforms as tf
    k2 = sigkernel(tf.time_augment(tf.lead_lag(x)),
                   tf.time_augment(tf.lead_lag(y)), grid=GridConfig(1, 1))
    np.testing.assert_allclose(k1, k2, rtol=1e-5)
