"""Launch parameters are schedule-only: bitwise-identical values AND
gradients under non-default :class:`repro.LaunchConfig` knobs vs. the
library defaults, on every backend that consumes them — including ragged
(``lengths=``) batches and the symmetric Gram fast path.

Shape discipline for the Pallas PDE strips: trailing zero-padding of a
partial strip is NOT ulp-stable (fl((left+up)−upleft) drifts on padded
rows), so the bitwise contract is stated — and tested — for strip heights
that divide the unrefined row count Lx.  The ``ops.py`` wrappers enforce
exactly that by padding to the strip, hence L = 129 (Lx = 128) with
strips 16/32/64 below.  Everything else (signature tiles, band chunking,
Gram row blocking, ragged end-aligned padding) is bitwise unconditionally.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import LaunchConfig
from repro.bench import autotune
from repro.core.gram import sigkernel_gram, sigkernel_gram_reduce
from repro.core.logsignature import logsignature
from repro.core.signature import signature
from repro.core.sigkernel import sigkernel

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True)
def _cold_autotune_cache(tmp_path, monkeypatch):
    # default-launch baselines must resolve to the library defaults, not to
    # whatever a developer machine's warm autotune cache last persisted
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "autotune.json"))
    autotune.invalidate_memo()
    yield
    autotune.invalidate_memo()


def _bits(a) -> bytes:
    return np.asarray(a).tobytes()


def _paths(seed: int, B: int, L: int, d: int, scale: float = 0.1):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, L, d)) * scale


# ---------------------------------------------------------------------------
# config object validation
# ---------------------------------------------------------------------------

def test_launch_config_validation():
    assert LaunchConfig().is_default
    assert not LaunchConfig(band_chunk=4).is_default
    with pytest.raises(ValueError, match="power of two"):
        LaunchConfig(pde_strip=24)
    with pytest.raises(ValueError, match="positive Python int"):
        LaunchConfig(gram_row_block=0)
    rt = LaunchConfig.from_dict(LaunchConfig(pde_strip=32,
                                             band_chunk=3).to_dict())
    assert rt == LaunchConfig(pde_strip=32, band_chunk=3)


def test_launch_config_is_static_and_hashable():
    cfgs = {LaunchConfig(), LaunchConfig(sig_bt=64)}
    assert len(cfgs) == 2
    leaves, _ = jax.tree_util.tree_flatten(LaunchConfig(pde_strip=32))
    assert leaves == []  # all-meta pytree: jit-stable, no tracers


# ---------------------------------------------------------------------------
# signature / logsignature: Pallas Horner BT/LB tiles
# ---------------------------------------------------------------------------

_SIG_LAUNCHES = [LaunchConfig(sig_bt=2), LaunchConfig(sig_lb=8),
                 LaunchConfig(sig_bt=2, sig_lb=8)]


@pytest.mark.parametrize("launch", _SIG_LAUNCHES)
def test_signature_tiles_bitwise(launch):
    p = _paths(0, 5, 33, 3, 0.2)  # B=5 > sig_bt, L-1=32 > sig_lb: real tiling
    want = signature(p, 4, backend="pallas")
    got = signature(p, 4, backend="pallas", launch=launch)
    assert _bits(got) == _bits(want)

    g_want = jax.grad(lambda q: signature(q, 4, backend="pallas").sum())(p)
    g_got = jax.grad(lambda q: signature(
        q, 4, backend="pallas", launch=launch).sum())(p)
    assert _bits(g_got) == _bits(g_want)


def test_signature_ragged_tiles_bitwise():
    p = _paths(1, 5, 33, 3, 0.2)
    lens = jnp.array([33, 9, 17, 33, 5])
    launch = LaunchConfig(sig_bt=2, sig_lb=8)
    want = signature(p, 3, backend="pallas", lengths=lens)
    got = signature(p, 3, backend="pallas", lengths=lens, launch=launch)
    assert _bits(got) == _bits(want)
    g_want = jax.grad(lambda q: signature(
        q, 3, backend="pallas", lengths=lens).sum())(p)
    g_got = jax.grad(lambda q: signature(
        q, 3, backend="pallas", lengths=lens, launch=launch).sum())(p)
    assert _bits(g_got) == _bits(g_want)


def test_logsignature_tiles_bitwise():
    p = _paths(2, 5, 33, 3, 0.2)
    launch = LaunchConfig(sig_bt=2, sig_lb=8)
    for mode in ("lyndon", "expand"):
        want = logsignature(p, 3, mode=mode, backend="pallas")
        got = logsignature(p, 3, mode=mode, backend="pallas", launch=launch)
        assert _bits(got) == _bits(want), mode
    g_want = jax.grad(lambda q: logsignature(
        q, 3, backend="pallas").sum())(p)
    g_got = jax.grad(lambda q: logsignature(
        q, 3, backend="pallas", launch=launch).sum())(p)
    assert _bits(g_got) == _bits(g_want)


def test_reference_backend_ignores_launch_bitwise():
    p = _paths(3, 4, 20, 3, 0.2)
    want = signature(p, 4, backend="reference")
    got = signature(p, 4, backend="reference",
                    launch=LaunchConfig(sig_bt=2, sig_lb=8, band_chunk=2))
    assert _bits(got) == _bits(want)


# ---------------------------------------------------------------------------
# sigkernel: Pallas PDE strip heights + antidiag band chunking
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strip", [16, 32, 64])
def test_sigkernel_pallas_strip_bitwise(strip):
    x = _paths(4, 2, 129, 3)  # Lx = 128: every tested strip divides it
    y = _paths(5, 2, 129, 3)
    want = sigkernel(x, y, backend="pallas")
    got = sigkernel(x, y, backend="pallas",
                    launch=LaunchConfig(pde_strip=strip))
    assert _bits(got) == _bits(want)


def test_sigkernel_pallas_strip_grad_bitwise():
    x = _paths(4, 2, 129, 3)
    y = _paths(5, 2, 129, 3)
    g_want = jax.grad(lambda a, b: sigkernel(
        a, b, backend="pallas").sum(), argnums=(0, 1))(x, y)
    g_got = jax.grad(lambda a, b: sigkernel(
        a, b, backend="pallas", launch=LaunchConfig(pde_strip=32)).sum(),
        argnums=(0, 1))(x, y)
    for gw, gg in zip(g_want, g_got):
        assert _bits(gg) == _bits(gw)


@pytest.mark.parametrize("chunk", [1, 2, 8])
def test_sigkernel_antidiag_band_chunk_bitwise(chunk):
    x = _paths(6, 5, 20, 3)
    y = _paths(7, 5, 20, 3)
    launch = LaunchConfig(band_chunk=chunk)
    want = sigkernel(x, y, backend="antidiag")
    got = sigkernel(x, y, backend="antidiag", launch=launch)
    assert _bits(got) == _bits(want)
    g_want = jax.grad(lambda a: sigkernel(a, y, backend="antidiag").sum())(x)
    g_got = jax.grad(lambda a: sigkernel(
        a, y, backend="antidiag", launch=launch).sum())(x)
    assert _bits(g_got) == _bits(g_want)


# ---------------------------------------------------------------------------
# Gram engine: row blocking, symmetric fast path, ragged batches
# ---------------------------------------------------------------------------

def test_gram_row_block_bitwise():
    X = _paths(8, 5, 16, 3)
    Y = _paths(9, 4, 16, 3)
    want = sigkernel_gram(X, Y, backend="antidiag", symmetric=False)
    for rb in (1, 2, 3):
        got = sigkernel_gram(X, Y, backend="antidiag", symmetric=False,
                             launch=LaunchConfig(gram_row_block=rb))
        assert _bits(got) == _bits(want), rb
    g_want = jax.grad(lambda a: sigkernel_gram(
        a, Y, backend="antidiag", symmetric=False).sum())(X)
    g_got = jax.grad(lambda a: sigkernel_gram(
        a, Y, backend="antidiag", symmetric=False,
        launch=LaunchConfig(gram_row_block=2, band_chunk=4)).sum())(X)
    assert _bits(g_got) == _bits(g_want)


def test_gram_symmetric_fast_path_launch_bitwise():
    X = _paths(10, 5, 16, 3)
    launch = LaunchConfig(gram_row_block=2, band_chunk=4)
    want = sigkernel_gram(X, backend="antidiag")
    got = sigkernel_gram(X, backend="antidiag", launch=launch)
    assert _bits(got) == _bits(want)
    # the symmetric backward scatter-adds pair cotangents, and row blocking
    # reorders that accumulation — a pre-existing ulp-level property of the
    # row_block= kwarg.  The launch knob's contract is therefore: bitwise
    # equal to the SAME explicit row_block, and allclose to the dense default.
    g_kwarg = jax.grad(lambda a: sigkernel_gram(
        a, backend="antidiag", row_block=2,
        launch=LaunchConfig(band_chunk=4)).sum())(X)
    g_launch = jax.grad(lambda a: sigkernel_gram(
        a, backend="antidiag", launch=launch).sum())(X)
    assert _bits(g_launch) == _bits(g_kwarg)
    g_dense = jax.grad(lambda a: sigkernel_gram(
        a, backend="antidiag").sum())(X)
    np.testing.assert_allclose(np.asarray(g_launch), np.asarray(g_dense),
                               rtol=1e-5, atol=1e-6)


def test_gram_ragged_launch_bitwise():
    from repro.core.config import TransformPipeline
    cfg = TransformPipeline(time_aug=True)
    X = _paths(11, 4, 16, 3)
    Y = _paths(12, 4, 16, 3)
    lx = jnp.array([16, 9, 12, 5])
    ly = jnp.array([7, 16, 10, 16])
    launch = LaunchConfig(gram_row_block=2, band_chunk=2)
    want = sigkernel_gram(X, Y, transforms=cfg, symmetric=False,
                          lengths=lx, lengths_y=ly)
    got = sigkernel_gram(X, Y, transforms=cfg, symmetric=False,
                         lengths=lx, lengths_y=ly, launch=launch)
    assert _bits(got) == _bits(want)
    g_want = jax.grad(lambda a: sigkernel_gram(
        a, Y, transforms=cfg, symmetric=False,
        lengths=lx, lengths_y=ly).sum())(X)
    g_got = jax.grad(lambda a: sigkernel_gram(
        a, Y, transforms=cfg, symmetric=False,
        lengths=lx, lengths_y=ly, launch=launch).sum())(X)
    assert _bits(g_got) == _bits(g_want)


def test_gram_pallas_ragged_strip_plumbing_bitwise():
    # ragged batches end-align (leading padding), which IS ulp-stable;
    # an explicit full-height strip must reproduce the default schedule
    x = _paths(13, 2, 65, 3)  # Lx = 64
    y = _paths(14, 2, 65, 3)
    lx = jnp.array([65, 40])
    ly = jnp.array([50, 65])
    want = sigkernel_gram(x, y, backend="pallas", symmetric=False,
                          lengths=lx, lengths_y=ly)
    got = sigkernel_gram(x, y, backend="pallas", symmetric=False,
                         lengths=lx, lengths_y=ly,
                         launch=LaunchConfig(pde_strip=128))
    assert _bits(got) == _bits(want)


def test_gram_reduce_launch_bitwise():
    X = _paths(15, 5, 16, 3)
    Y = _paths(16, 4, 16, 3)
    launch = LaunchConfig(band_chunk=4)
    want = sigkernel_gram_reduce(X, Y, row_block=2)
    got = sigkernel_gram_reduce(X, Y, row_block=2, launch=launch)
    assert _bits(got) == _bits(want)
    g_want = jax.grad(lambda a: sigkernel_gram_reduce(X, a, row_block=2))(Y)
    g_got = jax.grad(lambda a: sigkernel_gram_reduce(
        X, a, row_block=2, launch=launch))(Y)
    assert _bits(g_got) == _bits(g_want)


# ---------------------------------------------------------------------------
# guard rails: shape errors name the knob instead of bare-asserting
# ---------------------------------------------------------------------------

def test_strip_geometry_error_names_launch_knob():
    from repro.kernels.sigkernel_pde.kernel import check_strip
    with pytest.raises(ValueError, match="LaunchConfig.pde_strip"):
        check_strip(2, 2, 16)  # T=2 < 2**lam1
    with pytest.raises(ValueError, match="LaunchConfig.pde_strip"):
        check_strip(12, 2, 16)  # T not a pow2-multiple of 2**lam1
