"""Ragged-batch (variable-length) paths end-to-end.

The contract under test: with ``lengths=``, every entry point behaves as if
each path were truncated to its own true length — *bitwise* for the linear
lift, because padding turns into exactly-zero increments / Δ rows that the
Horner recursion and the Goursat boundary absorb without changing a single
float (docs/solver_guide.md § Ragged batches).  Padding *content* must be
irrelevant, so these tests poison it with NaN.
"""

import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core import transforms as tf
from repro.core.config import RBF, TransformPipeline
from repro.core.gram import sigkernel_gram
from repro.core.logsignature import logsignature
from repro.core.losses import mmd2, scoring_rule
from repro.core.signature import signature
from repro.core.sigkernel import sigkernel

B, L, D = 4, 11, 2
LENS = np.array([5, 11, 8, 3])
LENS_Y = np.array([7, 4, 13, 9])

PIPELINES = {
    "plain": TransformPipeline(),
    "time_aug": TransformPipeline(time_aug=True),
    "lead_lag": TransformPipeline(lead_lag=True),
    "all": TransformPipeline(time_aug=True, lead_lag=True, basepoint=True),
}


def _paths(seed, b, n, d, scale=0.2):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, d)) * scale


def _poison(x, lens):
    """Overwrite padding with NaN — ragged code must never read it."""
    out = np.asarray(x).copy()
    for i, n in enumerate(lens):
        out[i, n:] = np.nan
    return jnp.asarray(out)


X = _paths(0, B, L, D)
Y = _paths(1, B, L + 2, D)
XP = _poison(X, LENS)
YP = _poison(Y, LENS_Y)


# ---------------------------------------------------------------------------
# padded batch vs per-path truncated oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PIPELINES))
def test_signature_matches_truncated_oracle_bitwise(name):
    cfg = PIPELINES[name]
    sig = signature(XP, 3, transforms=cfg, lengths=jnp.asarray(LENS))
    for b, n in enumerate(LENS):
        oracle = signature(X[b:b + 1, :n], 3, transforms=cfg)
        np.testing.assert_array_equal(np.asarray(sig[b]),
                                      np.asarray(oracle[0]))


def test_logsignature_matches_truncated_oracle(name="all"):
    cfg = PIPELINES[name]
    ls = logsignature(XP, 3, transforms=cfg, lengths=jnp.asarray(LENS))
    for b, n in enumerate(LENS):
        oracle = logsignature(X[b:b + 1, :n], 3, transforms=cfg)
        np.testing.assert_allclose(np.asarray(ls[b]), np.asarray(oracle[0]),
                                   rtol=1e-6, atol=1e-7)


def test_signature_stream_repeats_final_value_past_true_end():
    cfg = PIPELINES["time_aug"]
    s = signature(XP, 3, transforms=cfg, lengths=jnp.asarray(LENS),
                  stream=True)
    final = signature(XP, 3, transforms=cfg, lengths=jnp.asarray(LENS))
    steps = s.shape[-2]
    for b, n in enumerate(LENS):
        # prefix entries at/past the true end all equal the final signature
        tail = np.asarray(s[b, cfg.transformed_steps(int(n)) - 1:])
        np.testing.assert_array_equal(
            tail, np.broadcast_to(np.asarray(final[b]), tail.shape))
    # the stream axis reflects the bucketed (padded) length
    assert steps == cfg.transformed_steps(tf.bucket_length(XP.shape[1]))


@pytest.mark.parametrize("backend", dispatch.backends_for("sigkernel"))
def test_sigkernel_matches_truncated_oracle_bitwise(backend):
    cfg = PIPELINES["all"]
    k = sigkernel(XP, YP, transforms=cfg, backend=backend,
                  lengths_x=jnp.asarray(LENS), lengths_y=jnp.asarray(LENS_Y))
    for b in range(B):
        oracle = sigkernel(X[b:b + 1, :LENS[b]], Y[b:b + 1, :LENS_Y[b]],
                           transforms=cfg, backend=backend)
        np.testing.assert_array_equal(np.asarray(k[b]), np.asarray(oracle[0]))


@pytest.mark.slow
@pytest.mark.parametrize("backend", [
    b for b in dispatch.backends_for("gram")
    if not dispatch.get(b).approximate])
def test_gram_matches_truncated_oracle(backend):
    cfg = PIPELINES["time_aug"]
    K = sigkernel_gram(XP, YP, backend=backend, transforms=cfg,
                       symmetric=False, lengths=jnp.asarray(LENS),
                       lengths_y=jnp.asarray(LENS_Y))
    for a in range(B):
        for b in range(B):
            oracle = sigkernel_gram(
                X[a:a + 1, :LENS[a]], Y[b:b + 1, :LENS_Y[b]],
                backend=backend, transforms=cfg, symmetric=False)
            np.testing.assert_allclose(
                float(K[a, b]), float(oracle[0, 0]), rtol=1e-6,
                err_msg=f"backend={backend} pair=({a},{b})")


@pytest.mark.slow
def test_gram_rbf_lift_matches_truncated_oracle():
    kernel = RBF(sigma=1.0)
    K = sigkernel_gram(XP, YP, static_kernel=kernel, symmetric=False,
                       backend="reference", lengths=jnp.asarray(LENS),
                       lengths_y=jnp.asarray(LENS_Y))
    for a in range(B):
        for b in range(B):
            oracle = sigkernel_gram(
                X[a:a + 1, :LENS[a]], Y[b:b + 1, :LENS_Y[b]],
                static_kernel=kernel, symmetric=False, backend="reference")
            np.testing.assert_allclose(float(K[a, b]), float(oracle[0, 0]),
                                       rtol=1e-5)


def test_symmetric_fast_path_ragged_matches_dense():
    cfg = PIPELINES["all"]
    lens = jnp.asarray(LENS)
    K_sym = sigkernel_gram(XP, transforms=cfg, lengths=lens)
    K_dense = sigkernel_gram(XP, XP, transforms=cfg, symmetric=False,
                             lengths=lens, lengths_y=lens)
    np.testing.assert_allclose(np.asarray(K_sym), np.asarray(K_dense),
                               rtol=1e-6, atol=1e-7)
    assert np.array_equal(np.asarray(K_sym), np.asarray(K_sym).T)


def test_gram_row_blocked_ragged_matches_unblocked():
    cfg = PIPELINES["time_aug"]
    kw = dict(transforms=cfg, symmetric=False, lengths=jnp.asarray(LENS),
              lengths_y=jnp.asarray(LENS_Y))
    np.testing.assert_array_equal(
        np.asarray(sigkernel_gram(XP, YP, row_block=3, **kw)),
        np.asarray(sigkernel_gram(XP, YP, **kw)))


# ---------------------------------------------------------------------------
# losses over ragged batches
# ---------------------------------------------------------------------------

def test_mmd2_two_differently_ragged_batches():
    cfg = PIPELINES["time_aug"]
    lens, lens_y = jnp.asarray(LENS), jnp.asarray(LENS_Y)
    got = mmd2(XP, YP, transforms=cfg, lengths=lens, lengths_y=lens_y)

    # oracle from per-pair truncated kernels
    def k(a, na, b, nb):
        return float(sigkernel(a[None, :na], b[None, :nb],
                               transforms=cfg)[0])

    kxx = np.array([[k(X[a], LENS[a], X[b], LENS[b]) for b in range(B)]
                    for a in range(B)])
    kyy = np.array([[k(Y[a], LENS_Y[a], Y[b], LENS_Y[b]) for b in range(B)]
                    for a in range(B)])
    kxy = np.array([[k(X[a], LENS[a], Y[b], LENS_Y[b]) for b in range(B)]
                    for a in range(B)])
    want = ((kxx.sum() - np.trace(kxx)) / (B * (B - 1))
            + (kyy.sum() - np.trace(kyy)) / (B * (B - 1))
            - 2.0 * kxy.mean())
    np.testing.assert_allclose(float(got), want, rtol=1e-5)


def test_mmd2_invariant_to_padded_length():
    """The same ragged data padded to different L gives the same loss."""
    cfg = PIPELINES["time_aug"]
    lens = jnp.asarray([3, 5, 4, 6])
    a = mmd2(X[:, :7], Y[:, :7], transforms=cfg, lengths=lens,
             lengths_y=lens)
    b = mmd2(jnp.pad(X[:, :7], ((0, 0), (0, 4), (0, 0))), Y[:, :7],
             transforms=cfg, lengths=lens, lengths_y=lens)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_scoring_rule_ragged():
    cfg = PIPELINES["time_aug"]
    got = scoring_rule(XP, Y[0, :6], transforms=cfg,
                       lengths=jnp.asarray(LENS), length_y=6)
    kxx = np.array([[float(sigkernel(X[a][None, :LENS[a]],
                                     X[b][None, :LENS[b]],
                                     transforms=cfg)[0])
                     for b in range(B)] for a in range(B)])
    kxy = np.array([float(sigkernel(X[a][None, :LENS[a]], Y[None, 0, :6],
                                    transforms=cfg)[0]) for a in range(B)])
    want = 0.5 * (kxx.sum() - np.trace(kxx)) / (B * (B - 1)) - kxy.mean()
    np.testing.assert_allclose(float(got), want, rtol=1e-5)


# ---------------------------------------------------------------------------
# gradients through lengths=
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_gram_grad_matches_truncated_oracle_autodiff():
    """∂K/∂X of the ragged Gram == accumulated truncated-batch autodiff,
    and padded positions get exactly-zero gradient."""
    cfg = PIPELINES["all"]
    lens, lens_y = jnp.asarray(LENS), jnp.asarray(LENS_Y)
    g = jax.grad(lambda x: sigkernel_gram(
        x, YP, transforms=cfg, symmetric=False,
        lengths=lens, lengths_y=lens_y).sum())(X)
    for a in range(B):
        def fa(xa, a=a):
            tot = 0.0
            for b in range(B):
                tot = tot + sigkernel_gram(
                    xa[None], Y[b:b + 1, :LENS_Y[b]], transforms=cfg,
                    symmetric=False, backend="reference").sum()
            return tot
        ga = jax.grad(fa)(X[a, :LENS[a]])
        np.testing.assert_allclose(np.asarray(g[a, :LENS[a]]),
                                   np.asarray(ga), rtol=1e-4, atol=1e-6)
        assert not np.any(np.asarray(g[a, LENS[a]:])), \
            f"padding of path {a} leaked gradient"


def test_gram_grad_matches_finite_differences_x64():
    """FD gradcheck through lengths= with time-aug + lead-lag + basepoint
    (f64 so the FD quotient is meaningful)."""
    from jax.experimental import enable_x64
    cfg = PIPELINES["all"]
    with enable_x64():
        x = jnp.asarray(np.asarray(X[:2, :6], np.float64))
        y = jnp.asarray(np.asarray(Y[:2, :7], np.float64))
        lens = jnp.asarray([4, 6])
        lens_y = jnp.asarray([7, 3])

        def f(q):
            return sigkernel_gram(q, y, transforms=cfg, symmetric=False,
                                  lengths=lens, lengths_y=lens_y).sum()

        g = jax.grad(f)(x)
        eps = 1e-6
        rng = np.random.default_rng(0)
        for _ in range(6):
            b = int(rng.integers(2))
            i = int(rng.integers(int(lens[b])))
            c = int(rng.integers(D))
            e = jnp.zeros_like(x).at[b, i, c].set(eps)
            fd = (f(x + e) - f(x - e)) / (2 * eps)
            np.testing.assert_allclose(float(g[b, i, c]), float(fd),
                                       rtol=1e-5, atol=1e-8)


def test_signature_grad_zero_on_padding():
    cfg = PIPELINES["all"]
    g = jax.grad(lambda x: signature(
        x, 3, transforms=cfg, lengths=jnp.asarray(LENS)).sum())(X)
    for b, n in enumerate(LENS):
        assert not np.any(np.asarray(g[b, n:]))


# ---------------------------------------------------------------------------
# bucketing / recompilation policy
# ---------------------------------------------------------------------------

def test_bucket_length_policy():
    assert tf.bucket_length(2) == 8       # floor at the minimum bucket
    assert tf.bucket_length(8) == 8
    assert tf.bucket_length(9) == 16
    assert tf.bucket_length(11) == 16
    assert tf.bucket_length(16) == 16
    assert tf.bucket_length(1000) == 1024


def test_ragged_batches_sharing_a_bucket_reuse_one_trace():
    """Two ragged batches whose padded lengths land in the same bucket go
    through ONE jit trace (the acceptance-criteria compile-count check)."""
    traces = []

    @jax.jit
    def f(x, lens):
        traces.append(1)
        return signature(x, 3, transforms=PIPELINES["time_aug"],
                         lengths=lens)

    x1, l1 = tf.pad_ragged(X[:, :11], jnp.asarray([5, 6, 7, 11]))
    x2, l2 = tf.pad_ragged(X[:, :9], jnp.asarray([4, 9, 3, 8]))
    assert x1.shape == x2.shape  # same bucket => same trace key
    r1, r2 = f(x1, l1), f(x2, l2)
    assert len(traces) == 1, "second ragged batch retraced despite bucket"
    # and the bucketed results still match the truncated oracles
    np.testing.assert_array_equal(
        np.asarray(r2[1]),
        np.asarray(signature(X[1:2, :9], 3,
                             transforms=PIPELINES["time_aug"])[0]))


def test_pad_ragged_canonicalises():
    p, lens = tf.pad_ragged(X, np.array([5, 11, 8, 3]))
    assert p.shape == (B, tf.bucket_length(L), D)
    assert lens.dtype == jnp.int32
    # edge padding: repeated last rows (content is irrelevant downstream)
    np.testing.assert_array_equal(np.asarray(p[:, L:]),
                                  np.broadcast_to(np.asarray(X[:, -1:]),
                                                  (B, p.shape[1] - L, D)))


# ---------------------------------------------------------------------------
# time-grid dtype hardening (satellite bugfix)
# ---------------------------------------------------------------------------

def test_time_grid_built_in_f32_for_bf16_at_long_length():
    """bf16 can't even represent integers past 256: a grid built natively in
    bf16 collapses to a handful of distinct steps by L=4096.  The fix builds
    in f32 and casts once — matching np.linspace(f32).astype(bf16)."""
    path = jnp.zeros((1, 4096, 1), jnp.bfloat16)
    out = tf.time_augment(path, 0.0, 1.0)
    assert out.dtype == jnp.bfloat16
    t = np.asarray(out[0, :, 1], np.float32)
    want = np.asarray(
        np.linspace(0.0, 1.0, 4096, dtype=np.float32).astype(jnp.bfloat16),
        np.float32)
    np.testing.assert_array_equal(t, want)
    assert t[-1] == 1.0 and (np.diff(t) >= 0).all()


def test_time_grid_integer_paths_promote_to_f32():
    path = jnp.arange(12, dtype=jnp.int32).reshape(1, 12, 1)
    out = tf.time_augment(path)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out[0, :, 1]),
                               np.linspace(0, 1, 12, dtype=np.float32))


def test_transform_increments_dt_in_f32_for_bf16():
    z = jnp.zeros((1, 4095, 1), jnp.bfloat16)
    out = tf.transform_increments(z, True, False)
    assert out.dtype == jnp.bfloat16
    dt = np.asarray(out[0, :, 1], np.float32)
    want = float(jnp.asarray(np.float32(1.0 / 4095)).astype(jnp.bfloat16))
    assert (dt == want).all()


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def test_lengths_validation():
    with pytest.raises(TypeError, match="integer-typed"):
        signature(X, 2, lengths=jnp.asarray([1.0, 2.0, 3.0, 4.0]))
    with pytest.raises(ValueError, match="shape"):
        signature(X, 2, lengths=jnp.asarray([5, 6]))
    with pytest.raises(ValueError, match=">= 2"):
        signature(X, 2, lengths=jnp.asarray([1, 5, 5, 5]))
    with pytest.raises(ValueError, match="<="):
        signature(X, 2, lengths=jnp.asarray([5, 5, 5, L + 1]))
    with pytest.raises(ValueError, match="lengths_y= requires Y"):
        sigkernel_gram(X, lengths_y=jnp.asarray(LENS))


def test_align_validation():
    with pytest.raises(ValueError, match="align"):
        tf.pipeline_increments(X, PIPELINES["plain"], jnp.asarray(LENS),
                               align="middle")


def test_ragged_entry_points_silent_on_warnings():
    """lengths= is new API — it must not trip any deprecation path."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        signature(XP, 2, lengths=jnp.asarray(LENS))
        sigkernel_gram(XP, lengths=jnp.asarray(LENS))
