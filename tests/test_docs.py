"""Docs stay truthful: links resolve and documented modules import."""

import importlib
import os
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(ROOT, "docs"))

import check_links  # noqa: E402


def test_no_broken_markdown_links():
    bad = check_links.broken_links()
    assert not bad, f"broken links: {bad}"


def test_docs_cover_required_pages():
    for page in ["docs/index.md", "docs/solver_guide.md",
                 "docs/api/core.signature.md", "docs/api/core.logsignature.md",
                 "docs/api/core.sigkernel.md", "docs/api/core.dispatch.md",
                 "docs/api/kernels.md"]:
        assert os.path.exists(os.path.join(ROOT, page)), page


@pytest.mark.parametrize("module", [
    "repro.core.signature", "repro.core.logsignature", "repro.core.lyndon",
    "repro.core.sigkernel", "repro.core.dispatch", "repro.core.gram",
    "repro.kernels.signature.ops", "repro.kernels.sigkernel_pde.ops",
])
def test_documented_modules_import(module):
    importlib.import_module(module)


def test_documented_symbols_exist():
    """Spot-check that API pages don't document vapourware."""
    # note: repro.core re-exports functions that shadow their submodules
    # (repro.core.logsignature is the function), so resolve via importlib.
    ls = importlib.import_module("repro.core.logsignature")
    ly = importlib.import_module("repro.core.lyndon")
    sk = importlib.import_module("repro.core.sigkernel")
    dp = importlib.import_module("repro.core.dispatch")
    gm = importlib.import_module("repro.core.gram")
    ops = importlib.import_module("repro.kernels.signature.ops")
    pde_ops = importlib.import_module("repro.kernels.sigkernel_pde.ops")
    for obj, names in [
        (dp, ["BackendSpec", "register", "get", "backends_for", "resolve",
              "canonicalize", "count_pair_solves", "on_tpu"]),
        (gm, ["sigkernel_gram"]),
        (pde_ops, ["solve_fused", "gram_fused"]),
        (ls, ["logsignature", "logsignature_combine", "logsignature_dim"]),
        (ly, ["lyndon_words", "witt_dims", "logsig_dim", "compress",
              "expand", "standard_bracketing", "bracket_string",
              "lyndon_flat_indices", "expand_matrix"]),
        (sk, ["sigkernel", "sigkernel_gram", "sigkernel_gram_blocked",
              "solve_goursat", "solve_goursat_antidiag",
              "solve_goursat_grad", "solve_goursat_grad_pde_approx",
              "delta_matrix"]),
        (ops, ["signature_from_increments", "logsignature_from_increments",
               "default_use_pallas", "choose_BT"]),
    ]:
        for name in names:
            assert hasattr(obj, name), (obj.__name__, name)
