"""Direct unit tests for the parallel/launch scaffolding.

``parallel/sharding.py`` and ``launch/mesh.py`` carry the Gram engine's
distribution layer (block-cyclic dealing, mesh factorisation, simulated-mesh
env plumbing) plus the model-parameter rule tables; these were the
least-covered modules in ``src/repro``.  Everything here is single-device —
mesh-construction paths that need real devices use fakes or the local
1-device mesh; true multi-device behaviour lives in
``tests/test_distributed_gram.py`` (the ``multidevice`` tier).
"""

import os
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import mesh as M
from repro.parallel import api as A
from repro.parallel import sharding as SH

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# launch/mesh.py
# ---------------------------------------------------------------------------

def test_gram_mesh_shape_factorisations():
    assert M.gram_mesh_shape(1) == (1, 1)
    assert M.gram_mesh_shape(2) == (2, 1)
    assert M.gram_mesh_shape(4) == (2, 2)
    assert M.gram_mesh_shape(8) == (4, 2)
    assert M.gram_mesh_shape(12) == (4, 3)
    assert M.gram_mesh_shape(7) == (7, 1)       # primes: all on data
    for n in (1, 2, 3, 4, 6, 8, 12, 16, 30):
        nd, nm = M.gram_mesh_shape(n)
        assert nd * nm == n and nd >= nm        # data gets the bigger factor


def test_gram_mesh_shape_rejects_nonpositive():
    with pytest.raises(ValueError, match=">= 1"):
        M.gram_mesh_shape(0)


def test_make_gram_mesh_local_device():
    mesh = M.make_gram_mesh(1)
    assert tuple(mesh.shape.keys()) == ("data", "model")
    assert mesh.shape["data"] == 1 and mesh.shape["model"] == 1


def test_make_gram_mesh_too_many_devices_points_at_flag():
    n = len(jax.devices()) + 7
    with pytest.raises(ValueError, match=M.HOST_DEVICE_FLAG):
        M.make_gram_mesh(n)


def test_host_device_flags_replaces_and_preserves():
    base = ("--xla_cpu_foo=1 "
            f"{M.HOST_DEVICE_FLAG}=2 --xla_bar=baz")
    out = M.host_device_flags(8, base)
    assert f"{M.HOST_DEVICE_FLAG}=8" in out
    assert f"{M.HOST_DEVICE_FLAG}=2" not in out
    assert "--xla_cpu_foo=1" in out and "--xla_bar=baz" in out
    assert out.count(M.HOST_DEVICE_FLAG) == 1


def test_simulated_mesh_env_is_a_copy():
    env = {"XLA_FLAGS": "--xla_keep=1", "PATH": "/bin"}
    out = M.simulated_mesh_env(4, env)
    assert f"{M.HOST_DEVICE_FLAG}=4" in out["XLA_FLAGS"]
    assert "--xla_keep=1" in out["XLA_FLAGS"]
    assert env["XLA_FLAGS"] == "--xla_keep=1"   # caller env untouched
    assert out["PATH"] == "/bin"
    # default: copies the process env without mutating it
    before = os.environ.get("XLA_FLAGS")
    M.simulated_mesh_env(8)
    assert os.environ.get("XLA_FLAGS") == before


# ---------------------------------------------------------------------------
# parallel/sharding.py — block-cyclic dealing and Gram specs
# ---------------------------------------------------------------------------

def test_block_cyclic_perm_round_trip():
    x = np.arange(24 * 3).reshape(24, 3)
    perm, inv = SH.block_cyclic_perm(24, n_shards=4, block=2)
    assert np.array_equal(x[perm][inv], x)


def test_block_cyclic_perm_deals_blocks_round_robin():
    perm, _ = SH.block_cyclic_perm(12, n_shards=2, block=2)
    dealt = np.arange(12)[perm]
    # contiguous halves of the permuted order are the two shards
    shard0, shard1 = dealt[:6], dealt[6:]
    # shard 0 gets blocks 0, 2, 4 -> rows 0,1, 4,5, 8,9 (cyclic deal)
    assert shard0.tolist() == [0, 1, 4, 5, 8, 9]
    assert shard1.tolist() == [2, 3, 6, 7, 10, 11]


def test_block_cyclic_perm_needs_divisibility():
    with pytest.raises(ValueError, match="divisible"):
        SH.block_cyclic_perm(10, n_shards=4, block=2)


def test_get_shard_map_returns_transform():
    sm = SH.get_shard_map()
    assert callable(sm)


def test_gram_specs_demote_to_replicated_when_indivisible():
    # fake 2x2 mesh: physical_spec only reads mesh.shape
    mesh = SimpleNamespace(shape={"data": 2, "model": 2})
    rows, cols, g = SH.gram_specs(mesh, 8, 6, row_axis="data",
                                  col_axis="model")
    assert rows == P("data") and cols == P("model")
    assert g == P("data", "model")
    # 7 rows do not divide the 2-wide data axis -> replicated, not an error
    rows7, _, g7 = SH.gram_specs(mesh, 7, 6)
    assert rows7 == P(None) and g7 == P(None, "model")


# ---------------------------------------------------------------------------
# parallel/sharding.py — logical rules and physical specs
# ---------------------------------------------------------------------------

def test_logical_spec_for_known_and_unknown_names():
    leaf2 = SimpleNamespace(ndim=2, shape=(64, 128))
    assert SH.logical_spec_for(("layer", "w_gate"), leaf2) == \
        ("fsdp", "model")
    # scan-stacked: one extra leading layer dim -> prepended None
    leaf3 = SimpleNamespace(ndim=3, shape=(4, 64, 128))
    assert SH.logical_spec_for(("stack", "w_gate"), leaf3) == \
        (None, "fsdp", "model")
    # unknown name or unexpected rank -> fully replicated
    assert SH.logical_spec_for(("x", "mystery"), leaf2) == (None, None)
    leaf4 = SimpleNamespace(ndim=4, shape=(2, 2, 2, 2))
    assert SH.logical_spec_for(("x", "w_gate"), leaf4) == \
        (None, None, None, None)


def test_physical_spec_divisibility_demotion():
    mesh = SimpleNamespace(shape={"data": 4, "model": 2})
    rules = {"fsdp": "data", "model": "model", None: None}
    # divisible on both dims
    assert SH.physical_spec(("fsdp", "model"), (8, 6), mesh, rules) == \
        P("data", "model")
    # 6 % 4 != 0 -> the fsdp dim is demoted to replicated
    assert SH.physical_spec(("fsdp", "model"), (6, 6), mesh, rules) == \
        P(None, "model")
    # multi-axis: trailing axes dropped until the dim divides
    rules2 = {"fsdp": ("data", "model"), None: None}
    assert SH.physical_spec(("fsdp",), (8,), mesh, rules2) == \
        P(("data", "model"))
    assert SH.physical_spec(("fsdp",), (4,), mesh, rules2) == P("data")


def test_physical_spec_each_mesh_axis_used_once():
    mesh = SimpleNamespace(shape={"data": 2, "model": 2})
    rules = {"batch": "data", "fsdp": "data", "model": "model", None: None}
    # both logical names map to "data": only the first dim gets it
    spec = SH.physical_spec(("batch", "fsdp"), (4, 4), mesh, rules)
    assert spec == P("data", None)


def test_api_resolve_dedup_and_rules_context():
    with A.logical_rules(A.DEFAULT_RULES):
        assert A.resolve("batch", None, None) == P("data", None, None)
        assert A.resolve("batch", "model") == P("data", "model")
        # mamba2-style rules map batch AND fsdp onto overlapping axes:
        # left-to-right dedup gives the first dim the axis
        ssm = dict(A.DEFAULT_RULES, batch=("data", "model"))
        with A.logical_rules(ssm):
            assert A.resolve("batch", "model") == P(("data", "model"), None)
    assert A.current_rules() is None


def test_api_shard_is_noop_without_rules():
    x = jnp.ones((4, 3))
    assert A.shard(x, "batch", None) is x


def test_param_shardings_on_local_mesh():
    """End-to-end rule-table resolution on the real 1-device mesh: every
    leaf gets a NamedSharding and placement succeeds."""
    mesh = M.make_host_mesh()
    params = {
        "emb": {"table": jnp.zeros((16, 8))},
        "blk": {"attn": {"wq": jnp.zeros((8, 4, 2))},
                "moe": {"w_gate": jnp.zeros((2, 8, 16))}},
    }
    shardings = SH.param_shardings(
        jax.eval_shape(lambda: params), None, mesh, False)
    for leaf, sh in zip(jax.tree.leaves(params),
                        jax.tree.leaves(shardings)):
        assert sh.mesh.shape == mesh.shape
        placed = jax.device_put(leaf, sh)
        assert placed.shape == leaf.shape
