"""Pallas sig-kernel PDE kernels vs the pure-jnp oracle (interpret mode).

Shape/dtype sweep per the kernel-validation contract: every (Lx, Ly, λ1, λ2,
dtype) cell asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.sigkernel_pde import ops, ref
from repro.kernels.sigkernel_pde.kernel import build_fwd
from repro.kernels.sigkernel_pde.grad_kernel import build_bwd

jax.config.update("jax_platform_name", "cpu")

FWD_CASES = [
    (2, 5, 7, 0, 0), (3, 16, 16, 0, 0), (2, 10, 33, 1, 1),
    (1, 130, 64, 0, 0), (2, 6, 9, 2, 1), (1, 33, 129, 0, 2),
    (4, 20, 20, 1, 0),
]


def delta(seed, B, Lx, Ly, dtype=jnp.float32):
    d = jax.random.normal(jax.random.PRNGKey(seed), (B, Lx, Ly)) * 0.1
    return d.astype(dtype)


@pytest.mark.parametrize("B,Lx,Ly,l1,l2", FWD_CASES)
def test_forward_vs_ref(B, Lx, Ly, l1, l2):
    d = delta(0, B, Lx, Ly)
    k_pal = ops.solve(d, l1, l2)
    k_ref = ref.solve(d, l1, l2)
    np.testing.assert_allclose(k_pal, k_ref, rtol=5e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_dtypes(dtype):
    d = delta(1, 2, 12, 15, dtype)
    k_pal = ops.solve(d, 1, 1)
    k_ref = ref.solve(d.astype(jnp.float32), 1, 1)
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(k_pal, np.float32), k_ref, rtol=tol)


@pytest.mark.parametrize("B,Lx,Ly,l1,l2", [
    (2, 5, 7, 0, 0), (3, 16, 16, 0, 0), (2, 10, 33, 1, 1),
    (1, 40, 50, 0, 0), (2, 6, 9, 2, 1), (1, 33, 20, 0, 2)])
@pytest.mark.slow
def test_backward_vs_ref(B, Lx, Ly, l1, l2):
    d = delta(2, B, Lx, Ly)
    gbar = jax.random.normal(jax.random.PRNGKey(3), (B,))
    _, cps = ops.solve_with_grid(d, l1, l2)
    dd_pal = ops.solve_grad(d, cps, gbar, l1, l2)
    dd_ref = ref.solve_grad(d, gbar, l1, l2)
    denom = max(float(jnp.abs(dd_ref).max()), 1e-6)
    assert float(jnp.abs(dd_pal - dd_ref).max()) / denom < 2e-5


@pytest.mark.parametrize("Lx,Ly,T,l1,l2", [
    (24, 10, 8, 0, 0), (16, 12, 8, 1, 0), (24, 40, 8, 1, 1), (32, 8, 8, 0, 2)])
@pytest.mark.slow
def test_multistrip_small_T(Lx, Ly, T, l1, l2):
    """Force small strips so the carried-boundary-row path is exercised."""
    B = 2
    d = delta(4, B, Lx, Ly)
    gbar = jax.random.normal(jax.random.PRNGKey(5), (B,))
    fwd = build_fwd(B, Lx, Ly, T=T, lam1=l1, lam2=l2, save_cps=True,
                    interpret=True)
    k, cps = fwd(d)
    np.testing.assert_allclose(k, ref.solve(d, l1, l2), rtol=5e-4)
    bwd = build_bwd(B, Lx, Ly, T=T, lam1=l1, lam2=l2, interpret=True)
    dd = bwd(d, d, cps, gbar)
    dd_ref = ref.solve_grad(d, gbar, l1, l2)
    denom = max(float(jnp.abs(dd_ref).max()), 1e-6)
    assert float(jnp.abs(dd - dd_ref).max()) / denom < 2e-5


@pytest.mark.slow
def test_end_to_end_custom_vjp():
    from repro.core.config import GridConfig
    from repro.core.sigkernel import sigkernel, delta_matrix, solve_goursat
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, 3)) * 0.2
    y = jax.random.normal(jax.random.PRNGKey(2), (2, 7, 3)) * 0.2
    k1 = sigkernel(x, y, grid=GridConfig(1, 1), backend="pallas")
    k2 = sigkernel(x, y, grid=GridConfig(1, 1))
    np.testing.assert_allclose(k1, k2, rtol=1e-5)
    g1 = jax.grad(lambda q: sigkernel(q, y, grid=GridConfig(1, 1),
                                      backend="pallas").sum())(x)
    g2 = jax.grad(
        lambda q: solve_goursat(delta_matrix(q, y), 1, 1).sum())(x)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_padding_invariance():
    """Zero Δ rows/cols must not change the solution (ops.py relies on it)."""
    d = delta(6, 1, 9, 11)
    dpad = jnp.pad(d, ((0, 0), (0, 5), (0, 3)))
    np.testing.assert_allclose(ref.solve(d, 0, 0), ref.solve(dpad, 0, 0),
                               rtol=1e-6)
