"""Autotune cache contract: cold-cache tunes and persists, warm-cache does
zero timed runs, corrupted/stale caches fail open to the static heuristics,
and ``REPRO_DISABLE_AUTOTUNE=1`` bypasses the cache entirely."""

import json

import jax
import pytest

from repro.bench import autotune, timer
from repro.core import dispatch

jax.config.update("jax_platform_name", "cpu")

#: tiny sig-kernel key shape (buckets to (8, 8, 2)) — tuning it measures
#: both CPU candidates in well under a second each
SHAPE = (6, 6, 2)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    autotune.invalidate_memo()
    yield path
    autotune.invalidate_memo()


def test_candidates_skip_tpu_only_backends_on_cpu():
    names = autotune.candidates("gram")
    assert "reference" in names
    assert all(not dispatch.get(n).needs_tpu for n in names)


def test_cold_cache_tunes_and_persists(cache):
    assert autotune.lookup("sigkernel", SHAPE) is None
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert winner in autotune.candidates("sigkernel")
    assert cache.exists()
    doc = json.loads(cache.read_text())
    assert doc["schema"] == autotune.SCHEMA
    entry = doc["entries"][autotune.cache_key("sigkernel", SHAPE)]
    assert entry["backend"] == winner
    assert set(entry["timings"]) == set(autotune.candidates("sigkernel"))
    assert autotune.lookup("sigkernel", SHAPE) == winner
    # auto-resolution consults the warm cache
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner


def test_shapes_share_power_of_two_buckets(cache):
    assert autotune.key_shape("sigkernel", (6, 6, 2)) == (8, 8, 2)
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert autotune.lookup("sigkernel", (7, 5, 2)) == winner  # same bucket
    assert autotune.lookup("sigkernel", (100, 100, 2)) is None
    assert autotune.lookup("sigkernel", (6, 6, 3)) is None  # d is exact
    assert autotune.lookup("gram", (2, 2, 6, 6, 2)) is None  # other op


def test_channels_and_depth_never_bucketed():
    # cost is exponential in depth / polynomial in d: only batch- and
    # length-like leading dims may share power-of-two buckets
    assert autotune.key_shape("signature", (30, 3, 5)) == (32, 3, 5)
    assert autotune.key_shape("logsignature", (100, 7, 6)) == (128, 7, 6)
    assert autotune.key_shape("gram", (4, 4, 12, 12, 3)) == (4, 4, 16, 16, 3)


def test_warm_cache_performs_zero_timed_runs(cache, monkeypatch):
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    timed = []
    monkeypatch.setattr(timer, "bench",
                        lambda *a, **k: timed.append(a) or 0.0)
    assert autotune.tune("sigkernel", SHAPE, repeats=1) == winner
    assert autotune.lookup("sigkernel", SHAPE) == winner
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner
    assert timed == []


def test_corrupted_cache_file_is_ignored_not_crashed_on(cache):
    cache.write_text("{ this is not json", encoding="utf-8")
    autotune.invalidate_memo()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"
    # tuning recovers by rewriting the file
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert autotune.lookup("sigkernel", SHAPE) == winner


def test_stale_schema_cache_is_ignored(cache):
    key = autotune.cache_key("sigkernel", SHAPE)
    cache.write_text(json.dumps({"schema": autotune.SCHEMA + 1,
                                 "entries": {key: {"backend": "antidiag"}}}),
                     encoding="utf-8")
    autotune.invalidate_memo()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"


def test_stale_backend_name_falls_back_to_heuristics(cache):
    key = autotune.cache_key("sigkernel", SHAPE)
    cache.write_text(json.dumps({
        "schema": autotune.SCHEMA,
        "entries": {key: {"backend": "renamed_away"}}}), encoding="utf-8")
    autotune.invalidate_memo()
    # lookup reports the raw entry; resolve validates it against the live
    # registry and quietly degrades to the static heuristic
    assert autotune.lookup("sigkernel", SHAPE) == "renamed_away"
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"


def test_disable_env_restores_static_heuristics(cache, monkeypatch):
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    monkeypatch.setenv(autotune.ENV_DISABLE, "1")
    assert not autotune.enabled()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=1 << 20) == "antidiag"
    monkeypatch.delenv(autotune.ENV_DISABLE)
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner


def test_auto_fused_winner_degrades_on_broadcast_batches(monkeypatch):
    """A tuned 'pallas_fused' sigkernel winner (the key carries no batch
    info) must fall back, not crash, when auto meets broadcastable batches
    the fused kernel cannot serve."""
    import numpy as np
    from repro.core.sigkernel import sigkernel
    monkeypatch.setattr(
        dispatch, "_autotuned",
        lambda op, shape, dtype, ragged=False: "pallas_fused"
        if shape is not None else None)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2)) * 0.1
    y = jax.random.normal(jax.random.PRNGKey(1), (5, 6, 2)) * 0.1
    k = sigkernel(x, y, backend="auto")  # must not raise
    np.testing.assert_allclose(k, sigkernel(x, y, backend="reference"),
                               rtol=5e-4, atol=1e-5)
    # an *explicit* fused request still fails loudly
    with pytest.raises(ValueError, match="matching batch"):
        sigkernel(x, y, backend="pallas_fused")


def test_cache_key_includes_op_platform_dtype():
    k = autotune.cache_key("sigkernel", SHAPE, "float32")
    assert k == "sigkernel|cpu|float32|8x8x2"
    assert autotune.cache_key("sigkernel", SHAPE, "float64") != k
    with pytest.raises(ValueError, match="unknown op"):
        autotune.cache_key("conv", SHAPE)


def test_ragged_cache_key_is_separate(cache):
    """A ragged (lengths=) workload must never share a cache entry with the
    dense workload of the same padded shape — the masked work differs."""
    dense = autotune.cache_key("sigkernel", SHAPE, "float32")
    ragged = autotune.cache_key("sigkernel", SHAPE, "float32", ragged=True)
    assert ragged == dense + "|ragged"
    winner = autotune.tune("sigkernel", SHAPE, repeats=1, ragged=True)
    assert winner in autotune.candidates("sigkernel")
    # the ragged measurement populated only the ragged key
    assert autotune.lookup("sigkernel", SHAPE, ragged=True) == winner
    assert autotune.lookup("sigkernel", SHAPE) is None
