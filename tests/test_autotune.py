"""Autotune cache contract: cold-cache tunes and persists, warm-cache does
zero timed runs, corrupted/stale caches fail open to the static heuristics,
and ``REPRO_DISABLE_AUTOTUNE=1`` bypasses the cache entirely."""

import json

import jax
import pytest

from repro.bench import autotune, timer
from repro.core import dispatch

jax.config.update("jax_platform_name", "cpu")

#: tiny sig-kernel key shape (buckets to (8, 8, 2)) — tuning it measures
#: both CPU candidates in well under a second each
SHAPE = (6, 6, 2)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    autotune.invalidate_memo()
    yield path
    autotune.invalidate_memo()


def test_candidates_skip_tpu_only_backends_on_cpu():
    names = autotune.candidates("gram")
    assert "reference" in names
    assert all(not dispatch.get(n).needs_tpu for n in names)


def test_cold_cache_tunes_and_persists(cache):
    assert autotune.lookup("sigkernel", SHAPE) is None
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert winner in autotune.candidates("sigkernel")
    assert cache.exists()
    doc = json.loads(cache.read_text())
    assert doc["schema"] == autotune.SCHEMA
    entry = doc["entries"][autotune.cache_key("sigkernel", SHAPE)]
    assert entry["backend"] == winner
    assert set(entry["timings"]) == set(autotune.candidates("sigkernel"))
    assert autotune.lookup("sigkernel", SHAPE) == winner
    # auto-resolution consults the warm cache
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner


def test_shapes_share_power_of_two_buckets(cache):
    assert autotune.key_shape("sigkernel", (6, 6, 2)) == (8, 8, 2)
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert autotune.lookup("sigkernel", (7, 5, 2)) == winner  # same bucket
    assert autotune.lookup("sigkernel", (100, 100, 2)) is None
    assert autotune.lookup("sigkernel", (6, 6, 3)) is None  # d is exact
    assert autotune.lookup("gram", (2, 2, 6, 6, 2)) is None  # other op


def test_channels_and_depth_never_bucketed():
    # cost is exponential in depth / polynomial in d: only batch- and
    # length-like leading dims may share power-of-two buckets
    assert autotune.key_shape("signature", (30, 3, 5)) == (32, 3, 5)
    assert autotune.key_shape("logsignature", (100, 7, 6)) == (128, 7, 6)
    assert autotune.key_shape("gram", (4, 4, 12, 12, 3)) == (4, 4, 16, 16, 3)


def test_warm_cache_performs_zero_timed_runs(cache, monkeypatch):
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    timed = []
    monkeypatch.setattr(timer, "bench",
                        lambda *a, **k: timed.append(a) or 0.0)
    assert autotune.tune("sigkernel", SHAPE, repeats=1) == winner
    assert autotune.lookup("sigkernel", SHAPE) == winner
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner
    assert timed == []


def test_corrupted_cache_file_is_ignored_not_crashed_on(cache):
    cache.write_text("{ this is not json", encoding="utf-8")
    autotune.invalidate_memo()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"
    # tuning recovers by rewriting the file
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    assert autotune.lookup("sigkernel", SHAPE) == winner


def test_stale_schema_cache_is_ignored(cache):
    key = autotune.cache_key("sigkernel", SHAPE)
    cache.write_text(json.dumps({"schema": autotune.SCHEMA + 1,
                                 "entries": {key: {"backend": "antidiag"}}}),
                     encoding="utf-8")
    autotune.invalidate_memo()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"


def test_stale_backend_name_falls_back_to_heuristics(cache):
    key = autotune.cache_key("sigkernel", SHAPE)
    cache.write_text(json.dumps({
        "schema": autotune.SCHEMA,
        "entries": {key: {"backend": "renamed_away"}}}), encoding="utf-8")
    autotune.invalidate_memo()
    # lookup reports the raw entry; resolve validates it against the live
    # registry and quietly degrades to the static heuristic
    assert autotune.lookup("sigkernel", SHAPE) == "renamed_away"
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"


def test_disable_env_restores_static_heuristics(cache, monkeypatch):
    winner = autotune.tune("sigkernel", SHAPE, repeats=1)
    monkeypatch.setenv(autotune.ENV_DISABLE, "1")
    assert not autotune.enabled()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=16) == "reference"
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE,
                            grid_cells=1 << 20) == "antidiag"
    monkeypatch.delenv(autotune.ENV_DISABLE)
    assert dispatch.resolve("auto", op="sigkernel", shape=SHAPE) == winner


def test_auto_fused_winner_degrades_on_broadcast_batches(monkeypatch):
    """A tuned 'pallas_fused' sigkernel winner (the key carries no batch
    info) must fall back, not crash, when auto meets broadcastable batches
    the fused kernel cannot serve."""
    import numpy as np
    from repro.core.sigkernel import sigkernel
    monkeypatch.setattr(
        dispatch, "_autotuned",
        lambda op, shape, dtype, ragged=False: "pallas_fused"
        if shape is not None else None)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2)) * 0.1
    y = jax.random.normal(jax.random.PRNGKey(1), (5, 6, 2)) * 0.1
    k = sigkernel(x, y, backend="auto")  # must not raise
    np.testing.assert_allclose(k, sigkernel(x, y, backend="reference"),
                               rtol=5e-4, atol=1e-5)
    # an *explicit* fused request still fails loudly
    with pytest.raises(ValueError, match="matching batch"):
        sigkernel(x, y, backend="pallas_fused")


def test_cache_key_includes_op_platform_dtype():
    k = autotune.cache_key("sigkernel", SHAPE, "float32")
    assert k == "sigkernel|cpu|float32|8x8x2"
    assert autotune.cache_key("sigkernel", SHAPE, "float64") != k
    with pytest.raises(ValueError, match="unknown op"):
        autotune.cache_key("conv", SHAPE)


def test_ragged_cache_key_is_separate(cache):
    """A ragged (lengths=) workload must never share a cache entry with the
    dense workload of the same padded shape — the masked work differs."""
    dense = autotune.cache_key("sigkernel", SHAPE, "float32")
    ragged = autotune.cache_key("sigkernel", SHAPE, "float32", ragged=True)
    assert ragged == dense + "|ragged"
    winner = autotune.tune("sigkernel", SHAPE, repeats=1, ragged=True)
    assert winner in autotune.candidates("sigkernel")
    # the ragged measurement populated only the ragged key
    assert autotune.lookup("sigkernel", SHAPE, ragged=True) == winner
    assert autotune.lookup("sigkernel", SHAPE) is None


# ---------------------------------------------------------------------------
# launch-parameter sweep (cache schema v2)
# ---------------------------------------------------------------------------

def test_schema1_cache_fails_open_to_cold(cache):
    """Pre-launch-sweep (schema 1) caches are ignored entirely — the entry
    layout changed, so re-tuning is the only safe recovery."""
    key = autotune.cache_key("sigkernel", SHAPE)
    cache.write_text(json.dumps({"schema": 1,
                                 "entries": {key: {"backend": "antidiag"}}}),
                     encoding="utf-8")
    autotune.invalidate_memo()
    assert autotune.lookup("sigkernel", SHAPE) is None
    assert autotune.lookup_launch("sigkernel", SHAPE) is None


def test_launch_candidates_bounded_and_default_first():
    from repro.core.config import LaunchConfig
    for op in ("signature", "logsignature", "sigkernel", "gram"):
        for backend in autotune.candidates(op):
            cands = autotune.launch_candidates(op, backend)
            assert cands[0] == LaunchConfig()  # defaults always compete
            assert len(cands) <= 8  # the sweep stays bounded
            assert len(set(cands)) == len(cands)


def test_tune_stores_launch_and_machine_stamp(cache):
    autotune.tune("sigkernel", SHAPE, repeats=1)
    entry = autotune.cache_entry("sigkernel", SHAPE)
    assert isinstance(entry["launch"], dict)
    assert entry["machine"] == timer.machine_key()
    assert isinstance(entry["launch_timings"], dict)
    # the winning launch round-trips through lookup_launch (None == the
    # defaults won, also a valid outcome of a real sweep)
    from repro.core.config import LaunchConfig
    got = autotune.lookup_launch("sigkernel", SHAPE)
    assert got is None or isinstance(got, LaunchConfig)


def _write_entry(cache, key, entry):
    cache.write_text(json.dumps({"schema": autotune.SCHEMA,
                                 "entries": {key: entry}}), encoding="utf-8")
    autotune.invalidate_memo()


def test_lookup_launch_machine_scoping(cache):
    from repro.core.config import LaunchConfig
    key = autotune.cache_key("sigkernel", SHAPE)
    base = {"backend": "antidiag", "timings": {"antidiag": 1e-3}}

    # same machine: the tuned launch applies
    _write_entry(cache, key, {**base, "launch": {"band_chunk": 8},
                              "machine": timer.machine_key()})
    assert autotune.lookup_launch("sigkernel", SHAPE) == \
        LaunchConfig(band_chunk=8)
    # ... and flows through dispatch.resolve_launch when none is explicit
    assert dispatch.resolve_launch(None, op="sigkernel", shape=SHAPE,
                                   dtype="float32") == \
        LaunchConfig(band_chunk=8)
    # an explicit launch= always beats the cache
    assert dispatch.resolve_launch(LaunchConfig(band_chunk=2),
                                   op="sigkernel", shape=SHAPE) == \
        LaunchConfig(band_chunk=2)

    # different machine: tile winners do not travel — fail open to defaults
    _write_entry(cache, key, {**base, "launch": {"band_chunk": 8},
                              "machine": "tpu|v5e|17179869184"})
    assert autotune.lookup_launch("sigkernel", SHAPE) is None
    assert dispatch.resolve_launch(None, op="sigkernel", shape=SHAPE) == \
        LaunchConfig()
    # the backend winner itself still applies (it is portable enough,
    # and compare.py normalises machine speed)
    assert autotune.lookup("sigkernel", SHAPE) == "antidiag"


def test_lookup_launch_rejects_invalid_payloads(cache):
    key = autotune.cache_key("sigkernel", SHAPE)
    base = {"backend": "antidiag", "machine": timer.machine_key()}
    # pre-sweep entry: no launch field at all
    _write_entry(cache, key, base)
    assert autotune.lookup_launch("sigkernel", SHAPE) is None
    # all-default / empty launch dict
    _write_entry(cache, key, {**base, "launch": {}})
    assert autotune.lookup_launch("sigkernel", SHAPE) is None
    # a knob that fails LaunchConfig validation (24 is not a power of two)
    _write_entry(cache, key, {**base, "launch": {"pde_strip": 24}})
    assert autotune.lookup_launch("sigkernel", SHAPE) is None
    # unknown keys are dropped by from_dict, leaving the defaults
    _write_entry(cache, key, {**base, "launch": {"warp_count": 4}})
    assert autotune.lookup_launch("sigkernel", SHAPE) is None


def test_lookup_launch_disabled_env(cache, monkeypatch):
    from repro.core.config import LaunchConfig
    key = autotune.cache_key("sigkernel", SHAPE)
    _write_entry(cache, key, {"backend": "antidiag",
                              "launch": {"band_chunk": 8},
                              "machine": timer.machine_key()})
    monkeypatch.setenv(autotune.ENV_DISABLE, "1")
    assert autotune.lookup_launch("sigkernel", SHAPE) is None
    assert dispatch.resolve_launch(None, op="sigkernel", shape=SHAPE) == \
        LaunchConfig()
