"""Sharded + streaming Gram engine: dense-oracle equivalence and the
multidevice proof tier.

In-process tests check the streaming reductions (``sigkernel_gram_reduce``
and the ``streaming=`` losses) against the dense-Gram oracle for values and
gradients — including a hypothesis sweep over backends, symmetric and
asymmetric cases, and ragged ``lengths=`` — plus the
``assert_streaming_reduction`` shape-guard semantics (fires on dense,
stays quiet on streaming, de-aliases shape coincidences).

The ``multidevice``-marked tests spawn subprocesses on a simulated 8-device
host mesh (the ``simulated_mesh`` fixture) and prove the sharded engine:
shard-count invariance (1 vs 4 vs 8 devices), equality with the
single-device engine, ragged inputs surviving sharding, the symmetric
pair-solve budget, and the streaming losses on the mesh.
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # everything except the random-shape property sweep runs without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property tests need hypothesis: pip install -r requirements-dev.txt")

import repro
from repro.core import dispatch, gram, losses
from repro.core.config import GridConfig, RBF

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=1e-4, atol=1e-6)


def _paths(key, b, L, d, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(key), (b, L, d)) * scale


# ---------------------------------------------------------------------------
# streaming reduce vs dense oracle (in-process, 1 device)
# ---------------------------------------------------------------------------

def test_reduce_matches_dense_sum_asymmetric():
    X, Y = _paths(0, 7, 9, 2), _paths(1, 5, 9, 2)
    K = repro.sigkernel_gram(X, Y)
    s = repro.sigkernel_gram_reduce(X, Y, row_block=3)
    np.testing.assert_allclose(float(s), float(np.asarray(K).sum()), **TOL)


def test_reduce_matches_dense_sum_symmetric():
    X = _paths(2, 7, 9, 2)
    K = np.asarray(repro.sigkernel_gram(X))
    s = repro.sigkernel_gram_reduce(X, row_block=2)
    np.testing.assert_allclose(float(s), K.sum(), **TOL)
    s_nd = repro.sigkernel_gram_reduce(X, row_block=2, include_diag=False)
    np.testing.assert_allclose(float(s_nd), K.sum() - np.trace(K), **TOL)


def test_reduce_include_diag_requires_symmetric():
    X, Y = _paths(0, 4, 8, 2), _paths(1, 3, 8, 2)
    with pytest.raises(ValueError, match="include_diag"):
        repro.sigkernel_gram_reduce(X, Y, include_diag=False)


def test_streaming_losses_match_dense_values_and_grads():
    X, Y = _paths(3, 6, 9, 2), _paths(4, 5, 9, 2)
    for unbiased in (True, False):
        dense = losses.mmd2(X, Y, unbiased=unbiased, streaming=False)
        stream = losses.mmd2(X, Y, unbiased=unbiased, row_block=2)
        np.testing.assert_allclose(float(stream), float(dense), atol=1e-5)
    gd = jax.grad(lambda q: losses.mmd2(q, Y))(X)
    gs = jax.grad(lambda q: losses.mmd2(q, Y, row_block=2))(X)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gd), **TOL)

    sd = losses.scoring_rule(X, Y[0])
    ss = losses.scoring_rule(X, Y[0], row_block=2)
    np.testing.assert_allclose(float(ss), float(sd), atol=1e-5)
    gd = jax.grad(lambda q: losses.scoring_rule(q, Y[0]))(X)
    gs = jax.grad(lambda q: losses.scoring_rule(q, Y[0], row_block=2))(X)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gd), **TOL)


def test_streaming_auto_enables_on_row_block():
    """streaming=None + row_block routes through the reduce path (same
    value, and the guard's per-shape cache gets populated); explicit
    streaming=False with row_block uses the blocked dense path."""
    X, Y = _paths(5, 6, 9, 2), _paths(6, 4, 9, 2)
    auto = losses.mmd2(X, Y, row_block=2)
    off = losses.mmd2(X, Y, row_block=2, streaming=False)
    on = losses.mmd2(X, Y, streaming=True)
    np.testing.assert_allclose(float(auto), float(off), atol=1e-5)
    np.testing.assert_allclose(float(on), float(off), atol=1e-5)


def test_streaming_ragged_matches_dense():
    X, Y = _paths(7, 7, 9, 2), _paths(8, 5, 11, 2)
    lx = jnp.asarray([4, 9, 6, 7, 8, 5, 9])
    ly = jnp.asarray([11, 3, 7, 5, 9])
    dense = losses.mmd2(X, Y, lengths=lx, lengths_y=ly, unbiased=False,
                        streaming=False)
    stream = losses.mmd2(X, Y, lengths=lx, lengths_y=ly, unbiased=False,
                         row_block=2)
    np.testing.assert_allclose(float(stream), float(dense), atol=1e-5)
    gd = jax.grad(lambda q: losses.mmd2(q, Y, lengths=lx, lengths_y=ly,
                                        unbiased=False, streaming=False))(X)
    gs = jax.grad(lambda q: losses.mmd2(q, Y, lengths=lx, lengths_y=ly,
                                        unbiased=False, row_block=2))(X)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(gd), **TOL)


def test_sig_aux_loss_streaming_passthrough():
    H, T = _paths(9, 4, 8, 6), _paths(10, 4, 8, 2)
    proj = jax.random.normal(jax.random.PRNGKey(11), (6, 2)) * 0.3
    dense = losses.sig_aux_loss(H, T, proj=proj)
    stream = losses.sig_aux_loss(H, T, proj=proj, row_block=2)
    np.testing.assert_allclose(float(stream), float(dense), atol=1e-5)


# ---------------------------------------------------------------------------
# property sweep: streaming == dense oracle across the config lattice
# ---------------------------------------------------------------------------

def _sweep_case(bx, by, L, rb, backend, rbf, symmetric, ragged):
    """Streaming reduce == dense Gram sum (value AND grad) for one config."""
    X = _paths(bx * 100 + L, bx, L, 2)
    kw = dict(backend=backend, grid=GridConfig(0, 0))
    if rbf:
        kw["static_kernel"] = RBF(sigma=1.2)
    if symmetric:
        args, lkw = (X,), {}
        if ragged:
            lkw["lengths"] = jnp.asarray(
                [2 + (i * 3) % (L - 1) for i in range(bx)])
        K = np.asarray(repro.sigkernel_gram(*args, **lkw, **kw))
        tot = K.sum()
    else:
        Y = _paths(by * 100 + L + 1, by, L, 2)
        args, lkw = (X, Y), {}
        if ragged:
            lkw["lengths"] = jnp.asarray(
                [2 + (i * 3) % (L - 1) for i in range(bx)])
            lkw["lengths_y"] = jnp.asarray(
                [2 + (i * 2) % (L - 1) for i in range(by)])
        K = np.asarray(repro.sigkernel_gram(*args, **lkw, **kw))
        tot = K.sum()

    def red(*a):
        return repro.sigkernel_gram_reduce(*a, row_block=rb, **lkw, **kw)

    np.testing.assert_allclose(float(red(*args)), tot, rtol=2e-4, atol=1e-5)
    # gradients: streaming VJP == dense VJP
    g_dense = jax.grad(
        lambda q: repro.sigkernel_gram(q, *args[1:], **lkw, **kw).sum())(X)
    g_stream = jax.grad(lambda q: red(q, *args[1:]))(X)
    np.testing.assert_allclose(np.asarray(g_stream), np.asarray(g_dense),
                               rtol=2e-4, atol=1e-5)


# fixed lattice corners so the contract is exercised even without hypothesis
@pytest.mark.parametrize("bx,by,L,rb,backend,rbf,symmetric,ragged", [
    (5, 4, 9, 2, "reference", False, False, False),
    (6, 3, 8, 1, "reference", False, True, False),
    (7, 5, 9, 2, "antidiag", False, False, True),
    (5, 4, 10, 3, "reference", True, True, True),
    (4, 6, 7, 1, "antidiag", True, False, False),
])
def test_streaming_sweep_fixed(bx, by, L, rb, backend, rbf, symmetric,
                               ragged):
    _sweep_case(bx, by, L, rb, backend, rbf, symmetric, ragged)


if HAVE_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=12, deadline=None)
    @given(
        bx=st.integers(3, 7),
        by=st.integers(2, 6),
        L=st.integers(6, 11),
        rb=st.integers(1, 3),
        backend=st.sampled_from(["reference", "antidiag"]),
        rbf=st.booleans(),
        symmetric=st.booleans(),
        ragged=st.booleans(),
    )
    def test_streaming_property_sweep(bx, by, L, rb, backend, rbf,
                                      symmetric, ragged):
        _sweep_case(bx, by, L, rb, backend, rbf, symmetric, ragged)


# ---------------------------------------------------------------------------
# the densify guard (satellite: regression for silent densification)
# ---------------------------------------------------------------------------

def test_guard_fires_on_dense_reduction():
    """A reduction that materialises the full Gram must be caught — value
    and VJP are both traced."""
    def dense(x, y):
        return repro.sigkernel_gram(x, y).sum()

    with pytest.raises(gram.StreamingViolation, match=r"\(7, 5\)"):
        gram.assert_streaming_reduction(
            jax.value_and_grad(dense),
            jax.ShapeDtypeStruct((7, 9, 2), jnp.float32),
            jax.ShapeDtypeStruct((5, 9, 2), jnp.float32),
            gram_shape=(7, 5))


def test_guard_fires_on_dense_delta_stack():
    """The (Bx, By, Lx, Ly) pairwise Δ stack is caught by the same prefix
    test even when the Gram itself is reduced away immediately."""
    def dense_sym(x):
        return repro.sigkernel_gram(x, x, symmetric=False).sum()

    with pytest.raises(gram.StreamingViolation):
        gram.assert_streaming_reduction(
            jax.value_and_grad(dense_sym),
            jax.ShapeDtypeStruct((6, 9, 2), jnp.float32),
            gram_shape=(6, 6))


def test_guard_quiet_on_streaming_reduction():
    def stream(x, y):
        return repro.sigkernel_gram_reduce(x, y, row_block=2)

    gram.assert_streaming_reduction(
        jax.value_and_grad(stream),
        jax.ShapeDtypeStruct((7, 9, 2), jnp.float32),
        jax.ShapeDtypeStruct((5, 9, 2), jnp.float32),
        gram_shape=(7, 5))


def test_guard_survives_shape_coincidences():
    """Regression: two false-positive classes the internal guard must
    de-alias — a ragged pad width equal to Bx (the L=9 → bucket-16 edge-pad
    VJP slices a (Bx, 7, d) cotangent when Bx == 7), and the rb=1 symmetric
    pair chunk tracking Bx exactly.  Both used to raise StreamingViolation
    on perfectly streaming reductions."""
    X = _paths(12, 7, 9, 2)
    lens = jnp.asarray([4, 9, 6, 7, 8, 5, 9])
    v = losses.mmd2(X, _paths(13, 5, 9, 2), lengths=lens, unbiased=False,
                    row_block=2)
    assert np.isfinite(float(v))
    s = losses.scoring_rule(X, _paths(13, 5, 9, 2)[0], row_block=1)
    assert np.isfinite(float(s))


def test_losses_guard_catches_injected_densify(monkeypatch):
    """End-to-end regression: if the reduce path ever silently densifies,
    mmd2(streaming=True) must raise instead of quietly materialising."""
    def densified(sX, sY, kernel, backend, rb, g, launch=None):
        K = gram._gram_rows(sX, sY, kernel, backend, g, None)
        return K.sum()

    monkeypatch.setattr(gram, "_reduce_rows", densified)
    gram._stream_checked.clear()
    X, Y = _paths(14, 8, 9, 2), _paths(15, 6, 9, 2)
    with pytest.raises(gram.StreamingViolation):
        losses.mmd2(X, Y, row_block=2, unbiased=False)
    gram._stream_checked.clear()


# ---------------------------------------------------------------------------
# multidevice tier: simulated 8-device host mesh (subprocesses)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_sharded_gram_shard_count_invariance(simulated_mesh):
    """1-vs-4-vs-8-device sub-meshes of one 8-device process produce the
    same Gram as the single-device engine — symmetric and asymmetric."""
    simulated_mesh(textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        import repro
        from repro.launch.mesh import make_gram_mesh
        assert len(jax.devices()) == 8, len(jax.devices())
        X = jax.random.normal(jax.random.PRNGKey(0), (13, 9, 2)) * 0.3
        Y = jax.random.normal(jax.random.PRNGKey(1), (11, 9, 2)) * 0.3
        K = np.asarray(repro.sigkernel_gram(X, Y))
        Ks = np.asarray(repro.sigkernel_gram(X))
        for n in (1, 4, 8):
            mesh = make_gram_mesh(n)
            Kn = np.asarray(repro.sigkernel_gram_sharded(X, Y, mesh=mesh))
            np.testing.assert_allclose(Kn, K, rtol=1e-5, atol=1e-6)
            Sn = np.asarray(repro.sigkernel_gram_sharded(X, mesh=mesh))
            np.testing.assert_allclose(Sn, Ks, rtol=1e-5, atol=1e-6)
        print("OK")
    """))


@pytest.mark.multidevice
def test_sharded_gram_ragged_and_row_block(simulated_mesh):
    """Ragged lengths= and per-device row_block sub-chunking survive
    sharding on the full 8-device mesh."""
    simulated_mesh(textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        import repro
        from repro.launch.mesh import make_gram_mesh
        X = jax.random.normal(jax.random.PRNGKey(0), (13, 9, 2)) * 0.3
        Y = jax.random.normal(jax.random.PRNGKey(1), (11, 9, 2)) * 0.3
        lens = jnp.asarray([4, 9, 6, 7, 8, 5, 9, 3, 9, 2, 8, 7, 5])
        mesh = make_gram_mesh(8)
        Kr = np.asarray(repro.sigkernel_gram(X, Y, lengths=lens))
        Krs = np.asarray(repro.sigkernel_gram_sharded(
            X, Y, lengths=lens, mesh=mesh))
        np.testing.assert_allclose(Krs, Kr, rtol=1e-5, atol=1e-6)
        K = np.asarray(repro.sigkernel_gram(X, Y))
        Kb = np.asarray(repro.sigkernel_gram_sharded(
            X, Y, mesh=mesh, row_block=2))
        np.testing.assert_allclose(Kb, K, rtol=1e-5, atol=1e-6)
        Ks = np.asarray(repro.sigkernel_gram(X))
        Sb = np.asarray(repro.sigkernel_gram_sharded(
            X, mesh=mesh, row_block=2))
        np.testing.assert_allclose(Sb, Ks, rtol=1e-5, atol=1e-6)
        print("OK")
    """))


@pytest.mark.multidevice
def test_sharded_symmetric_pair_budget(simulated_mesh):
    """The sharded symmetric fast path keeps the global PDE-solve budget at
    the triangle count plus round-robin padding — not the full Bx**2."""
    simulated_mesh(textwrap.dedent("""
        import jax, numpy as np
        import repro
        from repro.core import dispatch
        from repro.launch.mesh import make_gram_mesh
        X = jax.random.normal(jax.random.PRNGKey(0), (13, 9, 2)) * 0.3
        mesh = make_gram_mesh(8)
        n_pairs = 13 * 14 // 2
        budget = n_pairs + (-n_pairs) % 8
        with dispatch.count_pair_solves() as c:
            repro.sigkernel_gram_sharded(X, mesh=mesh)
        assert c.total == budget, (c.total, budget)
        assert c.total < 13 * 13, c.total
        print("OK")
    """))


@pytest.mark.multidevice
def test_streaming_losses_on_mesh(simulated_mesh):
    """Streaming mmd2/scoring_rule values and grads match the dense oracle
    inside an 8-device process (sharding and streaming compose)."""
    simulated_mesh(textwrap.dedent("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.core import losses
        X = jax.random.normal(jax.random.PRNGKey(0), (9, 9, 2)) * 0.3
        Y = jax.random.normal(jax.random.PRNGKey(1), (7, 9, 2)) * 0.3
        d = losses.mmd2(X, Y, streaming=False)
        s = losses.mmd2(X, Y, row_block=2)
        np.testing.assert_allclose(float(s), float(d), atol=1e-5)
        gd = jax.grad(lambda q: losses.mmd2(q, Y, streaming=False))(X)
        gs = jax.grad(lambda q: losses.mmd2(q, Y, row_block=2))(X)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gd),
                                   rtol=1e-4, atol=1e-6)
        print("OK")
    """))


@pytest.mark.multidevice
def test_flagship_example_runs_on_mesh(simulated_mesh):
    """examples/gram_matrix_distributed.py is the documented recipe — keep
    it green on the simulated mesh."""
    simulated_mesh(textwrap.dedent("""
        import runpy
        runpy.run_path("examples/gram_matrix_distributed.py",
                       run_name="__main__")
        print("OK")
    """), timeout=900)
