"""Approximate sig-kernel feature maps (repro.core.features) and their
first-class dispatch integration: accuracy against the exact engine (values
AND grads, linear + RBF lifts, ragged), the O(B·rank) streaming guarantee,
the capability-flag rejection contract, key-leaf reproducibility, and the
autotune accuracy-vs-speed frontier (budget lookup + cache-key separation
from exact winners)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.bench import autotune
from repro.core import dispatch, losses
from repro.core.config import RBF
from repro.core.gram import (StreamingViolation, sigkernel_gram,
                             sigkernel_gram_reduce)
from repro.core.features import FeatureConfig

jax.config.update("jax_platform_name", "cpu")


def _paths(seed, b, n, d, scale=0.3):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, n, d)) * scale


def _rel(a, b):
    return float(jnp.linalg.norm(jnp.asarray(a) - jnp.asarray(b))
                 / jnp.linalg.norm(jnp.asarray(b)))


B, L, D = 4, 9, 2
X = _paths(0, B, L, D)
Y = _paths(1, B + 1, L, D)


# ---------------------------------------------------------------------------
# config object
# ---------------------------------------------------------------------------

def test_feature_config_validation():
    with pytest.raises(ValueError, match="method"):
        FeatureConfig(method="svd")
    with pytest.raises(ValueError, match="rank"):
        FeatureConfig(rank=0)
    with pytest.raises(ValueError, match="depth"):
        FeatureConfig(depth=True)  # bools are not shape ints
    with pytest.raises(TypeError, match="FeatureConfig"):
        sigkernel_gram(X, Y, features={"method": "rff"})


def test_feature_config_is_pytree():
    f = FeatureConfig("rff", rank=8, key=jax.random.PRNGKey(3))
    leaves, treedef = jax.tree_util.tree_flatten(f)
    f2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert f2 == f
    # method/rank are static metadata: two methods get two treedefs
    g = FeatureConfig("nystroem", rank=8, key=jax.random.PRNGKey(3))
    assert jax.tree_util.tree_structure(g) != treedef


def test_feature_config_in_jit_and_sigkernel_class():
    f = FeatureConfig("rff", rank=64)
    sk = repro.SigKernel(features=f)
    K = jax.jit(sk.gram)(X)
    assert K.shape == (B, B)
    assert np.isfinite(np.asarray(K)).all()


# ---------------------------------------------------------------------------
# accuracy against the exact engine (the configured-budget contract)
# ---------------------------------------------------------------------------

def test_rff_gram_matches_exact_linear():
    Ke = sigkernel_gram(X, Y, symmetric=False)
    f = FeatureConfig("rff", rank=256)
    Ka = sigkernel_gram(X, Y, symmetric=False, features=f)
    assert _rel(Ka, Ke) < 0.1
    ge = jax.grad(lambda q: sigkernel_gram(q, Y, symmetric=False).sum())(X)
    ga = jax.grad(lambda q: sigkernel_gram(
        q, Y, symmetric=False, features=f).sum())(X)
    assert np.isfinite(np.asarray(ga)).all()
    # grads are exact autodiff of the estimator; vs the exact kernel they
    # carry the depth-truncation + Monte-Carlo error, hence the loose band
    assert _rel(ga, ge) < 0.6


def test_rff_gram_matches_exact_rbf_lift():
    kern = RBF(sigma=1.0)
    Ke = sigkernel_gram(X, Y, symmetric=False, static_kernel=kern)
    f = FeatureConfig("rff", rank=256, lift_dim=128)
    Ka = sigkernel_gram(X, Y, symmetric=False, static_kernel=kern,
                        features=f)
    assert _rel(Ka, Ke) < 0.15
    ga = jax.grad(lambda q: sigkernel_gram(
        q, Y, symmetric=False, static_kernel=kern, features=f).sum())(X)
    assert np.isfinite(np.asarray(ga)).all()


def test_rff_sigma_hyperparameter_is_differentiable():
    f = FeatureConfig("rff", rank=64)
    def loss(sigma):
        return sigkernel_gram(X, Y, symmetric=False,
                              static_kernel=RBF(sigma=sigma),
                              features=f).sum()
    g = jax.grad(loss)(1.0)
    assert np.isfinite(float(g)) and float(g) != 0.0


def test_nystroem_full_rank_reproduces_exact():
    Ke = sigkernel_gram(X, Y, symmetric=False)
    f = FeatureConfig("nystroem", rank=B + 1)  # pool covers the batch
    Ka = sigkernel_gram(X, Y, symmetric=False, features=f)
    assert _rel(Ka, Ke) < 1e-3
    ge = jax.grad(lambda q: sigkernel_gram(q, Y, symmetric=False).sum())(X)
    ga = jax.grad(lambda q: sigkernel_gram(
        q, Y, symmetric=False, features=f).sum())(X)
    assert _rel(ga, ge) < 1e-3


def test_ragged_lengths_through_features():
    lx = jnp.asarray([5, 9, 7, 6])
    ly = jnp.asarray([9, 4, 8, 6, 7])
    Ke = sigkernel_gram(X, Y, symmetric=False, lengths=lx, lengths_y=ly)
    f = FeatureConfig("rff", rank=256)
    Ka = sigkernel_gram(X, Y, symmetric=False, lengths=lx, lengths_y=ly,
                        features=f)
    assert _rel(Ka, Ke) < 0.15
    # padding content must be invisible: poison the padded tail
    Xp = X.at[:, -2:, :].set(jnp.nan)
    lx2 = jnp.asarray([5, 7, 7, 6])
    Ka1 = sigkernel_gram(X, Y, symmetric=False, lengths=lx2, lengths_y=ly,
                         features=f)
    Ka2 = sigkernel_gram(Xp, Y, symmetric=False, lengths=lx2, lengths_y=ly,
                         features=f)
    np.testing.assert_allclose(np.asarray(Ka1), np.asarray(Ka2))


# ---------------------------------------------------------------------------
# solve accounting + the O(B·rank) streaming guarantee
# ---------------------------------------------------------------------------

def test_rff_issues_zero_pde_solves():
    with dispatch.count_pair_solves() as c:
        sigkernel_gram(X, Y, symmetric=False,
                       features=FeatureConfig("rff", rank=32))
    assert c.total == 0


def test_nystroem_solve_budget_is_pool_plus_rows():
    f = FeatureConfig("nystroem", rank=2)  # pool = 4*rank = 8
    Xb = _paths(2, 12, 8, 2)
    Yb = _paths(3, 10, 8, 2)
    pool, rank = 8, 2
    with dispatch.count_pair_solves() as c:
        sigkernel_gram(Xb, Yb, symmetric=False, features=f)
    assert c.total == pool * pool + 12 * rank + 10 * rank


def test_reduce_matches_dense_feature_gram():
    f = FeatureConfig("rff", rank=64)
    K = np.asarray(sigkernel_gram(X, features=f))
    s = sigkernel_gram_reduce(X, features=f)
    np.testing.assert_allclose(float(s), K.sum(), rtol=1e-4)
    s_nd = sigkernel_gram_reduce(X, features=f, include_diag=False)
    np.testing.assert_allclose(float(s_nd), K.sum() - np.trace(K),
                               rtol=1e-4)
    Kxy = np.asarray(sigkernel_gram(X, Y, symmetric=False, features=f))
    sxy = sigkernel_gram_reduce(X, Y, features=f)
    np.testing.assert_allclose(float(sxy), Kxy.sum(), rtol=1e-4)


def test_streaming_guard_accepts_feature_path():
    # B > pool so even the nystroem pool Gram stays below (B, B)
    Xb = _paths(4, 12, 8, 2)
    for f in (FeatureConfig("rff", rank=16),
              FeatureConfig("nystroem", rank=2)):  # pool = 8 < 12
        sigkernel_gram_reduce(Xb, features=f, check_streaming=True)
        jax.grad(lambda q: sigkernel_gram_reduce(
            q, features=f, check_streaming=True))(Xb)


def test_mmd2_through_features_streams_by_default():
    # no row_block: an active approximation auto-enables streaming, and the
    # guard (value AND grad) proves no (B, B) Gram is materialised
    Xb, Yb = _paths(5, 12, 8, 2), _paths(6, 11, 8, 2)
    f = FeatureConfig("rff", rank=16)
    v = losses.mmd2(Xb, Yb, features=f)
    dense = losses.mmd2(Xb, Yb, features=f, streaming=False)
    np.testing.assert_allclose(float(v), float(dense), rtol=1e-4,
                               atol=1e-6)
    g = jax.grad(lambda q: losses.mmd2(q, Yb, features=f))(Xb)
    assert np.isfinite(np.asarray(g)).all()
    sr = losses.scoring_rule(Xb, Yb[0], features=f)
    assert np.isfinite(float(sr))


def test_sig_aux_loss_features_passthrough():
    H, T = _paths(7, 4, 8, 6), _paths(8, 4, 8, 2)
    proj = jax.random.normal(jax.random.PRNGKey(9), (6, 2)) * 0.3
    f = FeatureConfig("rff", rank=64)
    v = losses.sig_aux_loss(H, T, proj=proj, features=f)
    assert np.isfinite(float(v))


# ---------------------------------------------------------------------------
# capability-flag rejection (the dispatch contract)
# ---------------------------------------------------------------------------

def test_explicit_approx_backend_refused_without_opt_in():
    for name in ("rff", "nystroem"):
        with pytest.raises(ValueError, match="approximate=True"):
            dispatch.resolve(name, op="gram")
        with pytest.raises(ValueError, match="approximate=True"):
            sigkernel_gram(X, Y, backend=name, symmetric=False)
        with pytest.raises(ValueError, match="approximate=True"):
            losses.mmd2(X, Y, backend=name)
    # the error must name an exact escape hatch
    with pytest.raises(ValueError, match="reference"):
        dispatch.resolve("rff", op="gram")


def test_explicit_approx_backend_allowed_with_opt_in():
    assert dispatch.resolve("rff", op="gram",
                            allow_approximate=True) == "rff"
    K = sigkernel_gram(X, Y, backend="rff", symmetric=False,
                       features=FeatureConfig("rff", rank=32))
    assert K.shape == (B, B + 1)
    # an approximate backend name + error_budget also opts in (default
    # rank when the frontier cache is cold)
    K2 = sigkernel_gram(X, Y, backend="nystroem", symmetric=False,
                        error_budget=0.5)
    assert K2.shape == (B, B + 1)


def test_features_backend_conflict_raises():
    with pytest.raises(ValueError, match="conflicts"):
        sigkernel_gram(X, Y, symmetric=False, backend="antidiag",
                       features=FeatureConfig("rff"))
    with pytest.raises(ValueError, match="conflicts"):
        sigkernel_gram(X, Y, symmetric=False, backend="rff",
                       features=FeatureConfig("nystroem"))


def test_auto_never_picks_approx_without_budget():
    # cold cache or warm: plain auto must resolve exact
    name = dispatch.resolve("auto", op="gram", shape=(4, 4, 8, 8, 2),
                            dtype="float32")
    assert not dispatch.get(name).approximate


# ---------------------------------------------------------------------------
# key-leaf reproducibility
# ---------------------------------------------------------------------------

def test_feature_key_reproducibility():
    f0 = FeatureConfig("rff", rank=64)  # key=None -> PRNGKey(0)
    fk = FeatureConfig("rff", rank=64, key=jax.random.PRNGKey(0))
    f7 = FeatureConfig("rff", rank=64, key=jax.random.PRNGKey(7))
    K0 = sigkernel_gram(X, Y, symmetric=False, features=f0)
    Kk = sigkernel_gram(X, Y, symmetric=False, features=fk)
    K7 = sigkernel_gram(X, Y, symmetric=False, features=f7)
    np.testing.assert_array_equal(np.asarray(K0), np.asarray(Kk))
    assert _rel(K7, K0) > 1e-4  # different key, different estimator
    # and the same key twice is bitwise-stable
    np.testing.assert_array_equal(
        np.asarray(sigkernel_gram(X, Y, symmetric=False, features=f7)),
        np.asarray(K7))


# ---------------------------------------------------------------------------
# autotune frontier: cache-key separation + budget lookup round-trip
# ---------------------------------------------------------------------------

SHAPE = (4, 4, 8, 8, 2)


def _write_cache(path, entries):
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"schema": autotune.SCHEMA, "entries": entries}, fh)
    autotune.invalidate_memo()


def test_cache_key_separates_approx_from_exact():
    exact = autotune.cache_key("gram", SHAPE)
    approx = autotune.cache_key("gram", SHAPE, approx=True)
    ragged_approx = autotune.cache_key("gram", SHAPE, ragged=True,
                                       approx=True)
    assert approx == exact + "|approx"
    assert ragged_approx == exact + "|ragged|approx"
    assert len({exact, approx, ragged_approx}) == 3


def test_budget_lookup_round_trip(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    # no machine stamp: hand-written caches are accepted (cf. lookup_launch)
    _write_cache(str(cache), {
        autotune.cache_key("gram", SHAPE, approx=True): {
            "exact_seconds": 1.0,
            "frontier": [
                {"backend": "rff", "rank": 8, "rel_err": 0.30,
                 "seconds": 0.01},
                {"backend": "rff", "rank": 64, "rel_err": 0.05,
                 "seconds": 0.05},
                {"backend": "nystroem", "rank": 16, "rel_err": 0.02,
                 "seconds": 0.20},
                {"backend": "nystroem", "rank": 99, "rel_err": 0.001,
                 "seconds": 5.0},  # accurate but slower than exact: never
            ],
        },
    })
    # cheapest point fitting each budget wins
    assert autotune.lookup_budget("gram", SHAPE, "float32", 0.5) == \
        ("rff", 8)
    assert autotune.lookup_budget("gram", SHAPE, "float32", 0.1) == \
        ("rff", 64)
    assert autotune.lookup_budget("gram", SHAPE, "float32", 0.03) == \
        ("nystroem", 16)
    # tighter than every qualifying point -> None (exact engine)
    assert autotune.lookup_budget("gram", SHAPE, "float32", 0.0005) is None
    assert autotune.lookup_budget("gram", SHAPE, "float32", None) is None
    # dispatch.resolve_approx validates against the live registry
    assert dispatch.resolve_approx("gram", SHAPE, "float32",
                                   error_budget=0.5) == ("rff", 8)


def test_budget_lookup_drops_foreign_machine_stamp(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    _write_cache(str(cache), {
        autotune.cache_key("gram", SHAPE, approx=True): {
            "exact_seconds": 1.0,
            "machine": "someone-elses-box",
            "frontier": [{"backend": "rff", "rank": 8, "rel_err": 0.01,
                          "seconds": 0.01}],
        },
    })
    assert autotune.lookup_budget("gram", SHAPE, "float32", 0.5) is None


def test_budgeted_auto_uses_frontier_and_skips_pde(tmp_path, monkeypatch):
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    key_shape = (B, B + 1, L - 1, L - 1, D)  # what the engine will compute
    _write_cache(str(cache), {
        autotune.cache_key("gram", key_shape, approx=True): {
            "exact_seconds": 1.0,
            "frontier": [{"backend": "rff", "rank": 16, "rel_err": 0.05,
                          "seconds": 0.01}],
        },
    })
    with dispatch.count_pair_solves() as c:
        K = sigkernel_gram(X, Y, error_budget=0.1)  # backend="auto"
    assert c.total == 0  # the rff frontier point won: no PDE solves
    assert K.shape == (B, B + 1)
    # a budget tighter than the frontier falls back to the exact engine
    with dispatch.count_pair_solves() as c2:
        sigkernel_gram(X, Y, error_budget=1e-6)
    assert c2.total == (B) * (B + 1)


def test_exact_winner_slot_never_returns_approx(tmp_path, monkeypatch):
    # a (corrupt/stale) EXACT cache entry naming an approximate backend must
    # degrade to the heuristics, not leak an approximation into exact auto
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(cache))
    _write_cache(str(cache), {
        autotune.cache_key("gram", SHAPE): {"backend": "rff"},
    })
    name = dispatch.resolve("auto", op="gram", shape=SHAPE,
                            dtype="float32")
    assert not dispatch.get(name).approximate


def test_tune_frontier_rejects_non_gram_ops():
    with pytest.raises(ValueError, match="gram"):
        autotune.tune_frontier("sigkernel", (8, 8, 2))


def test_guard_rejects_dense_feature_free_path():
    # sanity: the guard infrastructure still fires on a genuinely dense
    # reduction, so the feature-path acceptances above mean something
    Xb = _paths(10, 6, 7, 2)
    with pytest.raises(StreamingViolation):
        from repro.core import gram as gram_mod
        gram_mod.assert_streaming_reduction(
            lambda q: sigkernel_gram(q).sum(), Xb, gram_shape=(6, 6))
