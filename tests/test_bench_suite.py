"""Bench suite + compare gate: the smoke suite produces a well-formed,
schema-versioned document; self-comparison is green; slowdowns, accuracy
losses, and missing entries are flagged with a nonzero exit."""

import copy
import json
import os

import jax
import pytest

from repro.bench import autotune, compare, suite

# whole-module smoke runs dominate the default suite; CI's full job still runs them
pytestmark = pytest.mark.slow

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def smoke_doc(tmp_path_factory):
    """One smoke-suite run shared by every test in this module.

    module-scoped, so env handling is manual (monkeypatch is per-test);
    the autotune cache goes to a temp dir to keep the user's real cache
    untouched.
    """
    path = tmp_path_factory.mktemp("bench") / "autotune.json"
    saved = os.environ.get(autotune.ENV_CACHE)
    os.environ[autotune.ENV_CACHE] = str(path)
    autotune.invalidate_memo()
    try:
        doc = suite.run_suite("smoke", repeats=1)
    finally:
        if saved is None:
            os.environ.pop(autotune.ENV_CACHE, None)
        else:
            os.environ[autotune.ENV_CACHE] = saved
        autotune.invalidate_memo()
    return doc


def _gated_time_entry(doc):
    for e in doc["entries"]:
        if e["kind"] == "time" and e.get("meta", {}).get("gate", True) \
                and e["seconds"] > 0:
            return e
    raise AssertionError("no gated timing entry in the smoke document")


def test_smoke_document_shape(smoke_doc):
    assert smoke_doc["schema"] == suite.SCHEMA
    assert smoke_doc["mode"] == "smoke"
    assert smoke_doc["fingerprint"]["platform"] == "cpu"
    names = [e["name"] for e in smoke_doc["entries"]]
    assert len(names) == len(set(names))
    kinds = {e["kind"] for e in smoke_doc["entries"]}
    assert kinds == {"time", "accuracy", "check"}
    # the acceptance-critical sections are present
    assert "calibration_matmul_scan" in names
    assert any(n.startswith("smoke_gram_") for n in names)
    assert any(n.startswith("autotune_") and n.endswith("_auto")
               for n in names)
    assert any(n.startswith("gradacc_") for n in names)


def test_write_load_roundtrip(smoke_doc, tmp_path):
    path = tmp_path / "bench.json"
    suite.write_json(smoke_doc, str(path))
    assert suite.load_json(str(path))["entries"] == smoke_doc["entries"]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": 999, "entries": []}))
    with pytest.raises(ValueError, match="schema"):
        suite.load_json(str(bad))


def test_markdown_summary_lists_entries(smoke_doc):
    md = suite.markdown_summary(smoke_doc)
    assert "calibration_matmul_scan" in md
    assert "µs/call" in md


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="mode"):
        suite.run_suite("warp")


def test_compare_self_is_green(smoke_doc):
    regressions, _ = compare.compare_docs(smoke_doc, smoke_doc)
    assert regressions == []


def test_compare_flags_big_slowdown(smoke_doc):
    slow = copy.deepcopy(smoke_doc)
    victim = _gated_time_entry(slow)
    victim["seconds"] = victim["seconds"] * 1000 + 1.0
    regressions, _ = compare.compare_docs(smoke_doc, slow)
    assert any(r.startswith("SLOWER " + victim["name"]) for r in regressions)
    # ...but generous tolerances swallow plausible shared-runner noise
    noisy = copy.deepcopy(smoke_doc)
    _gated_time_entry(noisy)["seconds"] *= 1.5
    regressions, _ = compare.compare_docs(smoke_doc, noisy)
    assert regressions == []


def test_compare_normalizes_uniform_machine_speed(smoke_doc):
    slower_box = copy.deepcopy(smoke_doc)
    for e in slower_box["entries"]:
        if e["kind"] == "time":
            e["seconds"] *= 4.0  # a uniformly 4x slower machine
    regressions, notes = compare.compare_docs(smoke_doc, slower_box)
    assert regressions == []
    assert any("machine-speed factor" in n for n in notes)
    # the same 4x, compared raw, would trip the 2.5x gate somewhere
    regressions, _ = compare.compare_docs(smoke_doc, slower_box,
                                          normalize=False)
    assert regressions != []


def test_compare_flags_accuracy_regression(smoke_doc):
    worse = copy.deepcopy(smoke_doc)
    victim = next(e for e in worse["entries"]
                  if e["kind"] == "accuracy"
                  and e.get("meta", {}).get("gate", True))
    victim["value"] = 0.5
    regressions, _ = compare.compare_docs(smoke_doc, worse)
    assert any(r.startswith("LESS-ACCURATE " + victim["name"])
               for r in regressions)


def test_compare_flags_missing_entries(smoke_doc):
    shrunk = copy.deepcopy(smoke_doc)
    dropped = shrunk["entries"].pop()
    regressions, _ = compare.compare_docs(smoke_doc, shrunk)
    assert any(dropped["name"] in r for r in regressions)
    regressions, notes = compare.compare_docs(smoke_doc, shrunk,
                                              allow_missing=True)
    assert regressions == []
    assert any(dropped["name"] in n for n in notes)


def test_compare_cli_exit_codes(smoke_doc, tmp_path):
    base = tmp_path / "base.json"
    suite.write_json(smoke_doc, str(base))
    assert compare.main([str(base), str(base), "--quiet"]) == 0
    slow = copy.deepcopy(smoke_doc)
    victim = _gated_time_entry(slow)
    victim["seconds"] = victim["seconds"] * 1000 + 1.0
    slow_path = tmp_path / "slow.json"
    suite.write_json(slow, str(slow_path))
    assert compare.main([str(base), str(slow_path), "--quiet"]) == 1
