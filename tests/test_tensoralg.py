"""Algebraic invariants of the flattened truncated tensor algebra."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis: pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st

import repro.core.tensoralg as ta

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * 0.3


@pytest.mark.parametrize("d,depth", [(2, 3), (3, 4), (5, 2), (1, 5)])
def test_layout_sizes(d, depth):
    assert ta.sig_dim(d, depth) == sum(d ** k for k in range(1, depth + 1))
    offs = ta.level_offsets(d, depth)
    assert offs[0] == 0
    assert all(b - a == d ** (k + 1)
               for k, (a, b) in enumerate(zip(offs, offs[1:])))


def test_split_join_roundtrip():
    d, depth = 3, 4
    x = rand(0, 7, ta.sig_dim(d, depth))
    assert np.allclose(ta.join_levels(ta.split_levels(x, d, depth)), x)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 4), depth=st.integers(2, 4), seed=st.integers(0, 99))
def test_chen_associative(d, depth, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b, c = (ta.tensor_exp(jax.random.normal(k, (d,)) * 0.4, depth)
               for k in ks)
    left = ta.chen(ta.chen(a, b, d, depth), c, d, depth)
    right = ta.chen(a, ta.chen(b, c, d, depth), d, depth)
    np.testing.assert_allclose(left, right, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(2, 4), depth=st.integers(2, 5), seed=st.integers(0, 99))
def test_exp_inverse(d, depth, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.5
    e = ta.tensor_exp(z, depth)
    e_inv = ta.tensor_exp(-z, depth)
    ident = ta.chen(e, e_inv, d, depth)
    np.testing.assert_allclose(ident, np.zeros_like(ident), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(2, 3), depth=st.integers(2, 4), seed=st.integers(0, 99))
def test_algebraic_inverse_matches_exp(d, depth, seed):
    z = jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.5
    e = ta.tensor_exp(z, depth)
    np.testing.assert_allclose(ta.sig_inverse(e, d, depth),
                               ta.tensor_exp(-z, depth), rtol=1e-4, atol=1e-5)


def test_identity_is_neutral():
    d, depth = 3, 3
    e = ta.tensor_exp(jnp.array([0.1, -0.2, 0.3]), depth)
    ident = ta.identity_like((), d, depth)
    np.testing.assert_allclose(ta.chen(ident, e, d, depth), e, atol=1e-6)
    np.testing.assert_allclose(ta.chen(e, ident, d, depth), e, atol=1e-6)
