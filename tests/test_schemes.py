"""Scheme-parameterised Goursat stack: order-2 stencil + mixed precision.

The PR 10 acceptance gates, end to end:

* defaults (``order1`` / ``float32``) are bitwise-identical to an explicit
  default :class:`GridConfig` on every backend — values AND grads;
* ``order2`` coincides with ``order1`` bitwise whenever an axis is
  unrefined (the data-gridline fallback degenerates to order-1 at λ = 0);
* every (scheme, interior_dtype, backend) combination's custom-VJP
  backward matches an independent oracle — ``jax.grad`` through the plain
  (non-custom) reference scan, plus f64 finite differences;
* ``order2`` beats ``order1`` at equal grid and matches its accuracy on a
  ≥2× coarser grid within the gated rel-err budget (f64, antidiag);
* bf16 interiors stay usefully close to f32 at long L and NaNs poison,
  never mask;
* config validation names the field and the accepted values; approximate
  backends refuse non-default schemes ("never silently downgraded");
  Pallas refuses order-2 strips of height 1;
* a warm scheme-frontier autotune entry + ``error_budget=`` reproduces the
  explicit coarser/order-2/bf16 configuration bitwise, and an explicit
  scheme choice is never overridden.
"""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.config import (GRID_INTERIOR_DTYPES, GRID_SCHEMES, GridConfig,
                               LaunchConfig)
from repro.core.gram import sigkernel_gram
from repro.core.sigkernel import delta_matrix, sigkernel

_sk = importlib.import_module("repro.core.sigkernel")

jax.config.update("jax_platform_name", "cpu")

BACKENDS = ("reference", "antidiag", "pallas", "pallas_fused")
COMBOS = [(s, dt) for s in GRID_SCHEMES for dt in GRID_INTERIOR_DTYPES]


def paths(seed, B=2, L=6, d=2, scale=0.2):
    k = jax.random.PRNGKey(seed)
    return (jax.random.normal(k, (B, L, d)) * scale).astype(jnp.float32)


def _bitwise(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape
    assert a.tobytes() == b.tobytes()


def _max_rel(got, want):
    den = max(float(jnp.abs(want).max()), 1e-9)
    return float(jnp.abs(got - want).max()) / den


# ---------------------------------------------------------------------------
# defaults are bitwise-stable; order2 degenerates to order1 at λ = 0
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_defaults_bitwise_identical(backend):
    """grid=None, GridConfig() and an explicit order1/float32 GridConfig are
    the same static configuration — values and grads bitwise equal."""
    x, y = paths(0), paths(1)
    explicit = GridConfig(1, 1, scheme="order1", interior_dtype="float32")
    k_def = sigkernel(x, y, grid=GridConfig(1, 1), backend=backend)
    k_exp = sigkernel(x, y, grid=explicit, backend=backend)
    _bitwise(k_def, k_exp)
    g_def = jax.grad(lambda q: sigkernel(
        q, y, grid=GridConfig(1, 1), backend=backend).sum())(x)
    g_exp = jax.grad(lambda q: sigkernel(
        q, y, grid=explicit, backend=backend).sum())(x)
    _bitwise(g_def, g_exp)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("lam1,lam2", [(0, 0), (0, 2)])
def test_order2_equals_order1_on_unrefined_axis(backend, lam1, lam2):
    """With an unrefined axis every cell sits on a data gridline, so the
    order-2 fallback rule makes the schemes coincide *bitwise* (stencil.py
    module docstring) — values and grads."""
    x, y = paths(2), paths(3, L=5)
    g1 = GridConfig(lam1, lam2, scheme="order1")
    g2 = GridConfig(lam1, lam2, scheme="order2")
    _bitwise(sigkernel(x, y, grid=g2, backend=backend),
             sigkernel(x, y, grid=g1, backend=backend))
    d1 = jax.grad(lambda q: sigkernel(
        q, y, grid=g1, backend=backend).sum())(x)
    d2 = jax.grad(lambda q: sigkernel(
        q, y, grid=g2, backend=backend).sum())(x)
    _bitwise(d2, d1)


# ---------------------------------------------------------------------------
# exact backward per (scheme, interior_dtype, backend)
# ---------------------------------------------------------------------------

def _oracle_grad(x, y, grid):
    """jax.grad through the *plain* reference scan (no custom VJP): XLA's
    autodiff of solve_goursat is an independent backward implementation with
    a bitwise-identical forward (same rounding), so it checks each backend's
    one-pass adjoint for f32 AND bf16 interiors."""
    def f(q):
        delta = delta_matrix(q, y)
        return _sk.solve_goursat(delta, grid.lam1, grid.lam2,
                                 scheme=grid.scheme,
                                 interior_dtype=grid.interior_dtype).sum()
    return jax.grad(f)(x)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scheme,idt", COMBOS)
def test_backward_exact_per_combination(backend, scheme, idt):
    x, y = paths(4, L=5), paths(5, L=6)
    g = GridConfig(1, 1, scheme=scheme, interior_dtype=idt)
    got = jax.grad(lambda q: sigkernel(
        q, y, grid=g, backend=backend).sum())(x)
    want = _oracle_grad(x, y, g)
    assert _max_rel(got, want) < (2e-5 if idt == "float32" else 2e-4)


@pytest.mark.parametrize("scheme", GRID_SCHEMES)
def test_backward_matches_finite_differences(scheme):
    """f64 central differences against the one-pass adjoint — the
    discretisation-independent ground truth for the custom VJP."""
    with jax.experimental.enable_x64():
        key = jax.random.PRNGKey(6)
        d = (jax.random.normal(key, (4, 5)) * 0.3).astype(jnp.float64)
        v = jax.random.normal(jax.random.PRNGKey(7), (4, 5)).astype(
            jnp.float64)
        grid = _sk.solve_goursat(d[None], 1, 1, return_grid=True,
                                 scheme=scheme)
        gbar = jnp.ones((1,), jnp.float64)
        dd = _sk.solve_goursat_grad(d[None], grid, gbar, 1, 1,
                                    scheme=scheme)[0]
        eps = 1e-6
        kp = _sk.solve_goursat((d + eps * v)[None], 1, 1, scheme=scheme)[0]
        km = _sk.solve_goursat((d - eps * v)[None], 1, 1, scheme=scheme)[0]
        fd = (kp - km) / (2 * eps)
        directional = float(jnp.sum(dd * v))
        assert abs(directional - float(fd)) / max(abs(float(fd)), 1e-12) \
            < 1e-6


# ---------------------------------------------------------------------------
# accuracy: order-2 at equal and 2×-coarser grids (f64, antidiag)
# ---------------------------------------------------------------------------

def test_order2_accuracy_gates():
    with jax.experimental.enable_x64():
        x = (jax.random.normal(jax.random.PRNGKey(0), (2, 5, 2))
             ).astype(jnp.float64)
        y = (jax.random.normal(jax.random.PRNGKey(1), (2, 5, 2))
             ).astype(jnp.float64)

        def solve(lam, scheme):
            g = GridConfig(lam, lam, scheme=scheme)
            return np.asarray(sigkernel(x, y, grid=g, backend="antidiag"))

        truth = solve(6, "order2")

        def err(lam, scheme):
            return float(np.max(np.abs(solve(lam, scheme) - truth)
                                / np.abs(truth)))

        e1_3, e1_4 = err(3, "order1"), err(4, "order1")
        e2_2, e2_3, e2_4 = (err(2, "order2"), err(3, "order2"),
                            err(4, "order2"))
    # order-2 beats order-1 at equal grid, with margin (measured ~20×)
    assert e2_4 * 1.5 < e1_4
    assert e2_3 * 1.5 < e1_3
    # order-2 on a 2× coarser grid matches order-1's accuracy, inside the
    # gated rel-err budget the scheme_frontier workload also enforces
    assert e2_3 < e1_4
    assert e2_3 <= 0.05
    # convergence orders: order-1 halves error ×~4 per level (h²); order-2
    # contracts much faster in the pre-asymptotic range that matters
    assert 3.0 < e1_3 / e1_4 < 6.5
    assert e2_2 / e2_3 > 8.0


# ---------------------------------------------------------------------------
# bf16 interiors: bounded drift at long L, NaNs poison
# ---------------------------------------------------------------------------

def test_bf16_agreement_long_paths():
    """bf16 interior rounding drifts with grid size but stays bounded —
    measured ~0.1 rel at L=32 and ~0.32 at L=128 (each interior cell is
    rounded, so error grows with the number of updates)."""
    for L, lam, gate in [(32, 0, 0.15), (128, 0, 0.60)]:
        x, y = paths(8, B=4, L=L), paths(9, B=4, L=L)
        kf = sigkernel(x, y, grid=GridConfig(lam, lam), backend="antidiag")
        kb = sigkernel(x, y, grid=GridConfig(
            lam, lam, interior_dtype="bfloat16"), backend="antidiag")
        assert bool(jnp.isfinite(kb).all())
        assert float((jnp.abs(kf - kb) / jnp.abs(kf)).max()) < gate


@pytest.mark.parametrize("backend", ("reference", "antidiag", "pallas"))
@pytest.mark.parametrize("idt", GRID_INTERIOR_DTYPES)
def test_nan_poisons_never_masks(backend, idt):
    x, y = paths(10, L=12), paths(11, L=12)
    x = x.at[0, 5, 1].set(jnp.nan)
    g = GridConfig(1, 1, scheme="order2", interior_dtype=idt)
    k = sigkernel(x, y, grid=g, backend=backend)
    assert bool(jnp.isnan(k[0]))
    assert bool(jnp.isfinite(k[1]))


# ---------------------------------------------------------------------------
# validation: every config field names itself and the accepted values
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("field,bad", [
    ("lam1", -1), ("lam1", 1.5), ("lam1", True),
    ("lam2", -1), ("lam2", 2.0), ("lam2", False),
])
def test_gridconfig_lam_validation(field, bad):
    with pytest.raises(ValueError,
                       match=rf"GridConfig\.{field} must be a non-negative "
                             rf"Python int"):
        GridConfig(**{field: bad})


def test_gridconfig_scheme_validation():
    with pytest.raises(ValueError,
                       match=r"GridConfig\.scheme must be one of "
                             r"\('order1', 'order2'\)"):
        GridConfig(scheme="order3")
    with pytest.raises(ValueError,
                       match=r"GridConfig\.interior_dtype must be one of "
                             r"\('float32', 'bfloat16'\)"):
        GridConfig(interior_dtype="float64")


@pytest.mark.parametrize("field", ["pde_strip", "sig_bt", "sig_lb",
                                   "gram_row_block", "band_chunk"])
@pytest.mark.parametrize("bad", [0, -2, 1.5, True])
def test_launchconfig_validation(field, bad):
    with pytest.raises(ValueError,
                       match=rf"LaunchConfig\.{field} must be None or a "
                             rf"positive Python int"):
        LaunchConfig(**{field: bad})


@pytest.mark.parametrize("field", ["pde_strip", "sig_bt", "sig_lb"])
def test_launchconfig_pow2_validation(field):
    with pytest.raises(ValueError,
                       match=rf"LaunchConfig\.{field} must be a power of "
                             rf"two"):
        LaunchConfig(**{field: 3})


# ---------------------------------------------------------------------------
# capability refusals: schemes are never silently downgraded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["rff", "nystroem"])
def test_approx_backends_refuse_order2(backend):
    with pytest.raises(ValueError, match="never silently downgraded"):
        dispatch.check_scheme(backend, "order2", op="gram")
    # and the refusal names a capable backend to switch to
    with pytest.raises(ValueError, match="'reference'"):
        dispatch.check_scheme(backend, "order2", op="gram")


def test_gram_engine_refuses_order2_approx():
    X, Y = paths(12, B=3), paths(13, B=3)
    with pytest.raises(ValueError, match="never silently downgraded"):
        sigkernel_gram(X, Y, symmetric=False, backend="rff",
                       error_budget=0.1, grid=GridConfig(scheme="order2"))


def test_pallas_refuses_order2_strip_of_one():
    x, y = paths(14), paths(15)
    with pytest.raises(ValueError, match=r"pde_strip >= 2"):
        sigkernel(x, y, grid=GridConfig(scheme="order2"), backend="pallas",
                  launch=LaunchConfig(pde_strip=1))


# ---------------------------------------------------------------------------
# error_budget= scheme frontier: warm cache reproduces the explicit config
# ---------------------------------------------------------------------------

def test_budget_hook_replays_frontier_point(tmp_path, monkeypatch):
    from repro.bench import autotune
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "cache.json"))
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    X, Y = paths(16, B=3, L=6), paths(17, B=2, L=6)
    Lx = X.shape[1] - 1
    key = autotune.cache_key(
        "gram", (X.shape[0], Y.shape[0], Lx << 2, Lx << 2, X.shape[2]),
        "float32", scheme=True)
    # stampless hand-written entry (accepted — seconds only gate locally)
    autotune._store(key, {
        "scheme_frontier": [{"scheme": "order2", "coarsen": 1,
                             "interior_dtype": "bfloat16",
                             "rel_err": 0.01, "seconds": 1e-4}],
        "exact_seconds": 1.0,
    })
    got = sigkernel_gram(X, Y, symmetric=False, grid=GridConfig(2, 2),
                         error_budget=0.1)
    want = sigkernel_gram(X, Y, symmetric=False,
                          grid=GridConfig(1, 1, scheme="order2",
                                          interior_dtype="bfloat16"))
    _bitwise(got, want)


def test_explicit_scheme_never_overridden(tmp_path, monkeypatch):
    """An explicit non-default GridConfig ignores the frontier cache: the
    budget hook only fires from the defaults."""
    from repro.bench import autotune
    monkeypatch.setenv(autotune.ENV_CACHE, str(tmp_path / "cache.json"))
    monkeypatch.delenv(autotune.ENV_DISABLE, raising=False)
    X, Y = paths(18, B=3, L=6), paths(19, B=2, L=6)
    Lx = X.shape[1] - 1
    key = autotune.cache_key(
        "gram", (X.shape[0], Y.shape[0], Lx << 2, Lx << 2, X.shape[2]),
        "float32", scheme=True)
    autotune._store(key, {
        "scheme_frontier": [{"scheme": "order1", "coarsen": 1,
                             "interior_dtype": "bfloat16",
                             "rel_err": 0.01, "seconds": 1e-4}],
        "exact_seconds": 1.0,
    })
    g = GridConfig(2, 2, scheme="order2")
    got = sigkernel_gram(X, Y, symmetric=False, grid=g, error_budget=0.1)
    want = sigkernel_gram(X, Y, symmetric=False, grid=g)
    _bitwise(got, want)
